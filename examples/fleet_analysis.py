"""Fleet analysis: the application-support workflow from the paper.

Simulates a day of jobs on a cluster (hpcmd daemons on every host, island
relays, central aggregation), then walks the paper's §4.4 dashboards:
roofline overview -> specialized views -> detailed job view -> per-job
report, plus the §4.6 automated findings.

    PYTHONPATH=src python examples/fleet_analysis.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import Aggregator, JobManifest, query
from repro.core.daemon import DaemonConfig, Hpcmd
from repro.core.dashboards import (markdown_table, render_roofline_svg,
                                   roofline_points,
                                   view_idle_accelerators,
                                   view_low_participation,
                                   view_memory_underuse,
                                   view_top_apps_by_device_hours)
from repro.core.detectors import DetectorBank
from repro.core.report import generate_report
from repro.core.sources import StaticStepCost, StepClock, XlaCostSource
from repro.core.transport import IslandRelay, StreamFileSink


def simulate_fleet(root: Path, n_islands=2, jobs_per_island=4,
                   hosts_per_job=3, samples=24):
    """Run real daemons for synthetic jobs; returns manifests."""
    rng = np.random.default_rng(0)
    manifests = {}
    apps = ["gemma2-27b", "qwen3-8b", "mamba2-780m", "hymba-1.5b"]
    island_dirs = []
    for isl in range(n_islands):
        node_dirs = []
        for j in range(jobs_per_island):
            job = f"cobra.{isl}{j:02d}"
            app = apps[(isl * jobs_per_island + j) % len(apps)]
            behaviour = ("hang" if (isl, j) == (0, 2)
                         else "idle" if (isl, j) == (1, 1)
                         else "healthy")
            man = JobManifest(job_id=job, user=f"user{j % 3}", app=app,
                              num_hosts=hosts_per_job,
                              num_chips=hosts_per_job * 4,
                              extra={"large_memory": "1"} if j == 3 else {})
            manifests[job] = man
            flops = rng.uniform(0.5, 2.0) * 1e13
            for h in range(hosts_per_job):
                host = f"isl{isl}-node{j:02d}{h}"
                spool = root / "nodes" / host
                node_dirs.append(spool)
                clock = StepClock()
                d = Hpcmd(spool, DaemonConfig(align_to_clock=False),
                          host=host, manifest=man)
                src = XlaCostSource(clock)
                src.set_cost(StaticStepCost(
                    flops=flops, bytes=flops / rng.uniform(2, 200),
                    collective_bytes=flops / 500, num_chips=4,
                    tokens_per_step=8192))
                d.add_source(src)
                from repro.core.sources import DeviceSource, EnvSource

                class FakeDevice(DeviceSource):
                    def collect(self, now):
                        frac = 0.02 if behaviour == "idle" else 0.6
                        return {"local_devices": 4, "devices_reporting": 4,
                                "hbm_bytes_in_use": frac * 64e9,
                                "hbm_bytes_limit": 64e9,
                                "hbm_frac_used": frac}
                d.add_source(FakeDevice())
                d.add_source(EnvSource(extra={"app": app}))
                step = 0
                for s in range(samples):
                    ts = 1000.0 + s * 10.0
                    stalled = (behaviour == "hang" and s > samples // 2)
                    if not stalled and behaviour != "idle":
                        step += 1
                        clock.record(step, tokens=8192, loss=3.0 - s * 0.05,
                                     ts=ts)
                    d.tick(ts + 0.5)
                d.spool.close()
        island_dirs.append((root / f"island{isl}", node_dirs))

    # per-island relays -> central inbox (paper §4.3)
    inbox = root / "inbox"
    for isl, (idir, node_dirs) in enumerate(island_dirs):
        relay = IslandRelay(node_dirs, idir, island_name=f"island{isl}")
        relay.pump()
        uplink = relay.uplink(StreamFileSink(inbox / f"island{isl}.log"))
        uplink.ship_once()
    return manifests


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="repro-fleet-"))
    print(f"workdir: {root}")
    manifests = simulate_fleet(root)
    # sharded ingest/query tier: hosts route to two aggregator shards
    # and every dashboard query below runs scatter/gather across them
    # (drop `shards=` for a single-store aggregator) — docs/sharding.md
    agg = Aggregator(root / "inbox", shards=2)
    n = agg.pump()
    print(f"aggregated {n} records from "
          f"{len(agg.store.hosts())} hosts, {len(agg.store.jobs())} jobs "
          f"across {agg.store.num_shards} shards "
          f"(sizes {agg.store.shard_sizes()})\n")

    # --- Fig 2: roofline overview ---------------------------------------
    points = roofline_points(agg.store, manifests)
    svg = render_roofline_svg(points)
    (root / "roofline.svg").write_text(svg)
    print(f"roofline overview: {root / 'roofline.svg'} "
          f"({len(points)} jobs)\n")

    # --- custom staff query (paper: Splunk query language) --------------
    rows = query(agg.store,
                 "search kind=perf gflops>0 "
                 "| stats avg(gflops_per_chip) avg(ai) count by job "
                 "| sort -avg_gflops_per_chip | head 5")
    print("top jobs by GFLOP/s/chip:")
    print(markdown_table(rows))

    # --- specialized views (§4.4) ----------------------------------------
    print("top apps by device-hours:")
    print(markdown_table(view_top_apps_by_device_hours(agg.store,
                                                       manifests)))
    print("accelerators reserved but idle:")
    print(markdown_table(view_idle_accelerators(agg.store)))
    print("large-memory underuse:")
    print(markdown_table(view_memory_underuse(agg.store, manifests)))
    print("low host participation:")
    print(markdown_table(view_low_participation(agg.store, manifests)))

    # --- automated findings (§4.6) ---------------------------------------
    bank = DetectorBank()
    events = bank.scan(agg.store, manifests)
    print("automated findings:")
    for e in events:
        print(f"  [{e.severity:8s}] {e.job:12s} {e.detector}: {e.message}")

    # --- per-job report for the worst offender ---------------------------
    if events:
        job = events[0].job
        report = generate_report(agg.store, job, root / "reports" / job,
                                 manifests)
        print(f"\nper-job report for {job}: {report}")


if __name__ == "__main__":
    main()
