"""Remote shard execution: a 4-worker topology (docs/remote.md).

Spawns four `repro.core.workers` shard-worker processes (the PerSyst
agent-tree leaves), routes a synthetic fleet's records to them over the
wire, runs scatter/gather fleet queries with worker-side partial
caches + conditional-scatter etags, then demonstrates the failure
story: kill a worker (degraded local fallback, identical results) and
restart it (the fresh process re-adopts its durable shard directory).

    PYTHONPATH=src python examples/remote_fleet.py

Workers can equally be managed by hand — e.g. one per node/container:

    repro-shard-worker --dir fleet/shard-00 --port 7700
    repro-shard-worker --dir fleet/shard-01 --port 7701
    ...

then attach with RemoteShardedAggregator(..., addresses=[("127.0.0.1",
7700), ...]) instead of the default spawn=True.
"""

import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import MetricRecord, query
from repro.core.dashboards import markdown_table
from repro.core.remote import RemoteShardedAggregator

FLEET_Q = ("search kind=perf gflops>0 "
           "| stats avg(gflops) p90(step_time_s) count by job "
           "| sort -avg_gflops | head 5")


def synth_records(n_jobs=12, hosts_per_job=4, samples=30, seed=0):
    rng = np.random.default_rng(seed)
    for j in range(n_jobs):
        base = rng.uniform(200, 900)
        for h in range(hosts_per_job):
            for s in range(samples):
                yield MetricRecord(
                    1000.0 + s * 10.0, f"node{j:02d}-{h}", f"job.{j:03d}",
                    "perf", {"gflops": float(base + rng.normal(0, 20)),
                             "step_time_s": float(rng.uniform(0.9, 1.2)),
                             "step": s})


def main() -> None:
    fleet_dir = Path(tempfile.mkdtemp()) / "fleet"
    print(f"== spawning 4 shard workers under {fleet_dir}")
    fleet = RemoteShardedAggregator(num_shards=4, directory=fleet_dir,
                                    seal_threshold=256,
                                    worker_idle_timeout_s=300.0)
    try:
        n = sum(fleet.insert(rec) for rec in synth_records())
        print(f"   ingested {n} records over the wire "
              f"({len(fleet)} fleet-wide)")

        t0 = time.perf_counter()
        rows = query(fleet, FLEET_Q)
        cold_ms = (time.perf_counter() - t0) * 1e3
        print(f"\n== fleet query, cold ({cold_ms:.1f} ms) — "
              f"{fleet.last_query_stats['segments_computed']} segments "
              "computed")
        print(markdown_table(rows))

        t0 = time.perf_counter()
        query(fleet, FLEET_Q)
        warm_ms = (time.perf_counter() - t0) * 1e3
        st = fleet.last_query_stats
        print(f"== same query, warm ({warm_ms:.1f} ms): "
              f"{st['shards_unchanged']}/{st['shards']} workers answered "
              f"not_modified, overlap={st['overlap']}")

        print("\n== killing worker 2 (degraded mode)")
        fleet.kill_worker(2)
        degraded = query(fleet, FLEET_Q)
        st = fleet.last_query_stats
        print(f"   degraded_shards={st['degraded_shards']}, "
              f"rows identical: {degraded == rows}")

        print("== restarting worker 2 (re-adopts its shard dir)")
        fleet.restart_worker(2)
        again = query(fleet, FLEET_Q)
        print(f"   workers alive: {fleet.workers_alive()}, "
              f"rows identical: {again == rows}")

        ex = fleet.explain(FLEET_Q)
        print(f"\n== explain: {ex['segments']} across "
              f"{len(ex['workers'])} workers, "
              f"cache hits={ex['cache']['hits']}")
    finally:
        fleet.close()
        print("== fleet shut down")


if __name__ == "__main__":
    main()
