"""Multi-tenant query service over a remote worker fleet
(docs/service.md).

Three tenant classes share one 4-worker `RemoteShardedAggregator`
through a `QueryService`:

* ``dashboard`` — six refresher threads re-running the same small set
  of watch queries (the refresh-storm case: in-flight dedup + the
  version-keyed result cache collapse them to ~one execution per
  query per store version, and under backpressure refreshes shed to
  their previous rows instead of queueing);
* ``analyst``  — one ad-hoc session issuing distinct exploratory
  queries at interactive priority;
* ``admin``    — one fleet-sweep loop running expensive scans at
  *batch* priority, capped to half the worker lanes so it can never
  starve the dashboards, and throttled by a small per-tenant quota.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import sys
import tempfile
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import MetricRecord, QueryService, QuotaExceeded
from repro.core.dashboards import markdown_table
from repro.core.remote import RemoteShardedAggregator

WATCH_QS = [
    "search kind=perf | stats avg(gflops) count by job | sort job | head 8",
    "search kind=perf | timechart span=60 avg(gflops)",
]
ANALYST_QS = [
    "search kind=perf gflops>400 | stats p90(gflops) by job | sort job",
    "search kind=perf step>=10 | stats avg(step_time_s) by host "
    "| sort host | head 6",
    "search job=job.00* | stats count dc(host) by job | sort job",
]
ADMIN_QS = [
    f"search kind=perf gflops>{x} | stats avg(gflops) p99(step_time_s) "
    "dc(host) by job | sort -avg_gflops | head 10"
    for x in (0, 150, 300, 450, 600, 750)
]


def synth_records(n_jobs=16, hosts_per_job=4, samples=40, seed=0):
    rng = np.random.default_rng(seed)
    for j in range(n_jobs):
        base = rng.uniform(200, 900)
        for h in range(hosts_per_job):
            for s in range(samples):
                yield MetricRecord(
                    1000.0 + s * 10.0, f"node{j:02d}-{h}", f"job.{j:03d}",
                    "perf", {"gflops": float(base + rng.normal(0, 20)),
                             "step_time_s": float(rng.uniform(0.9, 1.2)),
                             "step": s})


def main() -> None:
    fleet_dir = Path(tempfile.mkdtemp()) / "fleet"
    print(f"== spawning 4 shard workers under {fleet_dir}")
    fleet = RemoteShardedAggregator(num_shards=4, directory=fleet_dir,
                                    seal_threshold=256,
                                    worker_idle_timeout_s=300.0)
    svc = QueryService(fleet, max_concurrency=4, queue_limit=8,
                       tenant_quota=4)
    try:
        n = sum(fleet.insert(rec) for rec in synth_records())
        print(f"   ingested {n} records over the wire\n")

        quota_hits = [0]
        shed_hits = [0]

        def dashboard(i):
            for r in range(12):
                q = WATCH_QS[r % len(WATCH_QS)]
                try:
                    _rows, stats = svc.query_with_stats(
                        q, tenant="dashboard", shed_ok=True)
                except QuotaExceeded:
                    # all six panels share the "dashboard" tenant: at
                    # the quota, keep the previous panel like a shed
                    shed_hits[0] += 1
                    continue
                if stats.get("shed"):
                    shed_hits[0] += 1  # keep the previous panel

        def analyst():
            for q in ANALYST_QS * 2:
                svc.query(q, tenant="analyst")

        def admin():
            for q in ADMIN_QS:
                while True:
                    try:
                        svc.submit(q, tenant="admin",
                                   priority="batch").result(timeout=30)
                        break
                    except QuotaExceeded:
                        quota_hits[0] += 1
                        time.sleep(0.01)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=dashboard, args=(i,))
                   for i in range(6)]
        threads += [threading.Thread(target=analyst),
                    threading.Thread(target=admin)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_ms = (time.perf_counter() - t0) * 1e3

        c = svc.stats()
        print(f"== 8 tenant threads done in {wall_ms:.0f} ms")
        print(f"   submitted={c['submitted']}  executed={c['executed']}  "
              f"deduped={c['deduped']}  cached={c['result_cache_hits']}")
        print(f"   shed={c['shed']} (dashboards kept stale panels "
              f"{shed_hits[0]}x)  quota_rejections={c['quota_rejections']} "
              f"(admin backed off {quota_hits[0]}x)")
        collapsed = c["submitted"] - c["executed"] - c["shed"]
        print(f"   -> {collapsed} of {c['submitted']} submissions served "
              "without a private execution\n")

        print("== fleet overview (admin's widest scan)")
        print(markdown_table(svc.query(ADMIN_QS[0], tenant="admin",
                                       priority="batch")))
    finally:
        svc.close()
        fleet.close()
        print("== fleet shut down")


if __name__ == "__main__":
    main()
