"""End-to-end training driver: ~100M-parameter model, monitored, with
checkpointing — the deliverable-(b) driver.  Thin wrapper over the
production launcher (repro.launch.train).

Demo size (CPU-friendly, ~2 min):
    PYTHONPATH=src python examples/train_monitored.py

Full 100M x 200 steps (same code, bigger knobs):
    PYTHONPATH=src python examples/train_monitored.py --full
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main


def main() -> None:
    full = "--full" in sys.argv
    workdir = tempfile.mkdtemp(prefix="repro-train100m-")
    args = [
        "--arch", "qwen3-8b",
        "--preset-100m",
        "--steps", "200" if full else "20",
        "--seq-len", "256" if full else "64",
        "--batch", "8" if full else "4",
        "--workdir", workdir,
        "--checkpoint-every", "50" if full else "10",
        "--monitor-interval", "2.0",
        "--microbatches", "2",
        "--remat", "full",
        "--report",
        "--job-id", "train100m.demo",
    ]
    print(f"workdir: {workdir}")
    raise SystemExit(train_main(args))


if __name__ == "__main__":
    main()
