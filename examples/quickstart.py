"""Quickstart: train a tiny monitored model, query its metrics, write the
per-job report.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core import Aggregator, JobManifest, TrainMonitor, query
from repro.core.report import generate_report
from repro.core.transport import Shipper, StreamFileSink
from repro.data import Pipeline, SyntheticSource
from repro.models import Model, ModelOptions
from repro.optim import AdamW, OptimizerConfig
from repro.train import StepConfig, make_train_step


def main() -> None:
    workdir = Path(tempfile.mkdtemp(prefix="repro-quickstart-"))
    cfg = reduced(get_arch("qwen3-8b"))
    model = Model(cfg, options=ModelOptions(attn_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    optimizer = AdamW(OptimizerConfig(lr=1e-3, warmup_steps=5,
                                      total_steps=40))
    opt_state = optimizer.init(params)

    # --- monitoring: one hpcmd daemon for this "host" -------------------
    manifest = JobManifest(job_id="quickstart.1", user="you",
                           app=cfg.name, num_hosts=1, num_chips=1)
    monitor = TrainMonitor(workdir, manifest, interval_s=0.5,
                           align_to_clock=False)

    pipe = Pipeline(SyntheticSource(cfg, seq_len=64, batch=4),
                    stats=monitor.pipeline_stats)
    step = make_train_step(model, optimizer, StepConfig(ce_seq_chunk=32))
    sample = {k: jnp.asarray(v) for k, v in pipe.next().items()}
    compiled = jax.jit(step).lower(params, opt_state, None,
                                   sample).compile()
    figures = monitor.register_compiled(compiled, tokens_per_step=4 * 64)
    print(f"compiled step: {figures['flops']:.2e} FLOPs/step, "
          f"{figures['collective_bytes']:.2e} collective B/step, "
          f"dominant roofline term: {figures['dominant']}")

    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, opt_state, _, metrics = compiled(params, opt_state, None,
                                                 batch)
        monitor.on_step(i + 1, loss=float(metrics["loss"]),
                        tokens=4 * 64)
    pipe.close()
    monitor.stop()

    # --- transport -> aggregation -> analysis ---------------------------
    agg = Aggregator(workdir / "inbox")
    Shipper(monitor.daemon.spool.root,
            StreamFileSink(workdir / "inbox" / "host0.log")).ship_once()
    agg.pump()
    rows = query(agg.store,
                 "search kind=perf gflops>0 "
                 "| stats avg(gflops) avg(mfu) p50(step_time_s) count")
    print("splunklite:", rows[0])
    report = generate_report(agg.store, "quickstart.1",
                             workdir / "report", {"quickstart.1": manifest})
    print(f"report written: {report} (open report.html in a browser)")


if __name__ == "__main__":
    main()
