"""Fault tolerance demo: the elastic supervisor restarts a crashed
training job from its last committed checkpoint.

A deliberate failure is injected at step 6; the supervisor restarts the
child with --resume, which restores step 4's checkpoint and completes.

    PYTHONPATH=src python examples/failure_recovery.py
"""

import subprocess
import sys
import tempfile
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"


def main() -> None:
    workdir = tempfile.mkdtemp(prefix="repro-elastic-")
    cmd = [sys.executable, "-m", "repro.launch.elastic",
           "--workdir", workdir, "--max-restarts", "2", "--",
           "--arch", "qwen3-8b", "--reduced",
           "--steps", "12", "--seq-len", "32", "--batch", "4",
           "--checkpoint-every", "4", "--fail-at-step", "6",
           "--monitor-interval", "0.5", "--job-id", "demo.recovery"]
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
           "HOME": str(Path.home())}
    print("launching supervisor (failure injected at step 6)...")
    out = subprocess.run(cmd, text=True, env=env, timeout=600)
    print(f"supervisor exit code: {out.returncode} "
          f"(0 = recovered and completed)")


if __name__ == "__main__":
    main()
