"""Monitored serving: batched greedy decoding with hpcmd metrics.

    PYTHONPATH=src python examples/serve_monitored.py
"""

import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import Aggregator, JobManifest, TrainMonitor, query
from repro.core.transport import Shipper, StreamFileSink
from repro.models import Model, ModelOptions
from repro.train.serve import ServeEngine, ServeRequest


def main() -> None:
    workdir = Path(tempfile.mkdtemp(prefix="repro-serve-"))
    cfg = reduced(get_arch("gemma3-4b"))
    model = Model(cfg, options=ModelOptions(attn_chunk=32))
    params = model.init(jax.random.PRNGKey(0))

    manifest = JobManifest(job_id="serve.1", app=cfg.name, num_hosts=1,
                           num_chips=1, shape="decode")
    monitor = TrainMonitor(workdir, manifest, interval_s=0.25,
                           align_to_clock=False)
    engine = ServeEngine(model, params, batch_size=4, max_len=96,
                         monitor=monitor)

    rng = np.random.default_rng(0)
    for i in range(4):
        engine.submit(ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, 8 + i,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=16))
    done = engine.run()
    monitor.stop()
    for i, r in enumerate(done):
        print(f"request {i}: prompt[{len(r.prompt)}] -> {r.out.tolist()}")

    agg = Aggregator(workdir / "inbox")
    Shipper(monitor.daemon.spool.root,
            StreamFileSink(workdir / "inbox" / "host0.log")).ship_once()
    agg.pump()
    rows = query(agg.store, "search kind=perf "
                            "| stats max(steps_per_s) max(tokens_per_s)")
    print("decode throughput (monitor):", rows[0])


if __name__ == "__main__":
    main()
