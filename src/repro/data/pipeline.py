"""Data pipeline: deterministic, host-sharded token streams with
prefetching and monitoring hooks.

Two sources:

* :class:`SyntheticSource` — deterministic tokens from (seed, step, host);
  zero I/O, used by smoke tests and dry-run-adjacent examples.
* :class:`MemmapSource` — a binary token corpus on disk, read via memmap
  with host-strided offsets (each host reads a disjoint stripe); this is
  the production-shaped path.

The :class:`Pipeline` wraps a source with a background prefetch thread and
reports fetch-wait time to the monitor (the paper's I/O data source —
input stalls are a classic cause of "low GFLOP/s" jobs).
"""

from __future__ import annotations

import queue
import threading
import time
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sources import PipelineStats


class SyntheticSource:
    """Deterministic synthetic batches (tokens or stub embeddings)."""

    def __init__(self, cfg: ArchConfig, seq_len: int, batch: int,
                 host_id: int = 0, num_hosts: int = 1, seed: int = 0):
        assert batch % num_hosts == 0, (batch, num_hosts)
        self.cfg = cfg
        self.seq_len = seq_len
        self.local_batch = batch // num_hosts
        self.host_id = host_id
        self.seed = seed

    def get(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.host_id)
        cfg, s, b = self.cfg, self.seq_len, self.local_batch
        out: Dict[str, np.ndarray] = {}
        if cfg.frontend == "audio_frames":
            out["embeds"] = rng.standard_normal(
                (b, s, cfg.d_model)).astype(np.float32) * 0.1
            labels = rng.integers(0, cfg.vocab_size, (b, s))
        else:
            toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
            out["tokens"] = toks[:, :-1].astype(np.int32)
            labels = toks[:, 1:]
        out["labels"] = labels.astype(np.int32)
        out["loss_mask"] = np.ones((b, s), np.float32)
        if cfg.frontend == "image_patches":
            out["image_embeds"] = rng.standard_normal(
                (b, cfg.num_image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.1
        return out


class MemmapSource:
    """Token stripes from a flat binary corpus (uint32 little-endian).

    Host h reads batch rows [h*local_b, (h+1)*local_b) of each step's
    window; windows advance by global_batch*seq tokens per step and wrap.
    """

    def __init__(self, corpus_path, cfg: ArchConfig, seq_len: int,
                 batch: int, host_id: int = 0, num_hosts: int = 1):
        assert batch % num_hosts == 0
        self.cfg = cfg
        self.seq_len = seq_len
        self.batch = batch
        self.local_batch = batch // num_hosts
        self.host_id = host_id
        self.data = np.memmap(corpus_path, dtype=np.uint32, mode="r")
        need = (seq_len + 1) * batch
        if len(self.data) < need:
            raise ValueError(f"corpus too small: {len(self.data)} < {need}")

    @staticmethod
    def write_synthetic_corpus(path, vocab_size: int, num_tokens: int,
                               seed: int = 0) -> Path:
        rng = np.random.default_rng(seed)
        arr = rng.integers(0, vocab_size, num_tokens, dtype=np.uint32)
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        arr.tofile(path)
        return path

    def get(self, step: int) -> Dict[str, np.ndarray]:
        s, b = self.seq_len, self.local_batch
        row = s + 1
        step_span = self.batch * row
        usable = (len(self.data) // row) * row
        base = (step * step_span) % max(usable - step_span, row)
        start = base + self.host_id * b * row
        window = np.asarray(
            self.data[start:start + b * row]).reshape(b, row)
        toks = np.minimum(window, self.cfg.vocab_size - 1)
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, s), np.float32),
        }
        if self.cfg.frontend == "image_patches":
            rng = np.random.default_rng(step)
            out["image_embeds"] = rng.standard_normal(
                (b, self.cfg.num_image_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.1
        return out


class Pipeline:
    """Background-prefetching wrapper with monitoring hooks."""

    def __init__(self, source, stats: Optional[PipelineStats] = None,
                 prefetch: int = 2, start_step: int = 0):
        self.source = source
        self.stats = stats or PipelineStats()
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.get(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        step, batch = self._q.get()
        wait = time.perf_counter() - t0
        tokens = int(batch.get("tokens", batch.get("embeds")).shape[0]
                     * self.source.seq_len)
        self.stats.on_batch(tokens, wait)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
