"""Data pipeline substrate."""
from repro.data.pipeline import MemmapSource, Pipeline, SyntheticSource
