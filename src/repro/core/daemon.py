"""The hpcmd daemon analog (paper §4.2).

One daemon per host process.  It samples its registered sources at
clock-aligned intervals (synchronization across hosts via the system
clock, *zero* inter-host communication), attributes samples to the job
described by the launcher-written manifest (the SLURM-integration analog),
writes key=value lines to the local spool, and can be suspended so an
external profiler gets the "counters" to itself.

Per the paper's policy, hosts without a (single) job are not monitored
unless ``monitor_idle`` is set.
"""

from __future__ import annotations

import contextlib
import json
import math
import os
import socket
import threading
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.schema import MetricRecord, encode_line
from repro.core.sources import MetricSource
from repro.core.transport import Spool


@dataclass
class JobManifest:
    """Written by the launcher; read by the daemon (SLURM analog)."""

    job_id: str
    user: str = "unknown"
    app: str = "unknown"          # architecture / application name
    shape: str = ""               # input-shape id
    num_hosts: int = 1
    num_chips: int = 1
    mesh_shape: str = ""
    started_ts: float = 0.0
    extra: Dict[str, str] = field(default_factory=dict)

    def save(self, path: os.PathLike) -> None:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(asdict(self), f, indent=1)
        os.replace(tmp, p)

    @classmethod
    def load(cls, path: os.PathLike) -> Optional["JobManifest"]:
        try:
            with open(path, encoding="utf-8") as f:
                d = json.load(f)
            return cls(**d)
        except (OSError, ValueError, TypeError):
            return None


@dataclass
class DaemonConfig:
    interval_s: float = 600.0     # paper: one sample per 10 minutes
    align_to_clock: bool = True   # paper: sync across nodes via system clock
    monitor_idle: bool = False    # paper: skip idle/shared nodes
    max_segment_bytes: int = 1 << 20
    spool_fsync: bool = False     # fsync spool writes (crash-safe samples)


class Hpcmd:
    """The monitoring daemon.

    Deterministic embedding: call :meth:`tick` directly (tests, in-loop
    usage).  Background embedding: :meth:`start` / :meth:`stop` run the
    same tick loop in a daemon thread.
    """

    def __init__(self, spool_dir: os.PathLike,
                 config: Optional[DaemonConfig] = None,
                 host: Optional[str] = None,
                 manifest: Optional[JobManifest] = None) -> None:
        self.config = config or DaemonConfig()
        self.host = host or socket.gethostname()
        self.manifest = manifest
        self.spool = Spool(spool_dir,
                           max_segment_bytes=self.config.max_segment_bytes,
                           fsync=self.config.spool_fsync)
        self.sources: List[MetricSource] = []
        self._once_done: set = set()
        self._suspended = 0
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.samples_written = 0

    # ------------------------------------------------------------- sources
    def add_source(self, source: MetricSource) -> "Hpcmd":
        self.sources.append(source)
        return self

    # ----------------------------------------------------------- job state
    def set_manifest(self, manifest: Optional[JobManifest]) -> None:
        with self._lock:
            self.manifest = manifest
            self._once_done.clear()  # new job -> re-emit one-shot meta

    def load_manifest(self, path: os.PathLike) -> None:
        self.set_manifest(JobManifest.load(path))

    @property
    def node_state(self) -> str:
        return "allocated" if self.manifest is not None else "idle"

    # ------------------------------------------------------------- suspend
    def suspend(self) -> None:
        """Paper §4.2: users may suspend hpcmd to get exclusive access to
        hardware counters for profilers (VTune/PAPI analog)."""
        with self._lock:
            self._suspended += 1

    def resume(self) -> None:
        with self._lock:
            self._suspended = max(0, self._suspended - 1)

    @contextlib.contextmanager
    def suspended(self):
        self.suspend()
        try:
            yield
        finally:
            self.resume()

    @property
    def is_suspended(self) -> bool:
        return self._suspended > 0

    # ---------------------------------------------------------------- tick
    def tick(self, now: Optional[float] = None) -> int:
        """Run one sampling round.  Returns #records written."""
        now = time.time() if now is None else now
        if self.is_suspended:
            return 0
        if self.manifest is None and not self.config.monitor_idle:
            return 0
        job = self.manifest.job_id if self.manifest else "idle"
        written = 0
        for src in self.sources:
            if src.once and id(src) in self._once_done:
                continue
            fields = src.safe_collect(now)
            if fields is None:
                continue
            if src.once:
                self._once_done.add(id(src))
            rec = MetricRecord(ts=now, host=self.host, job=job,
                               kind=src.kind, fields=fields)
            self.spool.write_line(encode_line(rec))
            written += 1
        self.samples_written += written
        return written

    def next_sample_time(self, now: Optional[float] = None) -> float:
        now = time.time() if now is None else now
        iv = self.config.interval_s
        if not self.config.align_to_clock:
            return now + iv
        return (math.floor(now / iv) + 1) * iv

    # ----------------------------------------------------------- threading
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.is_set():
                target = self.next_sample_time()
                while not self._stop.is_set():
                    delay = target - time.time()
                    if delay <= 0:
                        break
                    self._stop.wait(min(delay, 0.25))
                if self._stop.is_set():
                    break
                self.tick(target)

        self._thread = threading.Thread(target=_loop, name="hpcmd",
                                        daemon=True)
        self._thread.start()

    def stop(self, final_tick: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if final_tick:
            self.tick()
        self.spool.close()
