"""Remote shard execution — worker processes, partial-state wire
protocol, and streaming gather (docs/remote.md).

The paper's production pipeline pushes collection and partial
processing onto the nodes and ships compact results upward (hpcmd →
rsyslog → Splunk indexers); PerSyst (arXiv:2009.06061) makes the same
move with a tree of aggregation agents that reduce on the way up.  This
module is that tier: shard stores live in separate **worker processes**
(``repro.core.workers``), the coordinator ships each worker a
serialized :class:`~repro.core.splunklite.ScatterPlan`, and every
worker replies with a merged map of *partial aggregation states* — the
small, immutable, content-keyed values PR 3/4 already produce, cache,
and merge per segment.  The gather is two-level, the PerSyst agent-tree
shape::

    segment partials ──(worker-local merge)──► per-worker partial map
    per-worker maps ──(coordinator merge)────► finalize ► tail stages

Wire protocol (both directions): length-prefixed JSON frames — a
4-byte big-endian payload length followed by a UTF-8 JSON object,
``MAX_FRAME_BYTES`` bounded.  Every request carries an ``op``; every
reply carries ``ok`` (error replies add ``kind``/``error`` and the
client re-raises ``QueryError`` kinds locally).  Connections open with
a ``hello`` exchange that pins ``PROTOCOL_VERSION`` and
``CODEC_VERSION`` — a mismatched worker is refused at connect time,
never mid-query.

Value codec (versioned, strict-JSON safe — no NaN/Infinity literals):
scalars (str/bool/int/finite float/None) pass through; every composite
is a tagged two-element list, so plain JSON arrays never appear bare
and decoding is unambiguous::

    ["f", "nan"|"inf"|"-inf"]   non-finite float
    ["t", [...]]                tuple        (partial states, group keys)
    ["l", [...]]                list         (generic lists)
    ["s", [...]]                set          (exact dc label sets)
    ["q", [...]]                P2Summary    (its state() tuple, encoded)
    ["Q", count, b64]           list of P2Summary, bulk-packed as raw
                                float64 records (the hot quantile path:
                                one base64 blob instead of thousands of
                                JSON floats; bit-exact either way)

That covers every partial kind in the scatter/gather algebra
(count int, sum/avg ``(n, sum)``, min/max/range ``(n, min, max)`` with
±inf empties, Welford ``(n, mean, M2)``, ``dc`` label sets, quantile
``P2Summary`` lists — raw and knotted) *and* the exact-row-gather
fallback rows.  Python's shortest-repr float serialization round-trips
exactly, so remote results are byte-identical to in-process execution
(the parity suite asserts it).

Failure semantics: a worker that dies mid-query is detected at the
socket, the coordinator reconnects once (the worker may have been
restarted — it re-adopts its durable ``shard-NN/`` directory from the
PR 2 manifests + WAL), and if that fails the shard degrades to local
in-process execution over a **read-only** open of the same directory.
Degraded shards are counted in ``last_query_stats["degraded_shards"]``
and ``explain()``; results stay identical because the fallback replays
exactly the state the worker would have served.
"""

from __future__ import annotations

import base64
import math
import json
import os
import socket
import struct
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import faults, splunklite
from repro.core.columnar import ColumnScan, ColumnarMetricStore
from repro.core.faults import CircuitBreaker, FaultPlan, RetryPolicy
from repro.core.schema import MetricRecord, encode_line, parse_line
from repro.core.shards import ShardedAggregator
from repro.core.sketches import P2Summary
from repro.core.splunklite import QueryError, ScatterPlan, _Fallback
from repro.core.telemetry import NULL_SPAN, Telemetry

PROTOCOL_VERSION = 2
CODEC_VERSION = 1
MAX_FRAME_BYTES = 1 << 28
# Top bit of the length prefix: this frame carries a 4-byte crc32c
# trailer after the payload (docs/faults.md).  Self-describing per
# frame, so either side may turn checksums off (benchmarks) and a v2
# receiver still interoperates frame by frame.  MAX_FRAME_BYTES is far
# below the flag bit, so a flagged length can never be mistaken for a
# huge plain frame.
FRAME_CRC_FLAG = 0x80000000
READY_PREFIX = "REPRO_WORKER_READY"

_LEN = struct.Struct("!I")
_NONFINITE = {"nan": math.nan, "inf": math.inf, "-inf": -math.inf}


class RemoteProtocolError(RuntimeError):
    """Malformed frame, codec violation, or version mismatch."""


class FrameChecksumError(RemoteProtocolError):
    """A frame's payload contradicts its crc32c trailer (bit rot or a
    fault-injected flip).  Unlike other protocol errors this one is
    *transient*: the connection is torn down and the op retried."""


class WorkerUnavailable(ConnectionError):
    """The worker for a shard cannot be reached (dead or unreachable)."""


class DeadlineExceeded(WorkerUnavailable, TimeoutError):
    """Retries (or the op itself) exhausted the end-to-end deadline
    budget.  Subclasses :class:`WorkerUnavailable` so every existing
    failover/degrade catch site treats it as a dead member."""


class CircuitOpen(WorkerUnavailable):
    """The per-worker circuit breaker is open: the worker failed
    consecutively and the reset timeout has not elapsed, so calls fail
    fast without touching the socket (docs/faults.md)."""


class WorkerError(RuntimeError):
    """The worker reached but reported a non-query failure."""


# ===========================================================================
# Value codec
# ===========================================================================

_N_MAX = 2 ** 53  # counts above this would not round-trip through f8


def _encode_summary_list(vs: List[P2Summary]) -> Optional[list]:
    """Bulk-pack a list of canonical P² summaries (the quantile partial
    state) as one float64 blob: per summary ``p, n, point, kind, k``
    followed by ``k`` raw values (kind 0) or 5+5 knots (kind 1).
    Returns ``None`` for non-canonical shapes — the generic per-value
    encoding then applies."""
    floats: List[float] = []
    for s in vs:
        if not isinstance(s, P2Summary) or s.n > _N_MAX:
            return None
        if s.raw is not None:
            if s.knots_v or s.knots_f:
                return None
            floats += (s.p, float(s.n), s.point, 0.0, float(len(s.raw)))
            floats += s.raw
        else:
            if len(s.knots_v) != 5 or len(s.knots_f) != 5:
                return None
            floats += (s.p, float(s.n), s.point, 1.0, 5.0)
            floats += s.knots_v
            floats += s.knots_f
    blob = np.asarray(floats, np.float64).tobytes()
    return ["Q", len(vs), base64.b64encode(blob).decode("ascii")]


def _decode_summary_list(count, b64s) -> List[P2Summary]:
    arr = np.frombuffer(base64.b64decode(b64s), np.float64)
    out: List[P2Summary] = []
    i = 0
    try:
        for _ in range(int(count)):
            p, n, point, kind, k = (float(x) for x in arr[i:i + 5])
            i += 5
            if math.isnan(point):
                point = math.nan  # normalize to the singleton: state
                # tuples compare by identity-then-value, as in-process
            if int(kind) == 0:
                k = int(k)
                raw = tuple(float(x) for x in arr[i:i + k])
                if len(raw) != k:
                    raise ValueError("truncated raw block")
                i += k
                out.append(P2Summary(p, int(n), raw=raw, point=point))
            else:
                kv = tuple(float(x) for x in arr[i:i + 5])
                kf = tuple(float(x) for x in arr[i + 5:i + 10])
                if len(kf) != 5:
                    raise ValueError("truncated knot block")
                i += 10
                out.append(P2Summary(p, int(n), kv, kf, None, point))
    except ValueError as exc:
        raise RemoteProtocolError(f"bad summary block: {exc}") from exc
    if i != arr.shape[0]:
        raise RemoteProtocolError("trailing bytes in summary block")
    return out


def encode_value(v) -> Any:
    """Encode one partial state / group key / row value (see module
    docstring for the tag table).  Raises ``TypeError`` on a value the
    wire algebra does not know — better than silently shipping
    something the far side cannot rebuild."""
    if v is None or isinstance(v, (str, bool, int)):
        return v
    if isinstance(v, float):
        if math.isfinite(v):
            return v
        return ["f", "nan" if math.isnan(v) else
                ("inf" if v > 0 else "-inf")]
    if isinstance(v, tuple):
        return ["t", [encode_value(x) for x in v]]
    if isinstance(v, list):
        if v and isinstance(v[0], P2Summary):
            bulk = _encode_summary_list(v)
            if bulk is not None:
                return bulk
        return ["l", [encode_value(x) for x in v]]
    if isinstance(v, (set, frozenset)):
        return ["s", [encode_value(x) for x in v]]
    if isinstance(v, P2Summary):
        return ["q", [encode_value(x) for x in v.state()]]
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return encode_value(float(v))
    raise TypeError(f"unencodable value {type(v).__name__}: {v!r}")


def decode_value(v):
    """Inverse of :func:`encode_value`."""
    if isinstance(v, list):
        if len(v) == 3 and v[0] == "Q":
            return _decode_summary_list(v[1], v[2])
        if len(v) != 2:
            raise RemoteProtocolError(f"bad tagged value: {v!r}")
        tag, payload = v
        if tag == "f":
            try:
                return _NONFINITE[payload]
            except (KeyError, TypeError):
                raise RemoteProtocolError(f"bad float tag: {payload!r}")
        if tag == "t":
            return tuple(decode_value(x) for x in payload)
        if tag == "l":
            return [decode_value(x) for x in payload]
        if tag == "s":
            return {decode_value(x) for x in payload}
        if tag == "q":
            return P2Summary.from_state(
                tuple(decode_value(x) for x in payload))
        raise RemoteProtocolError(f"unknown value tag {tag!r}")
    return v


def encode_partial_map(pmap: Dict[tuple, Dict[str, Any]]) -> list:
    """``{group key: {output name: partial state}}`` → wire list."""
    return [[encode_value(key),
             {out: encode_value(state) for out, state in states.items()}]
            for key, states in pmap.items()]


def decode_partial_map(obj) -> Dict[tuple, Dict[str, Any]]:
    out: Dict[tuple, Dict[str, Any]] = {}
    for entry in obj:
        if not isinstance(entry, list) or len(entry) != 2:
            raise RemoteProtocolError(f"bad partial-map entry: {entry!r}")
        key, states = entry
        out[decode_value(key)] = {str(o): decode_value(s)
                                  for o, s in states.items()}
    return out


def encode_rows(rows: Sequence[Dict]) -> list:
    """Exact-gather fallback rows → wire form (values via the codec)."""
    return [{k: encode_value(v) for k, v in r.items()} for r in rows]


def decode_rows(obj) -> List[Dict]:
    return [{str(k): decode_value(v) for k, v in r.items()} for r in obj]


def encode_array(arr: np.ndarray) -> Dict[str, Any]:
    """Numeric ndarray → base64 raw bytes + dtype (compact, exact)."""
    arr = np.ascontiguousarray(arr)
    return {"dtype": arr.dtype.str, "n": int(arr.shape[0]),
            "b64": base64.b64encode(arr.tobytes()).decode("ascii")}


def decode_array(obj) -> np.ndarray:
    arr = np.frombuffer(base64.b64decode(obj["b64"]),
                        dtype=np.dtype(obj["dtype"]))
    if arr.shape[0] != int(obj["n"]):
        raise RemoteProtocolError("array length mismatch")
    return arr.copy()  # writable, detached from the transport buffer


def encode_scan(sc: ColumnScan) -> Dict[str, Any]:
    return {
        "n": int(sc.n),
        "ts": encode_array(np.asarray(sc.ts, np.float64)),
        "host_codes": encode_array(np.asarray(sc.host_codes, np.int32)),
        "host_vocab": [str(v) for v in sc.host_vocab.tolist()],
        "job_codes": encode_array(np.asarray(sc.job_codes, np.int32)),
        "job_vocab": [str(v) for v in sc.job_vocab.tolist()],
        "fields": {f: [encode_array(np.asarray(v, np.float64)),
                       encode_array(np.asarray(p, bool))]
                   for f, (v, p) in sc._fields.items()},
    }


def decode_scan(obj) -> ColumnScan:
    fields = {str(f): (decode_array(v), decode_array(p))
              for f, (v, p) in obj["fields"].items()}
    return ColumnScan(
        int(obj["n"]), decode_array(obj["ts"]),
        decode_array(obj["host_codes"]),
        np.array(list(obj["host_vocab"]), dtype=object),
        decode_array(obj["job_codes"]),
        np.array(list(obj["job_vocab"]), dtype=object),
        fields)


# ===========================================================================
# Framing
# ===========================================================================

def send_frame(sock: socket.socket, obj: Dict,
               checksum: bool = True) -> None:
    payload = json.dumps(obj, separators=(",", ":"),
                         allow_nan=False).encode("utf-8")
    if len(payload) > MAX_FRAME_BYTES:
        raise RemoteProtocolError(f"frame too large: {len(payload)}B")
    if checksum:
        sock.sendall(_LEN.pack(len(payload) | FRAME_CRC_FLAG) + payload
                     + _LEN.pack(faults.crc32c(payload)))
    else:
        sock.sendall(_LEN.pack(len(payload)) + payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-frame"
                                  if buf else "peer closed")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Dict:
    (word,) = _LEN.unpack(recv_exact(sock, 4))
    checked = bool(word & FRAME_CRC_FLAG)
    n = word & ~FRAME_CRC_FLAG
    if n > MAX_FRAME_BYTES:
        raise RemoteProtocolError(f"oversized frame announced: {n}B")
    raw = recv_exact(sock, n)
    if checked:
        (want,) = _LEN.unpack(recv_exact(sock, 4))
        got = faults.crc32c(raw)
        if got != want:
            raise FrameChecksumError(
                f"frame checksum mismatch: got {got:#010x}, "
                f"want {want:#010x} over {n}B")
    try:
        obj = json.loads(raw.decode("utf-8"))
    except ValueError as exc:
        raise RemoteProtocolError(f"undecodable frame: {exc}") from exc
    if not isinstance(obj, dict):
        raise RemoteProtocolError("frame payload must be an object")
    return obj


# ===========================================================================
# Worker client + local worker processes
# ===========================================================================

class WorkerClient:
    """One persistent connection to a shard worker.

    ``rpc`` is request/reply; ``send``/``recv`` split the halves so the
    coordinator can issue every shard's request before reading any
    reply (scatter overlaps with transport).  Socket trouble raises
    :class:`WorkerUnavailable` and drops the connection; error replies
    re-raise ``QueryError`` for query mistakes and
    :class:`WorkerError` for everything else."""

    def __init__(self, address: Tuple[str, int],
                 op_timeout_s: float = 60.0,
                 connect_timeout_s: float = 10.0,
                 fault_plan: Optional[FaultPlan] = None,
                 checksums: bool = True) -> None:
        self.address = (str(address[0]), int(address[1]))
        self.op_timeout_s = float(op_timeout_s)
        self.connect_timeout_s = float(connect_timeout_s)
        self.fault_plan = fault_plan
        self.checksums = bool(checksums)
        self._sock: Optional[socket.socket] = None

    @property
    def connected(self) -> bool:
        return self._sock is not None

    def fileno(self) -> int:
        """Raw socket fd — the hedged-scatter path selects over several
        in-flight replies (docs/replication.md)."""
        if self._sock is None:
            raise WorkerUnavailable(f"not connected to {self.address}")
        return self._sock.fileno()

    def connect(self) -> Dict:
        self.close()
        try:
            sock = socket.create_connection(
                self.address, timeout=self.connect_timeout_s)
        except OSError as exc:
            raise WorkerUnavailable(
                f"cannot connect to worker at {self.address}: {exc}")
        sock.settimeout(self.op_timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        if self.fault_plan is not None:
            sock = faults.FaultyTransport(sock, self.fault_plan)
        self._sock = sock
        hello = self.rpc("hello", proto=PROTOCOL_VERSION,
                         codec=CODEC_VERSION)
        if hello.get("proto") != PROTOCOL_VERSION or \
                hello.get("codec") != CODEC_VERSION:
            self.close()
            raise RemoteProtocolError(
                f"worker at {self.address} speaks protocol "
                f"{hello.get('proto')}/codec {hello.get('codec')}, "
                f"need {PROTOCOL_VERSION}/{CODEC_VERSION}")
        return hello

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def send(self, msg: Dict) -> None:
        if self._sock is None:
            raise WorkerUnavailable(f"not connected to {self.address}")
        try:
            send_frame(self._sock, msg, checksum=self.checksums)
        except (OSError, ValueError) as exc:
            self.close()
            raise WorkerUnavailable(f"send to {self.address} failed: {exc}")

    def recv(self) -> Dict:
        if self._sock is None:
            raise WorkerUnavailable(f"not connected to {self.address}")
        try:
            reply = recv_frame(self._sock)
        except RemoteProtocolError:
            # oversized prefix, garbage payload or checksum mismatch:
            # the stream position is unknowable — close so this pooled
            # connection can never serve a desynced next request
            self.close()
            raise
        except (OSError, ConnectionError) as exc:
            self.close()
            raise WorkerUnavailable(f"recv from {self.address} failed: {exc}")
        if not reply.get("ok"):
            kind = reply.get("kind", "")
            err = reply.get("error", "worker error")
            if kind == "QueryError":
                raise QueryError(err)
            raise WorkerError(f"worker at {self.address}: {err}")
        return reply

    def rpc(self, op: str, **kw) -> Dict:
        msg = {"op": op}
        msg.update(kw)
        self.send(msg)
        return self.recv()


class LocalWorkerProcess:
    """A ``python -m repro.core.workers`` subprocess serving one shard
    directory on an ephemeral localhost port, with hard-deadline start
    and stop (a hung worker cannot wedge a CI job: readiness waits are
    bounded and :meth:`stop` escalates terminate → kill)."""

    def __init__(self, shard_dir: os.PathLike, host: str = "127.0.0.1",
                 seal_threshold: int = 4096,
                 dedup_horizon_s: Optional[float] = None,
                 wal_fsync: bool = False,
                 partial_cache_entries: int = 512,
                 idle_timeout_s: Optional[float] = None,
                 spawn_timeout_s: float = 30.0) -> None:
        self.shard_dir = Path(shard_dir)
        cmd = [sys.executable, "-m", "repro.core.workers",
               "--dir", str(self.shard_dir), "--host", host, "--port", "0",
               "--seal-threshold", str(int(seal_threshold)),
               "--partial-cache-entries", str(int(partial_cache_entries))]
        if dedup_horizon_s is not None:
            cmd += ["--dedup-horizon-s", str(float(dedup_horizon_s))]
        if wal_fsync:
            cmd += ["--wal-fsync"]
        if idle_timeout_s is not None:
            cmd += ["--idle-timeout-s", str(float(idle_timeout_s))]
        env = dict(os.environ)
        src_root = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        self.proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                     text=True, env=env)
        try:
            self.address = self._await_ready(float(spawn_timeout_s))
        except Exception:
            self.stop(timeout_s=5.0)
            raise

    def _await_ready(self, timeout_s: float) -> Tuple[str, int]:
        import selectors
        deadline = time.monotonic() + timeout_s
        sel = selectors.DefaultSelector()
        sel.register(self.proc.stdout, selectors.EVENT_READ)
        try:
            while True:
                if self.proc.poll() is not None:
                    raise RuntimeError(
                        f"worker for {self.shard_dir} exited with "
                        f"{self.proc.returncode} before becoming ready")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RuntimeError(
                        f"worker for {self.shard_dir} not ready within "
                        f"{timeout_s:.0f}s")
                if not sel.select(timeout=min(remaining, 0.25)):
                    continue
                line = self.proc.stdout.readline()
                if not line:
                    continue
                if line.startswith(READY_PREFIX):
                    kv = dict(part.split("=", 1)
                              for part in line.split()[1:])
                    return (kv["host"], int(kv["port"]))
        finally:
            sel.close()

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def stop(self, timeout_s: float = 5.0) -> None:
        """Terminate with a hard deadline; escalate to SIGKILL."""
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                try:
                    self.proc.wait(timeout=timeout_s)
                except subprocess.TimeoutExpired:
                    pass
        if self.proc.stdout is not None:
            self.proc.stdout.close()

    def kill(self) -> None:
        """Immediate SIGKILL — simulates a worker crash in tests."""
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()
        if self.proc.stdout is not None:
            self.proc.stdout.close()


class _CacheStatsSnapshot:
    """Read-only view of a worker's partial-cache counters, shaped like
    :class:`~repro.core.columnar.PartialAggregateCache` for the
    aggregator's summing properties."""

    __slots__ = ("hits", "misses", "evictions", "_entries")

    def __init__(self, hits: int, misses: int, evictions: int,
                 entries: int) -> None:
        self.hits = int(hits)
        self.misses = int(misses)
        self.evictions = int(evictions)
        self._entries = int(entries)

    def __len__(self) -> int:
        return self._entries


class OpSession:
    """One in-flight shard request: the checked-out ``(member, client)``
    attempts plus the flags the stats layer reads back after the reply
    drains.  A plain :class:`RemoteShard` session holds exactly one
    attempt; a :class:`ReplicaSet` session may grow a hedge attempt and
    fail over across members."""

    __slots__ = ("op", "kw", "attempts", "backups", "started", "first",
                 "hedged", "failed_over", "winner", "span",
                 "attempt_spans")

    def __init__(self, op: str, kw: Dict[str, Any],
                 attempts: List[Tuple[Any, WorkerClient]]) -> None:
        self.op = op
        self.kw = kw
        self.attempts = attempts
        self.backups: List[Any] = []
        self.started = time.monotonic()
        self.first = attempts[0][0] if attempts else None
        self.hedged = False
        self.failed_over = False
        self.winner = None
        # the caller's per-shard span (set after op_begin); hedge /
        # failover attempts hang child spans off it, keyed by member
        # identity so losers can be marked cancelled
        self.span = None
        self.attempt_spans: Dict[int, Any] = {}

    def finish_attempt(self, member: Any, status: Optional[str] = None,
                       **attrs: Any) -> None:
        att = self.attempt_spans.pop(id(member), None)
        if att is not None:
            if attrs:
                att.set(**attrs)
            att.finish(status)


class RemoteShard:
    """Store-surface proxy for one worker-hosted shard.

    Implements the read/ingest surface :class:`ShardedAggregator`
    expects from a shard (``insert``/``seal``/``records``/``select``/
    ``scan``/vocabs/``__len__``/``_version``), forwarding each call
    over the wire.  Reads degrade to a local **read-only** open of the
    shard's durable directory when the worker is unreachable
    (``degraded_calls`` counts those); ingest never degrades — writing
    around a worker would fork the directory's ownership."""

    def __init__(self, index: int, shard_dir: Path,
                 address: Optional[Tuple[str, int]] = None,
                 process: Optional[LocalWorkerProcess] = None,
                 op_timeout_s: float = 60.0,
                 store_kwargs: Optional[Dict[str, Any]] = None,
                 degraded_ok: bool = True,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 checksums: bool = True,
                 telemetry: Optional[Telemetry] = None) -> None:
        self.index = int(index)
        self.shard_dir = Path(shard_dir)
        self.process = process
        self._op_timeout_s = float(op_timeout_s)
        self.retry = retry
        self.breaker = breaker
        self.fault_plan = fault_plan
        self.checksums = bool(checksums)
        self.telemetry = telemetry
        # negotiated at hello: whether this worker understands the
        # optional ``trace`` request field (docs/observability.md) —
        # old workers never see it
        self.trace_capable = False
        self.retries = 0            # extra attempts beyond the first
        self.checksum_errors = 0    # frames rejected by their trailer
        self.deadline_exceeded = 0  # ops that exhausted their budget
        # idempotency keys: unique per coordinator-shard instance —
        # a retried mutation resends the same key and the worker
        # replays its recorded reply instead of re-applying
        self._idem_prefix = os.urandom(6).hex()
        self._idem_counter = 0
        self.client = self._make_client(address if address is not None
                                        else process.address)
        self.degraded_ok = bool(degraded_ok)
        self.degraded_calls = 0
        self._store_kwargs = dict(store_kwargs or {})
        self._fallback: Optional[ColumnarMetricStore] = None
        # conditional-scatter memo: fingerprint -> (worker version,
        # decoded partial map, {"segments": k, "buffer_rows": b}).
        # Versions are content-stable across worker restarts (the WAL
        # replay reproduces the pre-crash state exactly), so entries
        # survive reconnects.  Bounded LRU — one entry per actively
        # refreshed plan.
        self._scatter_memo: Dict[str, tuple] = {}
        # connection pool: the primary ``client`` plus up to
        # POOL_MAX - 1 extra sockets, so concurrent scatters to the
        # same worker hold independent connections instead of
        # serializing (or worse, interleaving frames) on one.  The lock
        # also guards the scatter memo, the degraded-fallback store,
        # and the counters.  _conn_gen is the pool generation: every
        # teardown (close/kill/restart) bumps it, and a connection
        # checked out under an older generation is closed on release
        # instead of pooled — without this, a connection created
        # mid-flight could be returned to the idle pool *after* the
        # teardown drained it, leaking one socket per kill/restart
        # cycle.
        self._lock = threading.RLock()
        self._idle: List[WorkerClient] = []
        self._primary_busy = False
        self._conn_gen = 0

    SCATTER_MEMO_MAX = 32
    POOL_MAX = 4

    def _make_client(self, address: Tuple[str, int]) -> WorkerClient:
        """Every client this shard opens (primary, pooled, restart) is
        built here, so fault plans and checksum settings apply to all
        of them uniformly."""
        return WorkerClient(address, op_timeout_s=self._op_timeout_s,
                            fault_plan=self.fault_plan,
                            checksums=self.checksums)

    # ------------------------------------------------- circuit breaker --
    def _breaker_ok(self) -> None:
        if self.breaker is not None:
            self.breaker.record_success()

    def _breaker_fail(self) -> None:
        if self.breaker is not None:
            self.breaker.record_failure()

    def _breaker_abort(self) -> None:
        if self.breaker is not None:
            self.breaker.record_abort()

    def scatter_etag(self, fingerprint: str) -> Optional[list]:
        """``[fingerprint, version]`` for a cached decoded map, or
        ``None`` — sent with a scatter so an unchanged worker can reply
        ``not_modified`` instead of recomputing and reshipping."""
        hit = self.scatter_memo_get(fingerprint)
        if hit is None:
            return None
        return [fingerprint, list(hit[0])]

    def scatter_memo_get(self, fingerprint: str) -> Optional[tuple]:
        from repro.core.columnar import _lru_memo_get
        with self._lock:
            return _lru_memo_get(self._scatter_memo, fingerprint)

    def scatter_memo_put(self, fingerprint: str, version, pmap,
                         summary: Dict[str, int]) -> None:
        from repro.core.columnar import _lru_memo_put
        with self._lock:
            _lru_memo_put(self._scatter_memo, fingerprint,
                          (tuple(version), pmap, dict(summary)),
                          self.SCATTER_MEMO_MAX)

    def drop_scatter_memo(self) -> None:
        with self._lock:
            self._scatter_memo.clear()

    # -------------------------------------------------- connection pool --
    def acquire(self) -> WorkerClient:
        """Check out a connected client for an exclusive send/recv
        session.  The scatter/gather paths hold one per query so
        concurrent queries' reply frames cannot interleave; plain
        :meth:`rpc` calls check one out per round trip.  Prefers the
        primary persistent client, then an idle pooled socket, and
        opens a fresh connection (to the primary's *current* address,
        so restarts are honored) only under real concurrency.  Raises
        :class:`WorkerUnavailable` when the worker cannot be reached."""
        if self.breaker is not None and not self.breaker.allow():
            raise CircuitOpen(
                f"shard {self.index} worker at {self.client.address}: "
                "circuit open")
        with self._lock:
            if not self._primary_busy:
                self._primary_busy = True
                if not self.client.connected:
                    try:
                        self.connect()
                    except (WorkerUnavailable, RemoteProtocolError, OSError):
                        self._primary_busy = False
                        raise
                self.client._pool_gen = self._conn_gen
                return self.client
            if self._idle:
                c = self._idle.pop()
                c._pool_gen = self._conn_gen
                return c
            address = self.client.address
            gen = self._conn_gen
        c = self._make_client(address)
        try:
            c.connect()
        except RemoteProtocolError:
            c.close()
            raise
        c._pool_gen = gen
        return c

    def release(self, c: WorkerClient, broken: bool = False) -> None:
        """Return a checked-out client.  ``broken`` (socket trouble or
        an unread reply left in flight) closes it instead of pooling;
        the primary client reconnects lazily on its next use.  A client
        checked out before the last teardown (stale pool generation) is
        always closed — pooling it would resurrect a connection the
        teardown already drained."""
        with self._lock:
            stale = getattr(c, "_pool_gen", -1) != self._conn_gen
            if c is self.client:
                self._primary_busy = False
                if broken or stale:
                    c.close()
                return
            if (not broken and not stale and c.connected
                    and c.address == self.client.address
                    and len(self._idle) < self.POOL_MAX - 1):
                self._idle.append(c)
                return
        c.close()

    def close_pool(self) -> None:
        """Drop every idle pooled connection (restart/kill/close)."""
        with self._lock:
            idle, self._idle = self._idle, []
        for c in idle:
            c.close()

    def invalidate_connections(self) -> None:
        """Unified connection teardown — the one path ``close()``,
        ``kill_worker``, and ``restart_worker`` all use.  Bumps the
        pool generation (checked-out connections created mid-flight are
        closed on release instead of pooled), closes the primary
        client, and drains the idle pool."""
        with self._lock:
            self._conn_gen += 1
            # _primary_busy is NOT reset here: if a query thread holds
            # the primary client mid-recv, closing the socket fails its
            # recv and its own release (stale generation) closes and
            # un-busies it — resetting early would let a third thread
            # re-check-out the same client object concurrently.
        self.client.close()
        self.close_pool()

    def session_send(self, c: WorkerClient, op: str, **kw) -> None:
        """Send ``op`` on a checked-out client, with the same single
        reconnect attempt :meth:`send` performs on the primary."""
        msg = {"op": op}
        msg.update(kw)
        try:
            c.send(msg)
        except WorkerUnavailable:
            if self.process is not None and not self.process.alive:
                raise
            try:
                c.connect()
            except (RemoteProtocolError, OSError) as exc:
                raise WorkerUnavailable(str(exc))
            with self._lock:
                self._drop_fallback()
            c.send(msg)

    # ------------------------------------------------------------- wiring --
    def connect(self) -> Dict:
        hello = self.client.connect()
        self.trace_capable = bool(hello.get("trace"))
        self._drop_fallback()
        # a fresh successful handshake is proof of life: close the
        # breaker immediately so a restarted worker serves without
        # waiting out a reset timeout
        self._breaker_ok()
        return hello

    def _adopt_spans(self, reply: Dict) -> None:
        """Splice worker-side spans shipped in ``reply`` into the
        coordinator's tracer (a reply only carries ``spans`` when the
        request carried trace context)."""
        spans = reply.pop("spans", None) if isinstance(reply, dict) else None
        if spans and self.telemetry is not None:
            self.telemetry.tracer.adopt(spans)

    def _try_reconnect(self) -> bool:
        """One reconnect attempt — covers a worker that was restarted
        behind the same address, or a socket that idled out."""
        if self.process is not None and not self.process.alive:
            return False
        try:
            self.connect()
            return True
        except (WorkerUnavailable, RemoteProtocolError, OSError):
            return False

    def send(self, op: str, **kw) -> None:
        msg = {"op": op}
        msg.update(kw)
        try:
            self.client.send(msg)
        except WorkerUnavailable:
            if not self._try_reconnect():
                raise
            self.client.send(msg)

    def recv(self) -> Dict:
        return self.client.recv()

    def rpc(self, op: str, **kw) -> Dict:
        """One pooled round trip — safe to call from any thread; a
        concurrent rpc checks out its own connection instead of
        interleaving frames with an in-flight scatter.  With a
        :class:`~repro.core.faults.RetryPolicy` configured, transient
        failures (socket trouble, checksum-rejected frames) retry with
        capped backoff under the op-timeout deadline budget;
        exhaustion raises :class:`DeadlineExceeded`.  Mutations must go
        through :meth:`mutate` so retries carry idempotency keys.
        When a traced span is active on this thread, the round trip
        (all attempts) is recorded as one ``rpc.<op>`` child span —
        retried attempts stay inside it, so a trace survives retries
        with its parent/child linkage intact."""
        span = NULL_SPAN
        if self.telemetry is not None:
            parent = self.telemetry.tracer.current()
            if parent.recording:
                span = parent.child(f"rpc.{op}",
                                    attrs={"shard": self.index})
                if self.trace_capable:
                    kw = dict(kw)
                    kw["trace"] = span.ctx()
        with span:
            if self.retry is None:
                return self._rpc_once(op, kw)
            attempts = 0

            def attempt() -> Dict:
                nonlocal attempts
                if attempts:
                    with self._lock:
                        self.retries += 1
                attempts += 1
                return self._rpc_once(op, kw)

            try:
                reply = self.retry.run(
                    attempt,
                    retry_on=(WorkerUnavailable, FrameChecksumError),
                    deadline_s=self._op_timeout_s)
            except faults.RetryBudgetExceeded as exc:
                with self._lock:
                    self.deadline_exceeded += 1
                span.set(attempts=attempts, deadline_exceeded=True)
                raise DeadlineExceeded(
                    f"shard {self.index} op {op!r}: {exc}") from exc
            if attempts > 1:
                span.set(attempts=attempts)
            return reply

    def mutate(self, op: str, **kw) -> Dict:
        """An :meth:`rpc` that stamps a fresh idempotency key — every
        state-changing op routes through here so a retried send can be
        applied at most once by the worker (docs/faults.md)."""
        with self._lock:
            self._idem_counter += 1
            idem = f"{self._idem_prefix}:{self._idem_counter}"
        return self.rpc(op, idem=idem, **kw)

    def _rpc_once(self, op: str, kw: Dict) -> Dict:
        try:
            c = self.acquire()
        except CircuitOpen:
            raise  # fail-fast gate: not evidence about the worker
        except (WorkerUnavailable, RemoteProtocolError, OSError):
            self._breaker_fail()
            raise
        broken = True
        try:
            self.session_send(c, op, **kw)
            reply = c.recv()
            broken = False
            self._breaker_ok()
            self._adopt_spans(reply)
            return reply
        except (QueryError, WorkerError):
            # error *reply*: the frame was fully consumed, the
            # connection is still in protocol sync — and the worker is
            # demonstrably alive
            broken = False
            self._breaker_ok()
            raise
        except FrameChecksumError:
            with self._lock:
                self.checksum_errors += 1
            self._breaker_fail()
            raise
        except (WorkerUnavailable, RemoteProtocolError, OSError):
            self._breaker_fail()
            raise
        finally:
            self.release(c, broken=broken)

    # ------------------------------------------------------- op sessions --
    def op_begin(self, op: str, **kw) -> OpSession:
        """Issue ``op`` on a checked-out connection and return the
        in-flight session — the scatter/gather fan-out issues every
        shard's ``op_begin`` before the first ``op_finish`` (transport
        overlaps with worker compute)."""
        try:
            c = self.acquire()
        except CircuitOpen:
            raise
        except (WorkerUnavailable, RemoteProtocolError, OSError):
            self._breaker_fail()
            raise
        try:
            self.session_send(c, op, **kw)
        except WorkerUnavailable:
            self.release(c, broken=True)
            self._breaker_fail()
            raise
        return OpSession(op, kw, [(self, c)])

    def op_finish(self, session: OpSession) -> Dict:
        """Drain the session's reply.  A definitive error reply
        (``QueryError``/``WorkerError``) leaves the connection in
        protocol sync, so it is released clean; socket trouble raises
        :class:`WorkerUnavailable` and drops the connection."""
        (sh, c), = session.attempts
        session.attempts = []
        try:
            reply = c.recv()
        except FrameChecksumError:
            with sh._lock:
                sh.checksum_errors += 1
            sh.release(c, broken=True)
            sh._breaker_fail()
            raise
        except (WorkerUnavailable, RemoteProtocolError):
            sh.release(c, broken=True)
            sh._breaker_fail()
            raise
        except (QueryError, WorkerError):
            sh.release(c)
            sh._breaker_ok()
            raise
        sh.release(c)
        sh._breaker_ok()
        sh._adopt_spans(reply)
        session.winner = sh
        return reply

    def op_abort(self, session: OpSession) -> None:
        """Abandon an in-flight session (mid-merge failure): the unread
        replies make these connections unusable, so drop them.  The
        breaker records an *abort* (not a failure): nothing was learned
        about this worker, but a half-open probe slot must be freed."""
        for sh, c in session.attempts:
            sh.release(c, broken=True)
            sh._breaker_abort()
        session.attempts = []

    # ----------------------------------------------------- degraded reads --
    def local_store(self) -> ColumnarMetricStore:
        """Read-only in-process open of the shard directory (degraded
        mode).  Invalidated whenever the worker connection comes back —
        a revived worker may accept new inserts this snapshot missed."""
        with self._lock:
            if self._fallback is None:
                kw = {k: self._store_kwargs[k]
                      for k in ("seal_threshold", "dedup_horizon_s",
                                "partial_cache_entries")
                      if k in self._store_kwargs}
                self._fallback = ColumnarMetricStore(
                    directory=self.shard_dir, read_only=True, **kw)
            return self._fallback

    def _degraded(self) -> ColumnarMetricStore:
        """Every degraded read funnels through here, so disabling
        degraded execution covers the whole store surface (scan,
        records, vocabs, ...), not just the query path."""
        if not self.degraded_ok:
            raise WorkerUnavailable(
                f"shard {self.index} worker unavailable and degraded "
                "execution is disabled")
        with self._lock:
            self.degraded_calls += 1
        return self.local_store()

    def _drop_fallback(self) -> None:
        with self._lock:
            fallback, self._fallback = self._fallback, None
        if fallback is not None:
            fallback.close()

    # ------------------------------------------------------ store surface --
    def insert(self, rec: MetricRecord) -> bool:
        return bool(self.mutate("insert",
                                line=encode_line(rec))["accepted"])

    def ingest_lines(self, lines: Iterable[str]) -> int:
        return int(self.mutate("lines", lines=list(lines))["n"])

    def seal(self) -> None:
        self.mutate("seal")

    def __len__(self) -> int:
        try:
            return int(self.rpc("len")["n"])
        except WorkerUnavailable:
            return len(self._degraded())

    @property
    def duplicates_dropped(self) -> int:
        try:
            return int(self.rpc("dups")["n"])
        except WorkerUnavailable:
            # best-effort: the read-only replay cannot reconstruct the
            # worker's lifetime counter, only its current key set
            return self._degraded().duplicates_dropped

    def _version(self) -> tuple:
        try:
            return tuple(self.rpc("version")["v"])
        except WorkerUnavailable:
            return self._degraded()._version()

    @property
    def records(self) -> List[MetricRecord]:
        try:
            lines = self.rpc("records")["lines"]
        except WorkerUnavailable:
            return self._degraded().records
        return [r for r in (parse_line(ln) for ln in lines)
                if r is not None]

    def select(self, job=None, kind=None, since=None, until=None):
        try:
            lines = self.rpc("select", job=job, kind=kind,
                             since=since, until=until)["lines"]
        except WorkerUnavailable:
            yield from self._degraded().select(job=job, kind=kind,
                                               since=since, until=until)
            return
        for ln in lines:
            rec = parse_line(ln)
            if rec is not None:
                yield rec

    def scan(self, job=None, kind=None, since=None, until=None,
             fields: Iterable[str] = ()) -> ColumnScan:
        fields = tuple(fields)
        try:
            reply = self.rpc("scan", job=job, kind=kind, since=since,
                             until=until, fields=list(fields))
        except WorkerUnavailable:
            return self._degraded().scan(job=job, kind=kind, since=since,
                                         until=until, fields=fields)
        return decode_scan(reply["scan"])

    def _vocab(self, which: str, job=None) -> List[str]:
        try:
            return [str(v) for v in
                    self.rpc("vocab", which=which, job=job)["values"]]
        except WorkerUnavailable:
            store = self._degraded()
            if which == "hosts":
                return store.hosts(job)
            return getattr(store, which)()

    def jobs(self) -> List[str]:
        return self._vocab("jobs")

    def kinds(self) -> List[str]:
        return self._vocab("kinds")

    def hosts(self, job=None) -> List[str]:
        return self._vocab("hosts", job=job)

    @property
    def partial_cache(self) -> _CacheStatsSnapshot:
        try:
            st = self.rpc("cache_stats")
        except WorkerUnavailable:
            pc = self._degraded().partial_cache
            return _CacheStatsSnapshot(pc.hits, pc.misses, pc.evictions,
                                       len(pc))
        return _CacheStatsSnapshot(st["hits"], st["misses"],
                                   st["evictions"], st["entries"])

    # -------------------------------------------------- maintenance tier --
    def compact(self, **kwargs) -> Dict:
        """Run segment compaction on the worker (``compact`` op).

        No degraded fallback: the read-only snapshot a dead worker
        leaves behind must refuse compaction (it cannot atomically
        swap manifests the live worker will reopen), so an unavailable
        worker propagates :class:`WorkerUnavailable`.

        When the worker reports retired segment uids, every
        coordinator-side decoded partial map for this shard is evicted:
        those maps were merged from segments that no longer exist, and
        serving one via the ``not_modified`` fast path would pin
        pre-compaction state forever.  The stale read-only fallback
        snapshot is dropped for the same reason."""
        reply = self.mutate("compact", **kwargs)
        stats = reply["stats"]
        if stats.get("retired_uids") or stats.get("runs"):
            self.drop_scatter_memo()
            self._drop_fallback()
        return stats

    def apply_retention(self, **kwargs) -> Dict:
        """Apply retention/rollup tiers on the worker (``retention``
        op).  Rollup tier tuples are shipped as JSON lists.  Like
        :meth:`compact`, a mutation (new rollups or dropped raw
        segments) evicts this shard's scatter memos and fallback
        snapshot."""
        if "rollups" in kwargs and kwargs["rollups"] is not None:
            kwargs["rollups"] = [list(t) if isinstance(t, (list, tuple))
                                 else t for t in kwargs["rollups"]]
        reply = self.mutate("retention", **kwargs)
        stats = reply["stats"]
        if stats.get("rollups_created") or stats.get("dropped_segments"):
            self.drop_scatter_memo()
            self._drop_fallback()
        return stats

    def storage_stats(self) -> Dict:
        """Worker-side storage accounting (``storage`` op); degraded
        fallback reads the shard directory directly."""
        try:
            return self.rpc("storage")["storage"]
        except WorkerUnavailable:
            return self._degraded().storage_stats()

    # ---------------------------------------------------------- lifecycle --
    def ping(self) -> bool:
        try:
            self.rpc("ping")
            return True
        except (WorkerUnavailable, WorkerError):
            return False

    def close(self) -> None:
        """Detach from the worker; shut it down only if we own it.

        Externally managed workers (``addresses=`` fleets) belong to
        whoever started them — closing a coordinator must not take the
        shared fleet dark, so only spawned :class:`LocalWorkerProcess`
        workers get the ``shutdown`` op and the hard-deadline stop."""
        if self.process is not None:
            try:
                self.client.rpc("shutdown")
            except (WorkerUnavailable, WorkerError, RemoteProtocolError):
                pass
        self.invalidate_connections()
        if self.process is not None:
            self.process.stop()
        self._drop_fallback()


class ReplicaSet:
    """Replica-aware shard proxy: one primary plus ``k-1`` replicas
    serving copies of the same shard data (docs/replication.md).

    **Writes route only to the primary** — dedup and WAL semantics are
    exactly the single-worker path — and every write marks the set
    *stale*: reads pin back to the primary (replicas may be behind its
    WAL) until the next :meth:`sync`.  ``sync`` ships each replica the
    segments it is missing (whole-segment adoption, in primary order)
    plus the primary's WAL tail, fast-forwarding the mutation
    generation, so a synced replica holds the primary's exact
    ``(sealed, buffer, seq)`` version and serves byte-identical
    replies.

    While synced, reads are **hedged**: a request goes to the
    fastest-responding member first and a second request fires to the
    next-best member after an adaptive delay (p95 of recent per-shard
    latencies, clamped); the first reply at the synced version wins and
    the loser is drained or dropped.  A member that dies mid-request
    **fails over** to the remaining members instead of entering
    degraded mode — degraded local execution only remains for the
    all-members-dead (or stale-and-primary-dead) corner, where the
    primary's durable directory is still the freshest truth."""

    is_replicated = True

    SCATTER_MEMO_MAX = RemoteShard.SCATTER_MEMO_MAX
    HEDGE_DEFAULT_S = 0.05   # before enough latency samples exist
    HEDGE_MIN_S = 0.002
    HEDGE_MAX_S = 2.0
    LATENCY_WINDOW = 64
    # read ops that may fail over to a synced replica; everything else
    # (ingest, seal, maintenance, replication control) is primary-only
    _READ_OPS = frozenset({
        "len", "dups", "version", "records", "select", "scan", "vocab",
        "cache_stats", "explain", "storage", "scatter", "gather", "ping"})

    def __init__(self, index: int, members: Sequence[RemoteShard],
                 hedge: bool = True,
                 hedge_delay_s: Optional[float] = None,
                 degraded_ok: bool = True) -> None:
        if not members:
            raise ValueError("a replica set needs at least one member")
        self.index = int(index)
        self.members = list(members)
        self.primary = self.members[0]
        self.telemetry = getattr(self.primary, "telemetry", None)
        self.hedge_enabled = bool(hedge)
        self.hedge_delay_s = hedge_delay_s  # fixed override; None=adaptive
        self.degraded_ok = bool(degraded_ok)
        self._lock = threading.RLock()
        from collections import deque as _deque
        self._lat = _deque(maxlen=self.LATENCY_WINDOW)
        self._member_lat = [0.0] * len(self.members)  # EWMA seconds
        # _synced[r]: replica r held the primary's exact version at the
        # last sync; stale: a write landed since, so only the primary
        # may serve reads regardless of the flags
        self._synced = [True] + [False] * (len(self.members) - 1)
        self._synced_version: Optional[tuple] = None
        self.stale = True
        self.syncs = 0
        self.hedged_ops = 0
        self.hedge_wins = 0
        self.hedge_cancelled = 0
        self.failovers = 0
        self.stale_replies = 0
        self.degraded_calls = 0
        # set-level conditional-scatter memo: replies are byte-identical
        # across synced members at one version, so one decoded map
        # serves etags for whichever member answers
        self._scatter_memo: Dict[str, tuple] = {}

    # --------------------------------------------------- identity surface --
    @property
    def shard_dir(self) -> Path:
        return self.primary.shard_dir

    @property
    def client(self) -> WorkerClient:
        return self.primary.client

    @property
    def process(self) -> Optional[LocalWorkerProcess]:
        return self.primary.process

    @property
    def trace_capable(self) -> bool:
        """Trace context is only attached when *every* member
        negotiated it — a hedged request may land on any of them."""
        return all(m.trace_capable for m in self.members)

    def connect(self) -> Dict:
        """Connect the primary (required); replicas best-effort."""
        hello = self.primary.connect()
        for m in self.members[1:]:
            try:
                m.connect()
            except (WorkerUnavailable, RemoteProtocolError, OSError):
                pass
        return hello

    def ping(self) -> bool:
        return any(m.ping() for m in self.members)

    def members_alive(self) -> List[bool]:
        return [m.ping() for m in self.members]

    def close(self) -> None:
        for m in self.members:
            try:
                m.close()
            except Exception:  # noqa: BLE001 - teardown best-effort
                pass

    def _try_reconnect(self) -> bool:
        ok = self.primary._try_reconnect()
        for m in self.members[1:]:
            m._try_reconnect()
        return ok

    def invalidate_connections(self) -> None:
        for m in self.members:
            m.invalidate_connections()

    # ------------------------------------------------------- scatter memo --
    def scatter_etag(self, fingerprint: str) -> Optional[list]:
        hit = self.scatter_memo_get(fingerprint)
        if hit is None:
            return None
        return [fingerprint, list(hit[0])]

    def scatter_memo_get(self, fingerprint: str) -> Optional[tuple]:
        from repro.core.columnar import _lru_memo_get
        with self._lock:
            return _lru_memo_get(self._scatter_memo, fingerprint)

    def scatter_memo_put(self, fingerprint: str, version, pmap,
                         summary: Dict[str, int]) -> None:
        from repro.core.columnar import _lru_memo_put
        with self._lock:
            _lru_memo_put(self._scatter_memo, fingerprint,
                          (tuple(version), pmap, dict(summary)),
                          self.SCATTER_MEMO_MAX)

    def drop_scatter_memo(self) -> None:
        with self._lock:
            self._scatter_memo.clear()
        for m in self.members:
            m.drop_scatter_memo()

    # --------------------------------------------------------- read order --
    def _read_order(self) -> List[RemoteShard]:
        """Members eligible to serve this read, fastest first.  Stale
        sets pin to the primary: an unsynced replica answering would
        silently miss the writes that staled the set."""
        with self._lock:
            if self.stale:
                return [self.primary]
            # an unmeasured member (EWMA 0.0) sorts *last*, not first:
            # preference stays with members that have demonstrated
            # latency (the primary, initially) and backups earn their
            # spot through hedge wins and failovers
            pairs = [(self._member_lat[i] if self._member_lat[i] > 0.0
                      else float("inf"), i, m)
                     for i, m in enumerate(self.members) if self._synced[i]]
        pairs.sort(key=lambda t: (t[0], t[1]))
        return [m for _lat, _i, m in pairs]

    def _note_latency(self, member: RemoteShard, seconds: float) -> None:
        with self._lock:
            self._lat.append(float(seconds))
            i = self.members.index(member)
            old = self._member_lat[i]
            self._member_lat[i] = (float(seconds) if old == 0.0
                                   else 0.7 * old + 0.3 * float(seconds))

    def _hedge_delay(self) -> float:
        if self.hedge_delay_s is not None:
            return float(self.hedge_delay_s)
        with self._lock:
            lats = list(self._lat)
        if len(lats) < 8:
            return self.HEDGE_DEFAULT_S
        p95 = float(np.percentile(np.asarray(lats, np.float64), 95.0))
        return min(max(p95, self.HEDGE_MIN_S), self.HEDGE_MAX_S)

    # ------------------------------------------------------- op sessions --
    def op_begin(self, op: str, **kw) -> OpSession:
        """Issue ``op`` to the fastest eligible member; remaining
        members are kept as hedge/failover backups for
        :meth:`op_finish`."""
        order = self._read_order()
        last: Optional[Exception] = None
        for k, m in enumerate(order):
            try:
                c = m.acquire()
                try:
                    m.session_send(c, op, **kw)
                except WorkerUnavailable:
                    m.release(c, broken=True)
                    raise
            except (WorkerUnavailable, RemoteProtocolError, OSError) as exc:
                if not isinstance(exc, CircuitOpen):
                    m._breaker_fail()
                last = exc
                continue
            session = OpSession(op, kw, [(m, c)])
            session.backups = list(order[k + 1:])
            if k:
                session.failed_over = True
                with self._lock:
                    self.failovers += 1
            return session
        if isinstance(last, WorkerUnavailable):
            raise last
        raise WorkerUnavailable(
            f"no reachable member for shard {self.index}"
            + (f": {last}" if last is not None else ""))

    def _fire_next(self, session: OpSession, hedge: bool) -> bool:
        """Issue the session's op to the next backup member (a hedge on
        the timer, or an immediate failover when every in-flight
        attempt died).  Returns whether an attempt was started."""
        while session.backups:
            m = session.backups.pop(0)
            try:
                c = m.acquire()
                try:
                    m.session_send(c, session.op, **session.kw)
                except WorkerUnavailable:
                    m.release(c, broken=True)
                    m._breaker_fail()
                    continue
            except (WorkerUnavailable, RemoteProtocolError, OSError) as exc:
                if not isinstance(exc, CircuitOpen):
                    m._breaker_fail()
                continue
            session.attempts.append((m, c))
            if session.span is not None and session.span.recording:
                att = session.span.child(
                    "hedge.attempt" if hedge else "failover.attempt")
                att.set(member=self.members.index(m))
                session.attempt_spans[id(m)] = att
            with self._lock:
                if hedge:
                    session.hedged = True
                    self.hedged_ops += 1
                else:
                    session.failed_over = True
                    self.failovers += 1
            return True
        return False

    def _wait_readable(self, session: OpSession,
                       timeout: Optional[float]):
        """Select over the in-flight attempts' sockets.  Returns the
        first readable ``(member, client)``, or ``None`` on timeout.
        Attempts whose socket is already gone are failed immediately."""
        import select as _select
        fds = {}
        for m, c in list(session.attempts):
            try:
                fds[c.fileno()] = (m, c)
            except (WorkerUnavailable, OSError):
                m.release(c, broken=True)
                session.attempts.remove((m, c))
        if not fds:
            return None
        try:
            ready, _w, _x = _select.select(list(fds), [], [], timeout)
        except OSError:
            return None
        if not ready:
            return None
        return fds[ready[0]]

    def _cancel_losers(self, session: OpSession) -> None:
        """A winner was chosen: drain any loser whose reply already
        arrived (its connection stays usable), drop the rest (an unread
        reply in flight would desync the stream)."""
        import select as _select
        for m, c in list(session.attempts):
            drained = False
            try:
                if _select.select([c.fileno()], [], [], 0)[0]:
                    try:
                        c.recv()
                        drained = True
                    except (QueryError, WorkerError):
                        drained = True  # error frame fully consumed
                    except WorkerUnavailable:
                        drained = False
            except (WorkerUnavailable, OSError):
                drained = False
            m.release(c, broken=not drained)
            m._breaker_abort()
            # a loser's span is marked cancelled whether its reply was
            # drained or dropped — only the winner's worker spans are
            # adopted into the trace
            if id(m) not in session.attempt_spans \
                    and session.span is not None \
                    and session.span.recording:
                session.attempt_spans[id(m)] = session.span.child(
                    "attempt", attrs={"member": self.members.index(m)})
            session.finish_attempt(m, "cancelled", drained=drained)
            if not drained:
                with self._lock:
                    self.hedge_cancelled += 1
        session.attempts = []

    def op_finish(self, session: OpSession) -> Dict:
        """Drain the first usable reply, firing the hedge when the
        adaptive delay expires and failing over when attempts die.  A
        non-primary reply is only accepted at the synced version — a
        replica that somehow lags answers are discarded (counted in
        ``stale_replies``), never served."""
        hedge_at: Optional[float] = None
        if self.hedge_enabled and session.backups:
            hedge_at = session.started + self._hedge_delay()
        op_timeout = max((c.op_timeout_s for _m, c in session.attempts),
                         default=60.0)
        deadline = session.started + op_timeout
        while True:
            if not session.attempts:
                if not self._fire_next(session, hedge=False):
                    raise WorkerUnavailable(
                        f"shard {self.index}: every replica-set member "
                        f"failed mid-{session.op}")
                continue
            now = time.monotonic()
            if now > deadline:
                self.op_abort(session)
                raise DeadlineExceeded(
                    f"shard {self.index}: {session.op} timed out across "
                    "replica-set members")
            timeout = deadline - now
            if hedge_at is not None:
                timeout = min(timeout, max(0.0, hedge_at - now))
            ready = self._wait_readable(session, timeout)
            if ready is None:
                if hedge_at is not None and time.monotonic() >= hedge_at:
                    hedge_at = None  # at most one hedge per op
                    self._fire_next(session, hedge=True)
                continue
            m, c = ready
            try:
                reply = c.recv()
            except FrameChecksumError:
                with m._lock:
                    m.checksum_errors += 1
                m.release(c, broken=True)
                m._breaker_fail()
                session.attempts.remove((m, c))
                session.finish_attempt(m, "error", checksum_error=True)
                continue
            except (WorkerUnavailable, RemoteProtocolError):
                m.release(c, broken=True)
                m._breaker_fail()
                session.attempts.remove((m, c))
                session.finish_attempt(m, "error")
                continue
            except (QueryError, WorkerError):
                # a definitive error reply: the query itself is bad on
                # every member — cancel the others and propagate
                m.release(c)
                m._breaker_ok()
                session.attempts.remove((m, c))
                session.finish_attempt(m, "error")
                self._cancel_losers(session)
                raise
            if (m is not self.primary and "version" in reply
                    and self._synced_version is not None
                    and tuple(reply["version"]) != self._synced_version):
                with self._lock:
                    self.stale_replies += 1
                m.release(c)
                m._breaker_ok()  # healthy reply, just behind on version
                session.attempts.remove((m, c))
                session.finish_attempt(m, "cancelled", stale=True)
                continue
            session.attempts.remove((m, c))
            m._adopt_spans(reply)
            session.finish_attempt(m)
            session.winner = m
            elapsed = time.monotonic() - session.started
            self._note_latency(m, elapsed)
            for loser, _lc in session.attempts:
                # the loser took at least this long — teach the
                # preference order about slow members even though they
                # never produce a measured reply
                self._note_latency(loser, elapsed)
            self._cancel_losers(session)
            m.release(c)
            m._breaker_ok()
            if session.hedged and m is not session.first:
                with self._lock:
                    self.hedge_wins += 1
            return reply

    def op_abort(self, session: OpSession) -> None:
        for m, c in session.attempts:
            m.release(c, broken=True)
            m._breaker_abort()
            session.finish_attempt(m, "cancelled")
        session.attempts = []

    # ---------------------------------------------------- failover reads --
    def rpc(self, op: str, **kw) -> Dict:
        """Round-trip with read failover: read ops walk the eligible
        members; anything else goes to the primary only."""
        if op not in self._READ_OPS:
            return self.primary.rpc(op, **kw)
        session = self.op_begin(op, **kw)
        return self.op_finish(session)

    def _read(self, name: str, *args, **kw):
        """Call a store-surface method with member failover.  Members
        are built with degraded execution disabled, so a dead worker
        raises :class:`WorkerUnavailable` here instead of silently
        opening its directory; only when every eligible member is dead
        does the *set* degrade — to the primary's directory, whose WAL
        is at least as fresh as any replica."""
        order = self._read_order()
        for k, m in enumerate(order):
            try:
                attr = getattr(m, name)
                out = attr(*args, **kw) if callable(attr) else attr
            except WorkerUnavailable:
                continue
            if k:
                with self._lock:
                    self.failovers += 1
            return out
        return self._degraded_read(name, args, kw)

    def _degraded(self) -> ColumnarMetricStore:
        if not self.degraded_ok:
            raise WorkerUnavailable(
                f"shard {self.index}: no replica-set member reachable "
                "and degraded execution is disabled")
        with self._lock:
            self.degraded_calls += 1
        return self.primary.local_store()

    def _degraded_read(self, name: str, args, kw):
        store = self._degraded()
        if name == "__len__":
            return len(store)
        if name == "duplicates_dropped":
            return store.duplicates_dropped
        if name == "_version":
            return store._version()
        if name == "records":
            return store.records
        if name == "select":
            return list(store.select(*args, **kw))
        if name == "scan":
            return store.scan(*args, **kw)
        if name in ("jobs", "kinds"):
            return getattr(store, name)()
        if name == "hosts":
            return store.hosts(*args, **kw)
        if name == "storage_stats":
            return store.storage_stats()
        if name == "partial_cache":
            pc = store.partial_cache
            return _CacheStatsSnapshot(pc.hits, pc.misses, pc.evictions,
                                       len(pc))
        raise WorkerUnavailable(
            f"shard {self.index}: no degraded mapping for {name!r}")

    # ------------------------------------------------------ store surface --
    def _mark_stale(self) -> None:
        with self._lock:
            self.stale = True

    def insert(self, rec: MetricRecord) -> bool:
        accepted = self.primary.insert(rec)
        if accepted:
            self._mark_stale()
        return accepted

    def ingest_lines(self, lines: Iterable[str]) -> int:
        n = self.primary.ingest_lines(lines)
        if n:
            self._mark_stale()
        return n

    def seal(self) -> None:
        self.primary.seal()
        self._mark_stale()

    def __len__(self) -> int:
        return int(self._read("__len__"))

    @property
    def duplicates_dropped(self) -> int:
        return int(self._read("duplicates_dropped"))

    def _version(self) -> tuple:
        return tuple(self._read("_version"))

    @property
    def records(self) -> List[MetricRecord]:
        return self._read("records")

    def select(self, job=None, kind=None, since=None, until=None):
        # materialized so the failover decision happens here, not at
        # first iteration of a lazily-raising generator
        rows = self._read("select", job=job, kind=kind,
                          since=since, until=until)
        return iter(list(rows))

    def scan(self, job=None, kind=None, since=None, until=None,
             fields: Iterable[str] = ()) -> ColumnScan:
        return self._read("scan", job=job, kind=kind, since=since,
                          until=until, fields=tuple(fields))

    def jobs(self) -> List[str]:
        return self._read("jobs")

    def kinds(self) -> List[str]:
        return self._read("kinds")

    def hosts(self, job=None) -> List[str]:
        return self._read("hosts", job=job)

    @property
    def partial_cache(self) -> _CacheStatsSnapshot:
        return self._read("partial_cache")

    def storage_stats(self) -> Dict:
        return self._read("storage_stats")

    def local_store(self) -> ColumnarMetricStore:
        return self.primary.local_store()

    # -------------------------------------------------- maintenance tier --
    def compact(self, **kwargs) -> Dict:
        """Compaction rewrites the primary's committed history, so the
        set goes stale (the next :meth:`sync` detects the divergence
        and fully re-adopts each replica)."""
        stats = self.primary.compact(**kwargs)
        self._mark_stale()
        self.drop_scatter_memo()
        return stats

    def apply_retention(self, **kwargs) -> Dict:
        stats = self.primary.apply_retention(**kwargs)
        self._mark_stale()
        self.drop_scatter_memo()
        return stats

    # ---------------------------------------------------------- catch-up --
    def mark_member_unsynced(self, r: int) -> None:
        """A replica member was restarted/replaced: keep it out of the
        read set until the next sync verifies its version."""
        if r:
            with self._lock:
                self._synced[r] = False

    def sync(self) -> Dict[str, Any]:
        """Bring every reachable replica to the primary's exact
        version: diff committed histories via ``sync_state``, ship
        missing segments whole (``fetch_segment`` → ``adopt_replica``,
        one segment per frame so frames stay bounded), then ship the
        WAL tail + mutation generation.  A replica whose history is not
        a prefix of the primary's (compaction/retention rewrote the
        past, or a foreign directory) is reset and re-adopts
        everything.  Returns sync stats; clears ``stale`` on success so
        hedged/failover reads open up again."""
        try:
            pstate = self.primary.rpc("sync_state")
        except (WorkerUnavailable, WorkerError):
            # no source of truth to converge to — leave flags untouched
            # (replicas keep serving at the last synced version)
            return {"replicas": len(self.members) - 1, "synced": 0,
                    "segments_shipped": 0, "resets": 0,
                    "unreachable": 0, "primary_unreachable": True}
        pversion = tuple(pstate["version"])
        psealed = [(str(e["stem"]), str(e["uid"]))
                   for e in pstate["sealed"]]
        prollups = [(str(e["stem"]), str(e["uid"]))
                    for e in pstate["rollups"]]
        stats = {"replicas": len(self.members) - 1, "synced": 0,
                 "segments_shipped": 0, "resets": 0, "unreachable": 0}
        fetched: Dict[str, Dict] = {}
        synced = [True] + [False] * (len(self.members) - 1)
        for r, m in enumerate(self.members[1:], start=1):
            try:
                rstate = m.rpc("sync_state")
                rsealed = [str(e["uid"]) for e in rstate["sealed"]]
                rrollups = [str(e["uid"]) for e in rstate["rollups"]]
                p_uids = [u for _s, u in psealed]
                pr_uids = [u for _s, u in prollups]
                reset = not (rsealed == p_uids[:len(rsealed)]
                             and rrollups == pr_uids[:len(rrollups)])
                if reset:
                    stats["resets"] += 1
                    m.mutate("adopt_replica", reset=True)
                    todo = psealed + prollups
                else:
                    todo = (psealed[len(rsealed):]
                            + prollups[len(rrollups):])
                for stem, _uid in todo:
                    payload = fetched.get(stem)
                    if payload is None:
                        got = self.primary.rpc("fetch_segment", stem=stem)
                        payload = {"manifest": got["manifest"],
                                   "bin": got["bin"]}
                        fetched[stem] = payload
                    m.mutate("adopt_replica", segments=[payload])
                    stats["segments_shipped"] += 1
                reply = m.mutate("adopt_replica",
                              buffer_lines=pstate["buffer_lines"],
                              seq=pstate["seq"])
                if tuple(reply["version"]) == pversion:
                    synced[r] = True
                    stats["synced"] += 1
            except (WorkerUnavailable, WorkerError):
                stats["unreachable"] += 1
        with self._lock:
            self._synced = synced
            self._synced_version = pversion
            self.stale = False
            self.syncs += 1
        return stats

    def replication_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"members": len(self.members),
                    "synced_members": sum(1 for ok in self._synced if ok),
                    "stale": self.stale, "syncs": self.syncs,
                    "hedged_ops": self.hedged_ops,
                    "hedge_wins": self.hedge_wins,
                    "hedge_cancelled": self.hedge_cancelled,
                    "failovers": self.failovers,
                    "stale_replies": self.stale_replies,
                    "degraded_calls": self.degraded_calls}


def _trace_overlaps(trace: List[Tuple[str, int]]) -> bool:
    """True when every shard request was issued before the first reply
    was consumed — the scatter-overlaps-with-transport invariant."""
    sends = [j for j, (kind, _i) in enumerate(trace) if kind == "send"]
    recvs = [j for j, (kind, _i) in enumerate(trace) if kind == "recv"]
    return bool(sends) and (not recvs or max(sends) < min(recvs))


class RemoteShardedAggregator(ShardedAggregator):
    """:class:`ShardedAggregator` whose shards live in worker processes.

    Presents the exact same store surface (dashboards, detectors,
    ``QueryHandle``, ``Aggregator.watch`` run unchanged); routing,
    manifest pinning, and the merged read paths are inherited — only
    shard *execution* moves across the wire:

    * mergeable pipelines serialize their :class:`ScatterPlan` once,
      issue it to **every** live worker before reading any reply
      (transport overlaps with worker compute; ``last_query_stats
      ["overlap"]`` proves it), then merge per-worker partial maps in
      shard order — deterministic, so results are byte-identical to
      in-process execution;
    * each worker consults its own segment-keyed partial-aggregate
      cache (docs/incremental.md), keeping the warm-path speedup;
    * anything non-mergeable gathers exact rows from every worker and
      finishes locally;
    * a dead worker's shard degrades to local read-only execution of
      its durable directory, counted in ``last_query_stats`` and
      :meth:`explain`; :meth:`restart_worker` respawns it (the fresh
      process re-adopts the directory via segment manifests + WAL).

    ``directory`` is required — worker processes serve durable shard
    dirs.  With ``spawn=True`` (default) the aggregator owns a local
    fleet of :class:`LocalWorkerProcess`; pass ``addresses=[(host,
    port), ...]`` to attach to externally managed workers
    (``repro-shard-worker`` console entry point) instead.
    """

    is_remote = True

    def __init__(self, num_shards: int = 4, policy="hash",
                 time_window_s: float = 3600.0,
                 seal_threshold: int = 4096,
                 dedup_horizon_s: Optional[float] = None,
                 directory: Optional[os.PathLike] = None,
                 wal_fsync: bool = False,
                 partial_cache_entries: int = 512,
                 addresses: Optional[Sequence[Tuple[str, int]]] = None,
                 spawn: Optional[bool] = None,
                 op_timeout_s: float = 60.0,
                 spawn_timeout_s: float = 30.0,
                 worker_idle_timeout_s: Optional[float] = None,
                 degraded_ok: bool = True,
                 replicas: int = 1,
                 hedge: bool = True,
                 hedge_delay_s: Optional[float] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 frame_checksums: bool = True,
                 retry: Any = "default",
                 breaker_threshold: int = 5,
                 breaker_reset_s: float = 1.0,
                 telemetry: Optional[Telemetry] = None) -> None:
        if directory is None:
            raise ValueError("RemoteShardedAggregator requires a directory "
                             "(workers serve durable shard dirs)")
        if addresses is not None and spawn:
            raise ValueError("pass addresses= or spawn=True, not both")
        if addresses is None and spawn is not None and not spawn:
            raise ValueError("spawn=False requires addresses= "
                             "(externally managed workers)")
        if addresses is not None and len(addresses) != num_shards:
            raise ValueError(f"{len(addresses)} addresses for "
                             f"{num_shards} shards")
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if replicas > 1 and addresses is not None:
            raise ValueError("replicas > 1 requires a spawned fleet "
                             "(replica directory layout is coordinator-"
                             "owned); attach external workers unreplicated")
        self._replicas = int(replicas)
        self._hedge = bool(hedge)
        self._hedge_delay_s = hedge_delay_s
        # robustness config (docs/faults.md): ``fault_plan`` injects
        # wire faults into every client this coordinator opens;
        # ``frame_checksums`` adds crc32c trailers to outbound frames;
        # ``retry="default"`` builds one shared RetryPolicy (pass None
        # to disable, or a RetryPolicy to tune); each worker gets its
        # own CircuitBreaker unless ``breaker_threshold`` is 0.
        self.fault_plan = fault_plan
        self.frame_checksums = bool(frame_checksums)
        self._retry: Optional[RetryPolicy] = (
            RetryPolicy() if retry == "default" else retry)
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_reset_s = float(breaker_reset_s)
        self._addresses = addresses
        self._spawn = bool(spawn) if spawn is not None else addresses is None
        self._op_timeout_s = float(op_timeout_s)
        self._spawn_timeout_s = float(spawn_timeout_s)
        self._worker_idle_timeout_s = worker_idle_timeout_s
        self.degraded_ok = bool(degraded_ok)
        self.remote_queries = 0
        self.degraded_queries = 0
        self.last_io_trace: List[Tuple[str, int]] = []
        super().__init__(num_shards=num_shards, policy=policy,
                         time_window_s=time_window_s,
                         seal_threshold=seal_threshold,
                         dedup_horizon_s=dedup_horizon_s,
                         directory=directory, wal_fsync=wal_fsync,
                         parallel=False,
                         partial_cache_entries=partial_cache_entries,
                         telemetry=telemetry)
        self.telemetry.registry.register_collector(
            "remote", self._remote_telemetry_samples)
        if self._spawn:
            self._record_topology()

    # ------------------------------------------------------ fleet wiring --
    def _worker_spawn_kwargs(self) -> Dict[str, Any]:
        kw = dict(self._store_kwargs)
        kw.pop("wal_fsync", None)
        return dict(seal_threshold=kw.get("seal_threshold", 4096),
                    dedup_horizon_s=kw.get("dedup_horizon_s"),
                    wal_fsync=self._store_kwargs.get("wal_fsync", False),
                    partial_cache_entries=kw.get("partial_cache_entries",
                                                 512),
                    idle_timeout_s=self._worker_idle_timeout_s,
                    spawn_timeout_s=self._spawn_timeout_s)

    def _robustness_kwargs(self) -> Dict[str, Any]:
        """Per-shard robustness wiring: the retry policy is shared
        (stateless config), the circuit breaker is per worker."""
        return dict(retry=self._retry,
                    breaker=(CircuitBreaker(
                        failure_threshold=self._breaker_threshold,
                        reset_timeout_s=self._breaker_reset_s)
                        if self._breaker_threshold > 0 else None),
                    fault_plan=self.fault_plan,
                    checksums=self.frame_checksums)

    def _replica_dirname(self, i: int, r: int) -> str:
        """Replica ``r > 0`` of shard ``i`` lives beside the primary
        directory (``shard-02.r1``) — same shard set, never listed in
        the manifest's routing ``shard_dirs``."""
        return f"{self._shard_dirname(i)}.r{r}"

    def _make_shards(self, num_shards: int, **store_kwargs):
        self._store_kwargs = dict(store_kwargs)
        if self._replicas > 1:
            return self._make_replica_sets(num_shards, store_kwargs)
        shards: List[RemoteShard] = []
        try:
            for i in range(num_shards):
                shard_dir = self.directory / self._shard_dirname(i)
                process = None
                address = None
                if self._spawn:
                    process = LocalWorkerProcess(shard_dir,
                                                 **self._worker_spawn_kwargs())
                else:
                    address = tuple(self._addresses[i])
                shard = RemoteShard(i, shard_dir, address=address,
                                    process=process,
                                    op_timeout_s=self._op_timeout_s,
                                    store_kwargs=store_kwargs,
                                    degraded_ok=self.degraded_ok,
                                    telemetry=self.telemetry,
                                    **self._robustness_kwargs())
                shards.append(shard)
                shard.connect()
        except Exception:
            for shard in shards:
                try:
                    shard.close()
                except Exception:  # noqa: BLE001 - teardown best-effort
                    pass
            raise
        return shards

    def _make_replica_sets(self, num_shards: int,
                           store_kwargs: Dict[str, Any]):
        """Spawn ``replicas`` workers per shard and wrap each group in
        a :class:`ReplicaSet`.  Members get ``degraded_ok=False`` so a
        dead member surfaces as :class:`WorkerUnavailable` for the set
        to fail over — only the *set* may degrade, and only when every
        member is gone."""
        shards: List[ReplicaSet] = []
        try:
            for i in range(num_shards):
                members: List[RemoteShard] = []
                try:
                    for r in range(self._replicas):
                        name = (self._shard_dirname(i) if r == 0
                                else self._replica_dirname(i, r))
                        process = LocalWorkerProcess(
                            self.directory / name,
                            **self._worker_spawn_kwargs())
                        members.append(RemoteShard(
                            i, self.directory / name, process=process,
                            op_timeout_s=self._op_timeout_s,
                            store_kwargs=store_kwargs,
                            degraded_ok=False,
                            telemetry=self.telemetry,
                            **self._robustness_kwargs()))
                except Exception:
                    for m in members:
                        try:
                            m.close()
                        except Exception:  # noqa: BLE001
                            pass
                    raise
                rset = ReplicaSet(i, members, hedge=self._hedge,
                                  hedge_delay_s=self._hedge_delay_s,
                                  degraded_ok=self.degraded_ok)
                shards.append(rset)
                rset.connect()
        except Exception:
            for sh in shards:
                try:
                    sh.close()
                except Exception:  # noqa: BLE001 - teardown best-effort
                    pass
            raise
        return shards

    def _record_topology(self) -> None:
        """Record the live worker topology in ``shards.json`` (purely
        informational — operators can see which processes last served
        the fleet)."""
        from repro.core import segmentio
        workers = []
        for sh in self.shards:
            members = (sh.members if getattr(sh, "is_replicated", False)
                       else [sh])
            for r, m in enumerate(members):
                workers.append({
                    "shard": sh.index,
                    "replica": r,
                    "dir": m.shard_dir.name,
                    "host": m.client.address[0],
                    "port": m.client.address[1],
                    "pid": (m.process.proc.pid
                            if m.process is not None else None),
                })
        try:
            segmentio.update_shardset_manifest(self.directory,
                                               {"workers": workers})
            if self._replicas > 1:
                # epoch-stamped membership: every (re)spawned topology
                # bumps the replication epoch, so two coordinators can
                # tell which member list is the current generation
                segmentio.stamp_replication(self.directory,
                                            self._replicas, workers)
        except (OSError, ValueError):
            pass  # topology notes must never fail a query path

    def _member_target(self, i: int, member: int):
        sh = self.shards[i]
        if getattr(sh, "is_replicated", False):
            return sh, sh.members[member]
        if member:
            raise ValueError(f"shard {i} is not replicated "
                             f"(member={member})")
        return sh, sh

    def restart_worker(self, i: int, member: int = 0) -> None:
        """Respawn shard ``i``'s worker process (replica ``member`` on
        a replicated fleet); the fresh process re-adopts the durable
        shard directory (segments mmap back in, the WAL tail replays,
        dedup keys reload).  A restarted *replica* stays out of the
        read set until the next :meth:`sync_replicas` verifies it
        matches the primary's version (catch-up)."""
        if not self._spawn:
            raise RuntimeError("only a spawned fleet can be restarted here; "
                               "restart external workers out-of-band and "
                               "call reconnect_worker()")
        sh, target = self._member_target(i, member)
        target.invalidate_connections()
        if target.process is not None:
            target.process.stop()
        target.process = LocalWorkerProcess(target.shard_dir,
                                            **self._worker_spawn_kwargs())
        target.client = target._make_client(target.process.address)
        target.connect()
        if getattr(sh, "is_replicated", False):
            sh.mark_member_unsynced(member)
        self._drop_memos()
        self._record_topology()

    def reconnect_worker(self, i: int) -> bool:
        """Try to re-establish shard ``i``'s connection (externally
        restarted worker).  Returns success."""
        return self.shards[i]._try_reconnect()

    def kill_worker(self, i: int, member: int = 0) -> None:
        """Hard-kill one worker of shard ``i`` (tests: failover and
        degraded mode).  Connection teardown goes through the same
        :meth:`RemoteShard.invalidate_connections` path as restart and
        close, so checked-out pooled connections created mid-flight are
        closed on release instead of leaking."""
        _sh, target = self._member_target(i, member)
        if target.process is not None:
            target.process.kill()
        target.invalidate_connections()

    def workers_alive(self) -> List[bool]:
        return [sh.ping() for sh in self.shards]

    def sync_replicas(self) -> List[Dict[str, Any]]:
        """Converge every replica to its primary's exact ``(sealed,
        buffer, seq)`` version (whole-segment adoption + WAL-tail
        shipping — see :meth:`ReplicaSet.sync`).  Returns per-shard
        sync stats; a no-op (empty stats) on an unreplicated fleet."""
        out: List[Dict[str, Any]] = []
        for sh in self.shards:
            if getattr(sh, "is_replicated", False):
                out.append(sh.sync())
            else:
                out.append({"replicas": 0, "synced": 0,
                            "segments_shipped": 0, "resets": 0,
                            "unreachable": 0})
        return out

    def replication_stats(self) -> Optional[Dict[str, Any]]:
        """Fleet-wide replication counters summed over the replica
        sets, or ``None`` on an unreplicated fleet."""
        sets = [sh for sh in self.shards
                if getattr(sh, "is_replicated", False)]
        if not sets:
            return None
        out: Dict[str, Any] = {
            "replica_sets": len(sets), "replicas": int(self._replicas),
            "members": 0, "synced_members": 0, "stale_sets": 0,
            "syncs": 0, "hedged_ops": 0, "hedge_wins": 0,
            "hedge_cancelled": 0, "failovers": 0, "stale_replies": 0,
            "degraded_calls": 0}
        for sh in sets:
            s = sh.replication_stats()
            out["stale_sets"] += int(s["stale"])
            for k in ("members", "synced_members", "syncs", "hedged_ops",
                      "hedge_wins", "hedge_cancelled", "failovers",
                      "stale_replies", "degraded_calls"):
                out[k] += int(s[k])
        return out

    def _all_members(self) -> List[RemoteShard]:
        members: List[RemoteShard] = []
        for sh in self.shards:
            members.extend(sh.members
                           if getattr(sh, "is_replicated", False)
                           else [sh])
        return members

    def robustness_stats(self) -> Dict[str, Any]:
        """Fleet-wide robustness counters (docs/faults.md): retry /
        checksum / deadline totals over every worker connection plus a
        rollup of the per-worker circuit-breaker states.  Surfaced by
        :meth:`explain` and ``QueryService.stats()``."""
        members = self._all_members()
        out: Dict[str, Any] = faults.sum_breaker_stats(
            m.breaker.snapshot() for m in members
            if m.breaker is not None)
        out["retries"] = sum(m.retries for m in members)
        out["checksum_errors"] = sum(m.checksum_errors for m in members)
        out["deadline_exceeded"] = sum(m.deadline_exceeded
                                       for m in members)
        out["frame_checksums"] = self.frame_checksums
        out["retry_enabled"] = self._retry is not None
        out["crc_impl"] = faults.CRC_IMPL
        return out

    def _remote_telemetry_samples(self) -> Dict[str, float]:
        """Registry collector (docs/observability.md): the same
        :meth:`robustness_stats` / :meth:`replication_stats` rollups
        that back :meth:`explain`, under dotted metric names — one
        source, two views."""
        with self._lock:
            out: Dict[str, float] = {
                "remote.queries": float(self.remote_queries),
                "remote.degraded_queries": float(self.degraded_queries),
            }
        rob = self.robustness_stats()
        for k in ("retries", "checksum_errors", "deadline_exceeded"):
            out[f"remote.{k}"] = float(rob.get(k, 0))
        out.update(faults.breaker_telemetry_samples(
            m.breaker.snapshot() for m in self._all_members()
            if m.breaker is not None))
        rep = self.replication_stats()
        if rep is not None:
            for k, v in rep.items():
                if isinstance(v, (int, float)):
                    out[f"replication.{k}"] = float(v)
        return out

    def drop_scatter_memos(self) -> None:
        """Forget every coordinator-side decoded partial map (so the
        next scatter is unconditionally recomputed — benchmarks use
        this to measure a true cold path)."""
        for sh in self.shards:
            sh.drop_scatter_memo()

    # ------------------------------------------------------------- ingest --
    def ingest_lines(self, lines: Iterable[str]) -> int:
        """Bulk ingest: lines are routed locally, then shipped as one
        batched ``lines`` frame per shard instead of one round trip per
        record (each worker parses, dedups, and WALs exactly as it
        would for individual inserts)."""
        self._check_open()
        by_shard: Dict[int, List[str]] = {}
        for line in lines:
            rec = parse_line(line)
            if rec is not None:
                by_shard.setdefault(self.shard_index(rec), []).append(line)
        total = 0
        for i, batch in sorted(by_shard.items()):
            total += self.shards[i].ingest_lines(batch)
        if total:
            self._drop_memos()
        return total

    def adopt_store_dir(self, src_directory: os.PathLike) -> int:
        """Not supported over the wire: whole-segment adoption writes
        files into shard directories that live worker processes own
        (they would never see the new segments).  Migrate with an
        in-process :class:`ShardedAggregator` over the same directory
        first, then open the shard set remotely — the workers adopt
        everything on startup."""
        raise RuntimeError(
            "adopt_store_dir is not supported on a remote fleet; run the "
            "migration with an in-process ShardedAggregator on this "
            "directory, then reopen it with RemoteShardedAggregator")

    # -------------------------------------------------------------- query --
    def _release_unread(self, sessions: List[Optional[OpSession]]
                        ) -> None:
        """A reply-merge loop that fails mid-way leaves later issued
        requests' replies buffered on their sockets — consuming one as
        the answer to a *future* request would silently serve stale
        results forever.  Abort those sessions (their connections are
        dropped); fresh ones are opened transparently on the next
        checkout."""
        for k, s in enumerate(sessions):
            if s is not None:
                self.shards[k].op_abort(s)
                if s.span is not None:
                    s.span.finish("cancelled")
                sessions[k] = None

    def query_with_stats(self, q: str, engine: Optional[str] = None,
                         tolerance: Optional[float] = None
                         ) -> Tuple[List[Dict], Dict]:
        """Distributed splunklite execution (see class docstring),
        returning ``(rows, stats)`` — the re-entrant contract.  Each
        call checks its own connections out of the per-shard pools and
        carries its own stats/trace, so concurrent callers neither
        interleave reply frames nor cross-contaminate stats.
        ``engine="rows"`` gathers every record and runs the legacy row
        executor locally (the parity oracle), as in-process.
        ``tolerance`` rides inside the serialized plan, so each worker
        makes the same rollup-tier eligibility decision the coordinator
        would make in-process (docs/storage.md).

        ``last_query_stats``/``last_io_trace`` stay best-effort
        aliases — **thread-unsafe debugging aids**: a concurrent query
        overwrites them, so read the ``(rows, stats)`` return value, or
        the query's root span in the tracer ring
        (``telemetry.tracer.last_trace()``), which carries the same
        stats and the io trace as attributes."""
        self._check_open()
        if engine == "rows":
            return super().query_with_stats(q, engine="rows")
        tracer = self.telemetry.tracer
        root = tracer.start_span("query", parent=tracer.current(),
                                 attrs={"q": q, "remote": True})
        with root:
            rows, stats, io_trace = self._query_remote_traced(
                root, q, tolerance)
            root.set(io_trace=[list(ev) for ev in io_trace],
                     **{k: v for k, v in stats.items()
                        if isinstance(v, (int, float, str, bool))})
        return rows, stats

    def _query_remote_traced(self, root, q: str,
                             tolerance: Optional[float]
                             ) -> Tuple[List[Dict], Dict,
                                        List[Tuple[str, int]]]:
        with root.child("plan.compile"):
            stages = splunklite._split_pipeline(q)
            plan = splunklite.compile_scatter_plan(stages,
                                                   tolerance=tolerance)
        trace: List[Tuple[str, int]] = []
        if plan is not None:
            rows, stats = self._scatter_remote(plan, trace, parent=root)
            if rows is not None:
                self.last_io_trace = trace
                self.last_query_stats = stats
                return rows, stats, trace
        with self._lock:
            self.fallback_queries += 1
        # the gather gets its own trace: its overlap invariant must not
        # be judged against the aborted scatter's events
        gather_trace: List[Tuple[str, int]] = []
        rows, rest, stats = self._gather_remote(stages, gather_trace,
                                                parent=root)
        self.last_io_trace = trace + gather_trace
        self.last_query_stats = stats
        with root.child("finalize"):
            out = splunklite.run_stages(rows, rest)
        return out, stats, trace + gather_trace

    def _scatter_remote(self, plan: ScatterPlan,
                        trace: List[Tuple[str, int]],
                        parent=NULL_SPAN
                        ) -> Tuple[Optional[List[Dict]], Optional[Dict]]:
        """Two-level gather: issue the serialized plan to every live
        worker first, then merge per-worker partial maps **in shard
        order** as replies drain (deterministic merges, overlapped
        transport), finalize, and run the tail.  Dead workers compute
        locally in their slot while the remaining workers keep
        crunching.  Returns ``(None, None)`` when any shard's data
        defeats the partial kernels (the caller re-plans as an exact
        gather — identical to in-process semantics).

        The streaming refresh path: every scatter carries an etag
        ``[fingerprint, last seen worker version]`` when the
        coordinator already holds that worker's decoded partial map —
        an unchanged worker answers ``not_modified`` (no recompute, no
        reshipping, no re-decode), so a repeated dashboard/watch query
        pays per shard only for data that actually arrived.  The memo
        hit is captured *at send time*: a concurrent query may replace
        the memo entry before this query's reply drains, and a
        ``not_modified`` answer is relative to the etag that was sent,
        not to whatever the memo holds by the time it arrives."""
        state = plan.state()
        sessions: List[Optional[OpSession]] = [None] * self.num_shards
        hits: List[Optional[tuple]] = [None] * self.num_shards
        scatter = parent.child("scatter",
                               attrs={"shards": self.num_shards})
        for i, sh in enumerate(self.shards):
            hit = sh.scatter_memo_get(plan.fingerprint)
            hits[i] = hit
            sspan = scatter.child("shard.scatter", attrs={"shard": i})
            try:
                etag = ([plan.fingerprint, list(hit[0])]
                        if hit is not None else None)
                kw: Dict[str, Any] = {"plan": state, "etag": etag}
                if sspan.recording and getattr(sh, "trace_capable",
                                               False):
                    kw["trace"] = sspan.ctx()
                sessions[i] = sh.op_begin("scatter", **kw)
                sessions[i].span = sspan
                trace.append(("send", i))
            except WorkerUnavailable as exc:
                sspan.set(error=repr(exc),
                          circuit_open=isinstance(exc, CircuitOpen))
                sspan.finish("error")
        stats = {"mode": "scatter_gather", "remote": True,
                 "shards": self.num_shards, "fingerprint": plan.fingerprint,
                 "segments_cached": 0, "segments_computed": 0,
                 "buffer_rows": 0, "rollup_segments": 0,
                 "rollup_replaced": 0, "quarantined_segments": 0,
                 "degraded_shards": 0,
                 "shards_unchanged": 0, "hedged_shards": 0,
                 "failover_shards": 0}
        counter_keys = ("segments_cached", "segments_computed",
                        "buffer_rows", "rollup_segments",
                        "rollup_replaced", "quarantined_segments")
        merged: Dict[tuple, Dict[str, Any]] = {}
        fell_back = False
        try:
            for i, sh in enumerate(self.shards):
                pmap = None
                reply = None
                s = sessions[i]
                sspan = (s.span if s is not None
                         and s.span is not None else NULL_SPAN)
                if s is not None:
                    try:
                        reply = sh.op_finish(s)
                        trace.append(("recv", i))
                        stats["hedged_shards"] += int(s.hedged)
                        stats["failover_shards"] += int(s.failed_over)
                        if s.hedged:
                            sspan.set(hedged=True)
                        if s.failed_over:
                            sspan.set(failed_over=True)
                        sessions[i] = None
                    except WorkerUnavailable as exc:
                        sessions[i] = None
                        sspan.set(error=repr(exc))
                        sspan.finish("error")
                if reply is not None:
                    if reply.get("fallback"):
                        fell_back = True
                        sspan.set(fallback=True)
                    elif reply.get("not_modified"):
                        hit = hits[i]
                        if hit is None:
                            raise RemoteProtocolError(
                                f"worker {i} sent not_modified without "
                                "a coordinator-side cached map")
                        _v, pmap, summary = hit
                        stats["segments_cached"] += summary["segments"]
                        stats["buffer_rows"] += summary["buffer_rows"]
                        stats["rollup_segments"] += summary.get(
                            "rollup_segments", 0)
                        stats["rollup_replaced"] += summary.get(
                            "rollup_replaced", 0)
                        stats["shards_unchanged"] += 1
                        sspan.set(not_modified=True)
                    else:
                        wstats = reply.get("stats", {})
                        for k in counter_keys:
                            stats[k] += int(wstats.get(k, 0))
                        if wstats.get("cache_bypassed"):
                            stats["cache_bypassed"] = True
                        if not fell_back:
                            pmap = decode_partial_map(reply["groups"])
                            sh.scatter_memo_put(
                                plan.fingerprint,
                                reply.get("version", ()), pmap,
                                {"segments":
                                 int(wstats.get("segments_cached", 0)) +
                                 int(wstats.get("segments_computed", 0)),
                                 "buffer_rows":
                                 int(wstats.get("buffer_rows", 0)),
                                 "rollup_segments":
                                 int(wstats.get("rollup_segments", 0)),
                                 "rollup_replaced":
                                 int(wstats.get("rollup_replaced", 0))})
                    sspan.finish()
                else:
                    if not self.degraded_ok:
                        raise WorkerUnavailable(
                            f"shard {i} worker unavailable and degraded "
                            "execution is disabled")
                    trace.append(("local", i))
                    stats["degraded_shards"] += 1
                    with scatter.child("shard.degraded",
                                       attrs={"shard": i}):
                        store = sh._degraded()
                        local_stats: Dict[str, int] = {}
                        try:
                            pmap = splunklite.scatter_partials(
                                store, plan, cache=store.partial_cache,
                                stats=local_stats)
                        except _Fallback:
                            fell_back = True
                            pmap = None
                        for k in counter_keys:
                            stats[k] += int(local_stats.get(k, 0))
                if pmap is not None and not fell_back:
                    with scatter.child("merge", attrs={"shard": i}):
                        merged = (splunklite.merge_partial_maps(
                            [merged, pmap], plan.aggs)
                            if merged else pmap)
        except BaseException:
            self._release_unread(sessions)
            scatter.finish("error")
            raise
        stats["overlap"] = _trace_overlaps(trace)
        with self._lock:
            if stats["degraded_shards"]:
                self.degraded_queries += 1
            if not fell_back:
                self.scatter_queries += 1
                self.remote_queries += 1
        if fell_back:
            # the plan was defeated mid-flight; the caller re-plans as
            # an exact gather, so this phase ends cancelled, not failed
            scatter.set(fallback=True)
            scatter.finish("cancelled")
            return None, None
        scatter.finish()
        with parent.child("finalize"):
            rows = splunklite.finalize_partial_rows(merged, plan)
            out = splunklite.run_stages(rows, plan.tail)
        return out, stats

    def _gather_remote(self, stages: List[List[str]],
                       trace: List[Tuple[str, int]],
                       parent=NULL_SPAN):
        """Exact gather across workers: every worker filters + projects
        its rows (requests issued before any reply is read), the
        coordinator restores canonical (ts, shard, local) order.
        Returns ``(rows, rest_stages, stats)``."""
        wire_stages = [[str(t) for t in toks] for toks in stages]
        sessions: List[Optional[OpSession]] = [None] * self.num_shards
        gather = parent.child("gather",
                              attrs={"shards": self.num_shards})
        for i, sh in enumerate(self.shards):
            sspan = gather.child("shard.gather", attrs={"shard": i})
            try:
                kw: Dict[str, Any] = {"stages": wire_stages}
                if sspan.recording and getattr(sh, "trace_capable",
                                               False):
                    kw["trace"] = sspan.ctx()
                sessions[i] = sh.op_begin("gather", **kw)
                sessions[i].span = sspan
                trace.append(("send", i))
            except WorkerUnavailable as exc:
                sspan.set(error=repr(exc),
                          circuit_open=isinstance(exc, CircuitOpen))
                sspan.finish("error")
        _terms, rest = splunklite._leading_terms(stages)
        ts_parts: List[np.ndarray] = []
        row_parts: List[List[Dict]] = []
        degraded = hedged = failed_over = 0
        try:
            for i, sh in enumerate(self.shards):
                ts = rows = None
                s = sessions[i]
                sspan = (s.span if s is not None
                         and s.span is not None else NULL_SPAN)
                if s is not None:
                    try:
                        reply = sh.op_finish(s)
                        trace.append(("recv", i))
                        hedged += int(s.hedged)
                        failed_over += int(s.failed_over)
                        if s.hedged:
                            sspan.set(hedged=True)
                        if s.failed_over:
                            sspan.set(failed_over=True)
                        sessions[i] = None
                        ts = decode_array(reply["ts"])
                        rows = decode_rows(reply["rows"])
                        sspan.set(rows=len(rows))
                        sspan.finish()
                    except WorkerUnavailable as exc:
                        sessions[i] = None
                        sspan.set(error=repr(exc))
                        sspan.finish("error")
                if ts is None:
                    if not self.degraded_ok:
                        raise WorkerUnavailable(
                            f"shard {i} worker unavailable and degraded "
                            "execution is disabled")
                    trace.append(("local", i))
                    degraded += 1
                    with gather.child("shard.degraded",
                                      attrs={"shard": i}):
                        store = sh._degraded()
                        ts, rows, _rest = splunklite.gather_filtered(
                            store, stages)
                ts_parts.append(np.asarray(ts, np.float64))
                row_parts.append(rows)
        except BaseException:
            self._release_unread(sessions)
            gather.finish("error")
            raise
        gather.finish()
        with self._lock:
            self.remote_queries += 1
            if degraded:
                self.degraded_queries += 1
        stats = {
            "mode": "exact_gather", "remote": True,
            "shards": self.num_shards, "degraded_shards": degraded,
            "hedged_shards": hedged, "failover_shards": failed_over,
            "overlap": _trace_overlaps(trace)}
        all_rows = [r for part in row_parts for r in part]
        if not all_rows:
            return [], rest, stats
        order = np.argsort(np.concatenate(ts_parts), kind="stable")
        return [all_rows[i] for i in order.tolist()], rest, stats

    # ------------------------------------------------------------ explain --
    def explain(self, q: str) -> Dict[str, Any]:
        """Parent-shaped explain plus per-worker liveness, degraded-call
        counters, each worker's own cache state for the plan's
        fingerprint, and a fleet ``storage`` block (per-tier
        segment/file/byte totals plus last compaction stats) merged
        from the workers' accounting.  Pure introspection (at most two
        RPCs per live worker); a dead worker's storage is read from its
        shard directory when degraded execution is allowed, otherwise
        skipped."""
        stages = splunklite._split_pipeline(q)
        plan = splunklite.compile_scatter_plan(stages)
        workers = []
        sealed = cached = buffer_rows = 0
        hits = misses = entries = 0
        storage_parts: List[Dict[str, Any]] = []
        for sh in self.shards:
            info: Dict[str, Any] = {"shard": sh.index,
                                    "degraded_calls": sh.degraded_calls}
            mlist = (sh.members if getattr(sh, "is_replicated", False)
                     else [sh])
            info["retries"] = sum(m.retries for m in mlist)
            info["checksum_errors"] = sum(m.checksum_errors
                                          for m in mlist)
            breakers = [m.breaker.snapshot() for m in mlist
                        if m.breaker is not None]
            if breakers:
                info["breakers"] = breakers
            if getattr(sh, "is_replicated", False):
                info["replicas_alive"] = sh.members_alive()
            try:
                if plan is not None:
                    r = sh.rpc("explain", fingerprint=plan.fingerprint)
                    info.update(alive=True, sealed=r["sealed"],
                                cached=r["cached"],
                                buffer_rows=r["buffer_rows"])
                    sealed += r["sealed"]
                    cached += r["cached"]
                    buffer_rows += r["buffer_rows"]
                    st = r["cache"]
                    storage_parts.append(r["storage"])
                else:
                    st = sh.rpc("cache_stats")
                    info["alive"] = True
                    storage_parts.append(sh.rpc("storage")["storage"])
                hits += st["hits"]
                misses += st["misses"]
                entries += st["entries"]
            except WorkerUnavailable:
                info["alive"] = False
                try:
                    storage_parts.append(sh._degraded().storage_stats())
                except WorkerUnavailable:
                    pass
            workers.append(info)
        out: Dict[str, Any] = {
            "remote": True,
            "shards": self.num_shards,
            "workers": workers,
            "degraded_shards": sum(1 for w in workers if not w["alive"]),
            "cache": {"hits": hits, "misses": misses, "entries": entries},
            "storage": self._merge_storage_stats(storage_parts),
        }
        rep = self.replication_stats()
        if rep is not None:
            out["replication"] = rep
        out["robustness"] = self.robustness_stats()
        if plan is not None:
            out.update({
                "mode": "scatter_gather",
                "fingerprint": plan.fingerprint,
                "partial_aggs": [name for name, _f, _o in plan.aggs],
                "group_by": list(plan.by),
                "columns": (sorted(plan.cols)
                            if plan.cols is not None else None),
                "tail_stages": [t[0] for t in plan.tail],
                "segments": {"sealed": sealed, "cached": cached,
                             "buffer_rows": buffer_rows},
            })
            return out
        terms, rest = splunklite._leading_terms(stages)
        cols = splunklite.referenced_columns(rest)
        out.update({
            "mode": "exact_gather",
            "pushed_terms": len(terms),
            "columns": sorted(cols) if cols is not None else None,
            "stages": [t[0] for t in rest],
        })
        return out
