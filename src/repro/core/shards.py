"""Sharded multi-aggregator query fan-out (scatter/gather).

The paper's Splunk tier answers fleet-wide queries over rsyslog streams
from every compute node; at MPCDF scale that is a multi-indexer
scatter/gather problem, and PerSyst (arXiv:2009.06061) keeps fleet
analysis tractable with a tree of aggregation agents.  This module is
that tier's analog: a :class:`ShardedAggregator` owns N
:class:`~repro.core.columnar.ColumnarMetricStore` shards, routes
inserts to them by policy, and executes splunklite pipelines as a
scatter plan (per-shard predicate masks + partial aggregates) and a
gather plan (merge of the partial states).

Execution strategy per query (see ``repro.core.splunklite`` for the
partial/merge/finalize algebra and docs/sharding.md for the format):

* **scatter/gather** — pipelines of row-local stages ending in a
  ``stats``/``timechart`` whose aggregations are all *mergeable*
  compile to a :class:`~repro.core.splunklite.ScatterPlan`.  Each shard
  filters with vectorized predicate masks (zone-map pruning included),
  gathers only referenced columns, and reduces every group to a small
  partial state — **per sealed segment**, consulting the shard store's
  segment-keyed partial-aggregate cache so a repeated query recomputes
  only append buffers and newly sealed segments (docs/incremental.md);
  the gather step merges states (count/sum/min/max/Welford merges, set
  union for ``dc``, order-insensitive P² sketch merge for quantiles)
  and finalizes rows, then runs any tail stages locally.  No shard
  ships rows.
* **exact gather** — anything else (order-dependent ``first``/``last``,
  ``sort``/``dedup``/``head`` before aggregation, whole-row aggregates)
  falls back to gathering the predicate-filtered, column-projected rows
  from every shard, canonically ordering them by record timestamp
  (stable: ties keep shard order), and running the remaining pipeline
  locally.  Results are exact; they match a single store whenever
  timestamps are unique (the monitoring wire format's normal case) or
  the pipeline is order-insensitive.

Routing policies: ``"hash"`` (stable blake2 hash of the host — keeps a
host's stream on one shard), ``"time"`` (time windows round-robin
across shards), or any callable ``(record, num_shards) -> shard index``.
Duplicates route identically, so per-shard dedup equals global dedup.

Durable layout (``directory=``): ``shards.json`` manifest plus one
standard store directory per shard (``shard-00/``, ``shard-01/``, ...).
Every shard directory is a complete, self-describing store — it can be
opened standalone with ``ColumnarMetricStore(directory=...)``, shipped
to another aggregator, or adopted segment-by-segment via
:meth:`ShardedAggregator.adopt_store_dir`.
"""

from __future__ import annotations

import hashlib
import math
import os
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Tuple, Union)

import numpy as np

from repro.core.columnar import (SCAN_MEMO_MAX, ColumnarMetricStore,
                                 ColumnScan, _empty_scan, _lru_memo_get,
                                 _lru_memo_put)
from repro.core.schema import MetricRecord, parse_line
from repro.core.telemetry import Telemetry
from repro.core import splunklite
from repro.core.splunklite import _Fallback

Policy = Union[str, Callable[[MetricRecord, int], int]]


def _hash_route(host: str, num_shards: int) -> int:
    """Stable host hash (process-restart safe, unlike ``hash()``)."""
    digest = hashlib.blake2b(host.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(digest, "little") % num_shards


class ShardedAggregator:
    """N columnar store shards behind one store-compatible query surface.

    Implements the read surface dashboards, detectors, and splunklite
    rely on (``query`` via dispatch, ``scan``, ``records``, ``select``,
    ``jobs``/``kinds``/``hosts``, ``insert``/``ingest_lines``), so it is
    a drop-in for :class:`MetricStore` at the analysis layer.

    ``num_shards``/``policy`` — shard count and routing policy.
    ``directory`` — durable mode: a ``shards.json`` manifest plus one
    standard store directory per shard.  Reopening validates the
    manifest (shard count and named policy must match).
    Remaining kwargs are forwarded to every shard store.
    """

    is_sharded = True  # splunklite.query dispatch marker

    def __init__(self, num_shards: int = 4, policy: Policy = "hash",
                 time_window_s: float = 3600.0,
                 seal_threshold: int = 4096,
                 dedup_horizon_s: Optional[float] = None,
                 directory: Optional[os.PathLike] = None,
                 wal_fsync: bool = False,
                 parallel: Optional[bool] = None,
                 partial_cache_entries: int = 512,
                 telemetry: Optional[Telemetry] = None) -> None:
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        # thread-parallel shard execution pays off once there are spare
        # cores for the NumPy kernels; on small boxes the GIL makes the
        # sequential scan faster, so auto-enable only with headroom
        if parallel is None:
            parallel = (os.cpu_count() or 1) >= 2 * num_shards
        self.parallel = bool(parallel)
        self.policy = policy
        self.time_window_s = float(time_window_s)
        self.directory = Path(directory) if directory is not None else None
        policy_name = policy if isinstance(policy, str) else "custom"
        if policy_name not in ("hash", "time", "custom"):
            raise ValueError(f"unknown routing policy {policy!r}")
        if self.directory is not None:
            from repro.core import segmentio
            manifest = segmentio.load_shardset_manifest(self.directory)
            if manifest is not None:
                if int(manifest["num_shards"]) != int(num_shards):
                    raise ValueError(
                        f"shard set at {self.directory} has "
                        f"{manifest['num_shards']} shards, not {num_shards}")
                if manifest["policy"] != policy_name:
                    raise ValueError(
                        f"shard set at {self.directory} was created with "
                        f"policy {manifest['policy']!r}, not {policy_name!r}")
                stored_window = float(manifest.get("time_window_s",
                                                   self.time_window_s))
                if policy_name == "time" and \
                        stored_window != self.time_window_s:
                    # a different window re-routes existing records, so
                    # per-shard dedup would no longer equal global dedup
                    raise ValueError(
                        f"shard set at {self.directory} was created with "
                        f"time_window_s={stored_window}, "
                        f"not {self.time_window_s}")
            else:
                segmentio.save_shardset_manifest(self.directory, {
                    "num_shards": int(num_shards),
                    "policy": policy_name,
                    "time_window_s": self.time_window_s,
                    "shard_dirs": [self._shard_dirname(i)
                                   for i in range(num_shards)],
                })
        self._closed = False
        # unified telemetry (docs/observability.md): tracing defaults
        # off (NullSpan fast path); the registry is always live — its
        # collectors are pull-based, so registration costs nothing on
        # the query path
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry(tracing=False)
        self.shards: List[ColumnarMetricStore] = self._make_shards(
            num_shards, seal_threshold=seal_threshold,
            dedup_horizon_s=dedup_horizon_s, wal_fsync=wal_fsync,
            partial_cache_entries=partial_cache_entries)
        # query-path observability (tests assert the scatter plan runs)
        self.scatter_queries = 0
        self.fallback_queries = 0
        self.segments_adopted = 0
        self.records_reingested = 0
        # Thread-unsafe debugging aid: a best-effort alias for the last
        # query_with_stats() result.  Concurrent callers WILL observe
        # another query's stats here — use the stats returned alongside
        # the rows, or the telemetry tracer's trace ring
        # (``telemetry.tracer.last_trace()``), which records the same
        # data under a lock (docs/observability.md).
        self.last_query_stats: Optional[Dict] = None
        self._cache: Dict[str, tuple] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        # guards the version memos, counters, and lazy pool creation so
        # the aggregator is re-entrant under a concurrent QueryService
        self._lock = threading.RLock()
        self.telemetry.registry.register_collector(
            "shards", self._telemetry_samples)

    def _make_shards(self, num_shards: int,
                     **store_kwargs) -> List[ColumnarMetricStore]:
        """Build the N shard backends.  The remote tier
        (:class:`repro.core.remote.RemoteShardedAggregator`) overrides
        this to return worker-process proxies with the same surface."""
        shards: List[ColumnarMetricStore] = []
        for i in range(num_shards):
            shard_dir = (self.directory / self._shard_dirname(i)
                         if self.directory is not None else None)
            shards.append(ColumnarMetricStore(directory=shard_dir,
                                              **store_kwargs))
        return shards

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"{type(self).__name__} is closed; reopen the directory "
                "with a fresh aggregator instead of reusing this one")

    def _map_shards(self, fn):
        """Run ``fn`` once per shard — in parallel for multi-shard sets
        (shard stores and their partial caches are internally locked,
        so concurrent queries may touch the same shard from different
        workers; NumPy kernels release the GIL).  Results come back in
        shard order, keeping every gather deterministic."""
        if self.num_shards == 1 or not self.parallel:
            return [fn(shard) for shard in self.shards]
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=min(self.num_shards, 8),
                    thread_name_prefix="shard-query")
            pool = self._pool
        return list(pool.map(fn, self.shards))

    @staticmethod
    def _shard_dirname(i: int) -> str:
        return f"shard-{i:02d}"

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    # ------------------------------------------------------------ routing --
    def shard_index(self, rec: MetricRecord) -> int:
        if callable(self.policy):
            return int(self.policy(rec, self.num_shards)) % self.num_shards
        if self.policy == "hash":
            return _hash_route(rec.host, self.num_shards)
        window = int(math.floor(float(rec.ts) / self.time_window_s))
        return window % self.num_shards

    # ------------------------------------------------------------- ingest --
    def insert(self, rec: MetricRecord) -> bool:
        self._check_open()
        accepted = self.shards[self.shard_index(rec)].insert(rec)
        if accepted:
            # aggregator-level version memos (records/scans) are stale
            # the moment any shard's version moves; the shards' own
            # per-segment partial caches are untouched by design
            self._drop_memos()
        return accepted

    def _drop_memos(self) -> None:
        with self._lock:
            if self._cache:
                self._cache.clear()

    def ingest_lines(self, lines: Iterable[str]) -> int:
        n = 0
        for line in lines:
            rec = parse_line(line)
            if rec is not None and self.insert(rec):
                n += 1
        return n

    def seal(self) -> None:
        self._check_open()
        for shard in self.shards:
            shard.seal()
        self._drop_memos()

    def close(self) -> None:
        """Shut down the shard backends and the query thread pool.

        Idempotent — closing twice is a no-op.  Afterwards every
        ingest/query entry point raises ``RuntimeError`` instead of
        silently reviving resources (a ``query()`` after ``close()``
        used to recreate the thread pool against closed stores)."""
        if self._closed:
            return
        self._closed = True
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        for shard in self.shards:
            shard.close()

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    @property
    def duplicates_dropped(self) -> int:
        return sum(s.duplicates_dropped for s in self.shards)

    def shard_sizes(self) -> List[int]:
        return [len(s) for s in self.shards]

    def _version(self) -> tuple:
        return tuple(s._version() for s in self.shards)

    # ------------------------------------------------------- segment adopt --
    def adopt_store_dir(self, src_directory: os.PathLike) -> int:
        """Migrate an existing single-store directory into the shards.

        Sealed segments are shippable units: a segment whose rows all
        route to one shard is adopted file-by-file (no re-parse) via
        :meth:`ColumnarMetricStore.adopt_segment`; otherwise its rows
        are re-ingested through normal routing.  The source WAL's
        complete lines are replayed last.  The source directory is only
        read.  Returns the number of records brought in.
        """
        from repro.core import segmentio
        self._check_open()
        src = Path(src_directory)
        total = 0
        for man_path in sorted((src / "segments").glob("seg-*.json")):
            try:
                seg = segmentio.load_segment(man_path)
            except (OSError, ValueError, KeyError, TypeError):
                continue
            target = self._segment_route(seg)
            if target is not None:
                total += self.shards[target].adopt_segment(man_path)
                self.segments_adopted += 1
            else:
                from repro.core.columnar import _segment_records
                for rec in _segment_records(seg, np.arange(seg.n)):
                    if self.insert(rec):
                        total += 1
                        self.records_reingested += 1
        for line in segmentio.read_complete_wal_lines(src / "wal.log"):
            rec = parse_line(line)
            if rec is not None and self.insert(rec):
                total += 1
        self._drop_memos()
        return total

    def _segment_route(self, seg) -> Optional[int]:
        """Shard index if every row of the segment routes there, else
        ``None`` (the segment must be split by re-ingesting rows)."""
        if self.num_shards == 1:
            return 0
        if callable(self.policy):
            return None
        if self.policy == "time":
            w = self.time_window_s
            lo = int(math.floor(seg.ts_min / w))
            hi = int(math.floor(seg.ts_max / w))
            return lo % self.num_shards if lo == hi else None
        hosts = {_hash_route(h, self.num_shards)
                 for h in seg.attrs["host"].index}
        if len(hosts) == 1:
            return next(iter(hosts))
        return None

    # --------------------------------------------------- compaction tier --
    def compact_all(self, **kwargs) -> Dict:
        """Run segment compaction on every shard (see
        :meth:`ColumnarMetricStore.compact`).  Returns per-shard stats
        plus fleet totals, including every retired segment uid."""
        self._check_open()
        per_shard = [shard.compact(**kwargs) for shard in self.shards]
        return self._merge_maintenance_stats(per_shard)

    def apply_retention(self, **kwargs) -> Dict:
        """Apply retention/rollup tiers on every shard (see
        :meth:`ColumnarMetricStore.apply_retention`)."""
        self._check_open()
        per_shard = [shard.apply_retention(**kwargs) for shard in
                     self.shards]
        return self._merge_maintenance_stats(per_shard)

    @staticmethod
    def _merge_maintenance_stats(per_shard: List[Dict]) -> Dict:
        total: Dict[str, Any] = {}
        for st in per_shard:
            for k, v in st.items():
                if isinstance(v, (int, float)) and k != "duration_s":
                    total[k] = total.get(k, 0) + v
                elif isinstance(v, list):
                    total.setdefault(k, []).extend(v)
        total["shards"] = per_shard
        return total

    def storage_stats(self) -> Dict:
        """Fleet storage accounting: per-tier totals over every shard
        (see :meth:`ColumnarMetricStore.storage_stats`)."""
        per_shard = [shard.storage_stats() for shard in self.shards]
        return self._merge_storage_stats(per_shard)

    def replication_stats(self) -> Optional[Dict[str, Any]]:
        """Replication counters (hedges, failovers, syncs) for fleets
        that replicate shards; ``None`` here — an in-process shard set
        has exactly one copy of each shard.  The remote fleet overrides
        this (docs/replication.md), and :meth:`QueryService.stats`
        surfaces whatever the store reports."""
        return None

    @staticmethod
    def _merge_storage_stats(per_shard: List[Dict]) -> Dict:
        total: Dict[str, Any] = {k: 0 for k in ("segments", "files",
                                                "rows", "bytes",
                                                "raw_bytes", "buffer_rows",
                                                "quarantined_segments")}
        tiers: Dict[str, Dict] = {}
        for st in per_shard:
            for k in ("segments", "files", "rows", "bytes", "raw_bytes",
                      "buffer_rows", "quarantined_segments"):
                total[k] += st.get(k, 0)
            for name, t in (st.get("tiers") or {}).items():
                agg = tiers.setdefault(name, {
                    "segments": 0, "files": 0, "rows": 0,
                    "bytes": 0, "raw_bytes": 0})
                for k in agg:
                    agg[k] += t.get(k, 0)
        total["tiers"] = tiers
        total["last_compaction"] = [st.get("last_compaction")
                                    for st in per_shard]
        return total

    # -------------------------------------------------------------- query --
    def query(self, q: str, engine: Optional[str] = None,
              tolerance: Optional[float] = None) -> List[Dict]:
        """Execute a splunklite pipeline across the shards.

        ``engine="rows"`` forces the legacy row executor over the
        canonically ordered gathered rows (the parity oracle);
        otherwise a mergeable pipeline runs scatter/gather — consulting
        each shard's segment-keyed partial-aggregate cache, so repeated
        fleet queries recompute only append buffers and newly sealed
        segments — and anything else takes the exact-gather path.
        ``tolerance`` opts the scatter plan into approximate
        rollup-tier answers (docs/storage.md).
        ``last_query_stats`` records the mode and, for scatter/gather,
        the fleet-wide cached/recomputed segment counts — as a
        *best-effort alias*; concurrent callers must use
        :meth:`query_with_stats`.
        """
        rows, _stats = self.query_with_stats(q, engine=engine,
                                             tolerance=tolerance)
        return rows

    def query_with_stats(self, q: str, engine: Optional[str] = None,
                         tolerance: Optional[float] = None
                         ) -> Tuple[List[Dict], Dict]:
        """:meth:`query` returning ``(rows, stats)`` with per-call
        stats — the re-entrant contract: nothing here is read back from
        shared attributes, so any number of threads can query one
        aggregator without cross-contaminating their stats.  The
        ``last_query_stats`` attribute is still *written* (best-effort,
        racy) for backwards compatibility — the same stats dict is
        also attached to the query's root span, so the tracer ring is
        the thread-safe way to read it after the fact."""
        self._check_open()
        tracer = self.telemetry.tracer
        root = tracer.start_span("query", parent=tracer.current(),
                                 attrs={"q": q})
        with root:
            rows, stats = self._query_traced(root, q, engine, tolerance)
            root.set(**{k: v for k, v in stats.items()
                        if isinstance(v, (int, float, str, bool))})
        return rows, stats

    def _query_traced(self, root, q: str, engine: Optional[str],
                      tolerance: Optional[float]
                      ) -> Tuple[List[Dict], Dict]:
        with root.child("plan.compile"):
            stages = splunklite._split_pipeline(q)
            plan = (None if engine == "rows" else
                    splunklite.compile_scatter_plan(stages,
                                                    tolerance=tolerance))
        if engine == "rows":
            stats = {"mode": "rows"}
            self.last_query_stats = stats
            rows = [r.as_dict() for r in self.records]
            if not stages:
                return rows, stats
            return splunklite.run_stages(rows, stages,
                                         implicit_first=True), stats
        if plan is not None:
            # one stats dict per shard *per call*: concurrent queries
            # each carry their own dicts, so the scatter fills them
            # without cross-thread sharing even when two queries touch
            # the same shard at once
            stats_by_shard = {id(s): {} for s in self.shards}
            try:
                with root.child("scatter",
                                attrs={"shards": self.num_shards}):
                    maps = self._map_shards(
                        lambda shard: splunklite.scatter_partials(
                            shard, plan, cache=shard.partial_cache,
                            stats=stats_by_shard[id(shard)]))
                with root.child("merge"):
                    merged = splunklite.merge_partial_maps(maps, plan.aggs)
                with root.child("finalize"):
                    rows = splunklite.finalize_partial_rows(merged, plan)
                    rows = splunklite.run_stages(rows, plan.tail)
                with self._lock:
                    self.scatter_queries += 1
                stats = {"mode": "scatter_gather",
                         "shards": self.num_shards,
                         "fingerprint": plan.fingerprint,
                         "segments_cached": 0, "segments_computed": 0,
                         "buffer_rows": 0}
                for st in stats_by_shard.values():
                    for k in ("segments_cached", "segments_computed",
                              "buffer_rows"):
                        stats[k] += st.get(k, 0)
                    for k in ("rollup_segments", "rollup_replaced"):
                        if st.get(k):
                            stats[k] = stats.get(k, 0) + st[k]
                    if st.get("cache_bypassed"):
                        stats["cache_bypassed"] = True
                self.last_query_stats = stats
                return rows, stats
            except _Fallback:
                pass  # shard data defeated a partial kernel: go exact
        with self._lock:
            self.fallback_queries += 1
        stats = {"mode": "exact_gather"}
        self.last_query_stats = stats
        with root.child("gather", attrs={"shards": self.num_shards}):
            rows, rest = self._gather_rows(stages)
        with root.child("finalize"):
            rows = splunklite.run_stages(rows, rest)
        return rows, stats

    @property
    def partial_cache_hits(self) -> int:
        return sum(s.partial_cache.hits for s in self.shards)

    @property
    def partial_cache_misses(self) -> int:
        return sum(s.partial_cache.misses for s in self.shards)

    def _telemetry_samples(self) -> Dict[str, float]:
        """Registry collector: fleet query counters, partial-cache
        totals, and storage vitals.  ``explain()`` reads its cache
        numbers through the same per-shard accessors, so the registry
        and the legacy dicts cannot diverge."""
        with self._lock:
            out = {"shards.count": self.num_shards,
                   "shards.scatter_queries": self.scatter_queries,
                   "shards.fallback_queries": self.fallback_queries,
                   "shards.segments_adopted": self.segments_adopted,
                   "shards.records_reingested": self.records_reingested}
        out["cache.partial.hits"] = self.partial_cache_hits
        out["cache.partial.misses"] = self.partial_cache_misses
        out["cache.partial.entries"] = sum(
            len(s.partial_cache) for s in self.shards)
        out["cache.partial.evictions"] = sum(
            getattr(s.partial_cache, "evictions", 0) for s in self.shards)
        try:
            storage = self.storage_stats()
        except Exception:
            storage = {}
        for k in ("segments", "rows", "bytes", "buffer_rows",
                  "quarantined_segments"):
            if k in storage:
                out["storage." + k] = storage[k]
        return out

    def explain(self, q: str) -> Dict[str, Any]:
        """Describe how a query would execute (for tests/operators),
        including the fleet-wide partial-cache state for the plan's
        fingerprint.  Pure introspection — runs nothing."""
        stages = splunklite._split_pipeline(q)
        plan = splunklite.compile_scatter_plan(stages)
        cache_info = {
            "hits": self.partial_cache_hits,
            "misses": self.partial_cache_misses,
            "entries": sum(len(s.partial_cache) for s in self.shards),
        }
        storage = self.storage_stats()
        if plan is not None:
            sealed = cached = 0
            for shard in self.shards:
                for _seg, uid in shard.segment_units(include_buffer=False):
                    sealed += 1
                    if shard.partial_cache.peek((uid, plan.fingerprint)):
                        cached += 1
            return {
                "mode": "scatter_gather",
                "shards": self.num_shards,
                "fingerprint": plan.fingerprint,
                "partial_aggs": [name for name, _f, _o in plan.aggs],
                "group_by": list(plan.by),
                "columns": (sorted(plan.cols)
                            if plan.cols is not None else None),
                "tail_stages": [t[0] for t in plan.tail],
                "segments": {"sealed": sealed, "cached": cached,
                             "buffer_rows": sum(len(s._buffer)
                                                for s in self.shards)},
                "cache": cache_info,
                "storage": storage,
            }
        terms, rest = splunklite._leading_terms(stages)
        cols = splunklite.referenced_columns(rest)
        return {
            "mode": "exact_gather",
            "shards": self.num_shards,
            "pushed_terms": len(terms),
            "columns": sorted(cols) if cols is not None else None,
            "stages": [t[0] for t in rest],
            "cache": cache_info,
            "storage": storage,
        }

    def _gather_rows(self, stages: List[List[str]]):
        """Exact gather: filtered + projected rows from every shard in
        canonical (ts, shard, local-position) order."""
        gathered = self._map_shards(
            lambda shard: splunklite.gather_filtered(shard, stages))
        ts_parts = [ts for ts, _rows, _rest in gathered]
        row_parts = [rows for _ts, rows, _rest in gathered]
        rest = gathered[-1][2]
        all_rows = [r for part in row_parts for r in part]
        if not all_rows:
            return [], rest
        ts_all = np.concatenate(ts_parts)
        order = np.argsort(ts_all, kind="stable")
        return [all_rows[i] for i in order.tolist()], rest

    # -------------------------------------------------------------- reads --
    @property
    def records(self) -> List[MetricRecord]:
        """All records in canonical (ts, shard, local) order."""
        with self._lock:
            v = self._version()
            cached = self._cache.get("records")
            if cached is None or cached[0] != v:
                recs: List[MetricRecord] = []
                ts: List[float] = []
                for shard in self.shards:
                    part = shard.records
                    recs.extend(part)
                    ts.extend(float(r.ts) for r in part)
                order = np.argsort(np.asarray(ts), kind="stable")
                cached = (v, [recs[i] for i in order.tolist()])
                self._cache["records"] = cached
            return cached[1]

    def select(self, job: Optional[str] = None, kind: Optional[str] = None,
               since: Optional[float] = None,
               until: Optional[float] = None) -> Iterator[MetricRecord]:
        recs: List[MetricRecord] = []
        ts: List[float] = []
        for shard in self.shards:
            for r in shard.select(job=job, kind=kind, since=since,
                                  until=until):
                recs.append(r)
                ts.append(float(r.ts))
        order = np.argsort(np.asarray(ts), kind="stable")
        for i in order.tolist():
            yield recs[i]

    def scan(self, job: Optional[str] = None, kind: Optional[str] = None,
             since: Optional[float] = None, until: Optional[float] = None,
             fields: Iterable[str] = ()) -> ColumnScan:
        """Merged vectorized scan across shards (memoized per version).

        Row order is shard-concatenation order; every dashboard/detector
        consumer orders by (ts, value) itself, so the merged scan is a
        drop-in for the single-store one.
        """
        self._check_open()
        fields = tuple(fields)
        memo_key = (job, kind, since, until, fields)
        with self._lock:
            memo = self._cache.get("scans")
            if memo is None or memo[0] != self._version():
                memo = (self._version(), {})
                self._cache["scans"] = memo
            sc = _lru_memo_get(memo[1], memo_key)
            if sc is None:
                sc = self._scan_uncached(job, kind, since, until, fields)
                _lru_memo_put(memo[1], memo_key, sc, SCAN_MEMO_MAX)
            return sc

    def _scan_uncached(self, job, kind, since, until,
                       fields: Tuple[str, ...]) -> ColumnScan:
        scans = [s.scan(job=job, kind=kind, since=since, until=until,
                        fields=fields) for s in self.shards]
        scans = [s for s in scans if s.n]
        if not scans:
            return _empty_scan(fields)
        n = sum(s.n for s in scans)
        ts = np.concatenate([s.ts for s in scans])
        host_index: Dict[str, int] = {}
        job_index: Dict[str, int] = {}
        host_codes = np.empty(n, np.int32)
        job_codes = np.empty(n, np.int32)
        fvals = {f: np.empty(n) for f in fields}
        fpres = {f: np.empty(n, bool) for f in fields}
        pos = 0
        for sc in scans:
            m = sc.n
            for codes_out, codes, vocab, index in (
                    (host_codes, sc.host_codes, sc.host_vocab, host_index),
                    (job_codes, sc.job_codes, sc.job_vocab, job_index)):
                remap = np.array([index.setdefault(v, len(index))
                                  for v in vocab.tolist()], np.int32) \
                    if len(vocab) else np.empty(0, np.int32)
                codes_out[pos:pos + m] = remap[codes]
            for f in fields:
                v, p = sc.field(f)
                fvals[f][pos:pos + m] = v
                fpres[f][pos:pos + m] = p
            pos += m
        return ColumnScan(
            n, ts, host_codes, np.array(list(host_index), dtype=object),
            job_codes, np.array(list(job_index), dtype=object),
            {f: (fvals[f], fpres[f]) for f in fields})

    # ------------------------------------------------------------- vocabs --
    def _vocab_union(self, method: str) -> List[str]:
        out: Dict[str, None] = {}
        for shard in self.shards:
            for v in getattr(shard, method)():
                out.setdefault(v)
        return sorted(out)

    def jobs(self) -> List[str]:
        return self._vocab_union("jobs")

    def kinds(self) -> List[str]:
        return self._vocab_union("kinds")

    def hosts(self, job: Optional[str] = None) -> List[str]:
        out: Dict[str, None] = {}
        for shard in self.shards:
            for v in shard.hosts(job):
                out.setdefault(v)
        return sorted(out)
