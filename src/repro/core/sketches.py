"""Streaming statistics with O(1) memory.

The paper aggregates large jobs into min/median/max curves inside Splunk;
PerSyst (cited in the paper's §3) showed quantile aggregation is what makes
many-node jobs comprehensible.  For 1000+-host fleets we cannot hold raw
samples, so we provide:

* :class:`StreamStats` — count/mean/std/min/max via Welford.
* :class:`P2Quantile` — the P² algorithm (Jain & Chlamtac 1985): a single
  quantile estimate from 5 markers, no stored samples.
* :class:`QuantileSet` — min/p25/median/p75/max in O(1) memory.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional


class StreamStats:
    """Welford online mean/variance plus min/max."""

    __slots__ = ("n", "mean", "_m2", "min", "max")

    def __init__(self) -> None:
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def extend(self, xs: Iterable[float]) -> "StreamStats":
        for x in xs:
            self.add(x)
        return self

    @property
    def var(self) -> float:
        return self._m2 / self.n if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self.var)

    def merge(self, other: "StreamStats") -> "StreamStats":
        """Parallel-merge (Chan et al.) — used by island relays."""
        if other.n == 0:
            return self
        if self.n == 0:
            self.n, self.mean, self._m2 = other.n, other.mean, other._m2
            self.min, self.max = other.min, other.max
            return self
        n = self.n + other.n
        d = other.mean - self.mean
        self._m2 += other._m2 + d * d * self.n * other.n / n
        self.mean += d * other.n / n
        self.n = n
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self


class P2Quantile:
    """P² single-quantile estimator (no stored samples).

    Error is typically <1% of the value range for unimodal streams, which
    is ample for dashboard median/p90 curves.
    """

    __slots__ = ("p", "_n", "_q", "_pos", "_npos", "_dn", "count")

    def __init__(self, p: float) -> None:
        if not 0.0 < p < 1.0:
            raise ValueError("p must be in (0,1)")
        self.p = p
        self._q: List[float] = []   # marker heights
        self._pos = [1, 2, 3, 4, 5]  # marker positions (1-based)
        self._npos = [1.0, 1 + 2 * p, 1 + 4 * p, 3 + 2 * p, 5.0]
        self._dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]
        self.count = 0

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if len(self._q) < 5:
            self._q.append(x)
            if len(self._q) == 5:
                self._q.sort()
            return
        q, pos = self._q, self._pos
        # locate cell
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1
        for i in range(5):
            self._npos[i] += self._dn[i]
        # adjust interior markers
        for i in (1, 2, 3):
            d = self._npos[i] - pos[i]
            if ((d >= 1 and pos[i + 1] - pos[i] > 1)
                    or (d <= -1 and pos[i - 1] - pos[i] < -1)):
                s = 1 if d >= 0 else -1
                qn = self._parabolic(i, s)
                if not (q[i - 1] < qn < q[i + 1]):
                    qn = self._linear(i, s)
                q[i] = qn
                pos[i] += s

    def _parabolic(self, i: int, s: int) -> float:
        q, n = self._q, self._pos
        return q[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, s: int) -> float:
        q, n = self._q, self._pos
        return q[i] + s * (q[i + s] - q[i]) / (n[i + s] - n[i])

    @property
    def value(self) -> float:
        if not self._q:
            return math.nan
        if len(self._q) < 5:
            srt = sorted(self._q)
            idx = self.p * (len(srt) - 1)
            lo = int(math.floor(idx))
            hi = min(lo + 1, len(srt) - 1)
            frac = idx - lo
            return srt[lo] * (1 - frac) + srt[hi] * frac
        return self._q[2]


class QuantileSet:
    """min / p25 / median / p75 / max in O(1) memory."""

    def __init__(self) -> None:
        self.stats = StreamStats()
        self._p25 = P2Quantile(0.25)
        self._p50 = P2Quantile(0.50)
        self._p75 = P2Quantile(0.75)

    def add(self, x: float) -> None:
        self.stats.add(x)
        self._p25.add(x)
        self._p50.add(x)
        self._p75.add(x)

    def summary(self) -> Dict[str, float]:
        s = self.stats
        return {
            "count": s.n,
            "min": s.min if s.n else math.nan,
            "p25": self._p25.value,
            "median": self._p50.value,
            "p75": self._p75.value,
            "max": s.max if s.n else math.nan,
            "mean": s.mean if s.n else math.nan,
            "std": s.std,
        }


def exact_quantile(xs: List[float], p: float) -> float:
    """Exact quantile (linear interpolation) — the test oracle."""
    if not xs:
        return math.nan
    srt = sorted(xs)
    idx = p * (len(srt) - 1)
    lo = int(math.floor(idx))
    hi = min(lo + 1, len(srt) - 1)
    frac = idx - lo
    return srt[lo] * (1 - frac) + srt[hi] * frac
