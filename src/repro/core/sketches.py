"""Streaming statistics with O(1) memory.

The paper aggregates large jobs into min/median/max curves inside Splunk;
PerSyst (cited in the paper's §3) showed quantile aggregation is what makes
many-node jobs comprehensible.  For 1000+-host fleets we cannot hold raw
samples, so we provide:

* :class:`StreamStats` — count/mean/std/min/max via Welford.
* :class:`P2Quantile` — the P² algorithm (Jain & Chlamtac 1985): a single
  quantile estimate from 5 markers, no stored samples.
* :class:`QuantileSet` — min/p25/median/p75/max in O(1) memory.
* :class:`P2Summary` / :func:`merge_quantile_summaries` — the mergeable
  form of a P² sketch: a five-knot piecewise-linear quantile summary
  that shards export and an aggregator merges (order-insensitively)
  into one distributed quantile estimate.  See docs/sharding.md for the
  merge algebra and the error bound.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class StreamStats:
    """Welford online mean/variance plus min/max."""

    __slots__ = ("n", "mean", "_m2", "min", "max")

    def __init__(self) -> None:
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self._m2 += d * (x - self.mean)
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def extend(self, xs: Iterable[float]) -> "StreamStats":
        for x in xs:
            self.add(x)
        return self

    @property
    def var(self) -> float:
        return self._m2 / self.n if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self.var)

    def merge(self, other: "StreamStats") -> "StreamStats":
        """Parallel-merge (Chan et al.) — used by island relays."""
        if other.n == 0:
            return self
        if self.n == 0:
            self.n, self.mean, self._m2 = other.n, other.mean, other._m2
            self.min, self.max = other.min, other.max
            return self
        n = self.n + other.n
        d = other.mean - self.mean
        self._m2 += other._m2 + d * d * self.n * other.n / n
        self.mean += d * other.n / n
        self.n = n
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self


class P2Quantile:
    """P² single-quantile estimator (no stored samples).

    Error is typically <1% of the value range for unimodal streams, which
    is ample for dashboard median/p90 curves.
    """

    __slots__ = ("p", "_n", "_q", "_pos", "_npos", "_dn", "count")

    def __init__(self, p: float) -> None:
        if not 0.0 < p < 1.0:
            raise ValueError("p must be in (0,1)")
        self.p = p
        self._q: List[float] = []   # marker heights
        self._pos = [1, 2, 3, 4, 5]  # marker positions (1-based)
        self._npos = [1.0, 1 + 2 * p, 1 + 4 * p, 3 + 2 * p, 5.0]
        self._dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]
        self.count = 0

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if len(self._q) < 5:
            self._q.append(x)
            if len(self._q) == 5:
                self._q.sort()
            return
        q, pos = self._q, self._pos
        # locate cell
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1
        for i in range(5):
            self._npos[i] += self._dn[i]
        # adjust interior markers
        for i in (1, 2, 3):
            d = self._npos[i] - pos[i]
            if ((d >= 1 and pos[i + 1] - pos[i] > 1)
                    or (d <= -1 and pos[i - 1] - pos[i] < -1)):
                s = 1 if d >= 0 else -1
                qn = self._parabolic(i, s)
                if not (q[i - 1] < qn < q[i + 1]):
                    qn = self._linear(i, s)
                q[i] = qn
                pos[i] += s

    def _parabolic(self, i: int, s: int) -> float:
        q, n = self._q, self._pos
        return q[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, s: int) -> float:
        q, n = self._q, self._pos
        return q[i] + s * (q[i + s] - q[i]) / (n[i + s] - n[i])

    @property
    def value(self) -> float:
        if not self._q:
            return math.nan
        if len(self._q) < 5:
            srt = sorted(self._q)
            idx = self.p * (len(srt) - 1)
            lo = int(math.floor(idx))
            hi = min(lo + 1, len(srt) - 1)
            frac = idx - lo
            return srt[lo] * (1 - frac) + srt[hi] * frac
        return self._q[2]

    def summary(self) -> "P2Summary":
        """Export the mergeable form of this sketch's current state."""
        if self.count < 5:
            return P2Summary.from_values(self._q, self.p)
        # marker heights with their *observed* cumulative fractions
        fracs = [(pos - 1) / (self.count - 1) for pos in self._pos]
        return P2Summary(self.p, self.count, tuple(self._q), tuple(fracs),
                         point=self.value)


class P2Summary:
    """Mergeable quantile summary — the shippable state of a P² sketch.

    A summary is either a small raw sample (``n <= RAW_MAX`` values kept
    exactly, so merges of tiny groups stay exact) or five knots of the
    shard-local quantile function: ``(value, cumulative fraction)``
    pairs at the P² marker fractions ``{0, p/2, p, (1+p)/2, 1}``.  Knots
    come from :meth:`P2Quantile.summary` (streaming build) or
    :meth:`from_values` (batch build over values a shard already holds —
    knot values are then *exact* local quantiles).

    ``point`` is the summary's own estimate at ``p``; a merge of a
    single non-empty summary returns it unchanged, which makes
    ``merge(empty, s) == s`` hold exactly.

    Summaries are immutable value objects: every field is a scalar or
    tuple and no merge ever mutates its inputs.  That makes them safe
    to hold in the segment-keyed partial-aggregate caches
    (docs/incremental.md) and to ship across process boundaries — the
    same summary may be merged any number of times, in any order, with
    identical results.  :meth:`state` / :meth:`from_state` round-trip
    the summary through a plain tuple for transport or comparison.
    """

    RAW_MAX = 32

    __slots__ = ("p", "n", "knots_v", "knots_f", "raw", "point")

    def __init__(self, p: float, n: int,
                 knots_v: Tuple[float, ...] = (),
                 knots_f: Tuple[float, ...] = (),
                 raw: Optional[Tuple[float, ...]] = None,
                 point: float = math.nan) -> None:
        self.p = p
        self.n = int(n)
        self.knots_v = knots_v
        self.knots_f = knots_f
        self.raw = raw
        self.point = point

    @classmethod
    def from_values(cls, xs: Sequence[float], p: float) -> "P2Summary":
        """Batch build from values a shard holds (exact local knots)."""
        xs = [float(x) for x in xs]
        n = len(xs)
        if n == 0:
            return cls(p, 0, raw=(), point=math.nan)
        if n <= cls.RAW_MAX:
            raw = tuple(sorted(xs))
            return cls(p, n, raw=raw, point=exact_quantile(list(raw), p))
        fracs = (0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0)
        vals = np.quantile(np.asarray(xs, dtype=np.float64),
                           np.asarray(fracs))
        return cls(p, n, tuple(float(v) for v in vals), fracs,
                   point=float(vals[2]))

    def _sort_key(self):
        return (self.n, self.raw if self.raw is not None else (),
                self.knots_v, self.knots_f)

    def state(self) -> tuple:
        """The summary's full state as one plain tuple — canonical for
        equality/hashing and self-contained for transport."""
        return (self.p, self.n, self.knots_v, self.knots_f, self.raw,
                self.point)

    @classmethod
    def from_state(cls, state: tuple) -> "P2Summary":
        """Rebuild from :meth:`state` output (tuples may arrive as
        lists after a JSON round-trip — the wire codec in
        ``repro.core.remote`` ships states verbatim).  Raises
        ``ValueError`` on a malformed state so transport bugs surface
        at the decode boundary, not deep inside a merge."""
        try:
            p, n, knots_v, knots_f, raw, point = state
            return cls(float(p), int(n),
                       tuple(float(v) for v in knots_v),
                       tuple(float(f) for f in knots_f),
                       (tuple(float(x) for x in raw)
                        if raw is not None else None),
                       float(point))
        except (TypeError, ValueError) as exc:
            raise ValueError(
                f"malformed P2Summary state: {state!r}") from exc

    def __eq__(self, other) -> bool:
        if not isinstance(other, P2Summary):
            return NotImplemented
        return self.state() == other.state()

    def __hash__(self) -> int:
        return hash(self.state())

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        form = (f"raw[{len(self.raw)}]" if self.raw is not None
                else "knots")
        return (f"P2Summary(p={self.p}, n={self.n}, {form}, "
                f"point={self.point})")


def _knotted_from_values(xs: Sequence[float], p: float) -> "P2Summary":
    """Force a 5-knot summary over raw values (even when ``n`` is small
    enough that :meth:`P2Summary.from_values` would keep them raw) —
    used to make mixed raw+knotted groups uniformly knotted so they can
    take the vectorized batch merge.  Knot values are exact pooled
    quantiles, the same derivation ``from_values`` uses past
    ``RAW_MAX``."""
    srt = sorted(float(x) for x in xs)
    n1 = len(srt) - 1
    fracs = (0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0)
    vals = []
    for f in fracs:  # exact_quantile over one shared sort (np.quantile
        idx = f * n1  # per tiny pool costs more than it computes)
        lo = int(idx)
        hi = min(lo + 1, n1)
        w = idx - lo
        vals.append(srt[lo] * (1.0 - w) + srt[hi] * w)
    return P2Summary(p, len(srt), tuple(vals), fracs, point=vals[2])


def merge_quantile_summary_groups(groups: List[List["P2Summary"]],
                                  p: float) -> List[float]:
    """Batched :func:`merge_quantile_summaries` over many groups — the
    gather node finalizes one quantile column for *all* group keys in a
    handful of vectorized passes instead of one Python CDF merge per
    group.  All-raw groups pool into an exact quantile; otherwise each
    group's raw summaries condense into one exact 5-knot pooled part
    (weighted by its sample count) and the now uniformly knotted groups
    are stacked and merged with NumPy.  Stays within the documented
    merge bound and the summaries' value range; order-insensitive like
    the scalar merge (pooling ignores order, the CDF average is
    commutative)."""
    out: List[float] = [math.nan] * len(groups)
    batched: Dict[int, List[Tuple[int, List["P2Summary"]]]] = {}
    for i, summaries in enumerate(groups):
        ss = [s for s in summaries if s.n > 0]
        if not ss:
            continue
        if len(ss) == 1:
            out[i] = ss[0].point
            continue
        raw_pool = [x for s in ss if s.raw is not None for x in s.raw]
        knotted = [s for s in ss if s.raw is None]
        if not knotted:
            out[i] = exact_quantile(raw_pool, p)
            continue
        if raw_pool:
            knotted = knotted + [_knotted_from_values(raw_pool, p)]
        batched.setdefault(len(knotted), []).append((i, knotted))
    for n_parts, items in batched.items():
        idxs = [i for i, _ in items]
        vals = _batch_merge_knotted([ss for _, ss in items], n_parts, p)
        for i, v in zip(idxs, vals):
            out[i] = v
    return out


def _batch_merge_knotted(groups: List[List["P2Summary"]], S: int,
                         p: float) -> np.ndarray:
    """Vectorized CDF-average merge for G groups of S knotted summaries."""
    G = len(groups)
    V = np.array([[s.knots_v for s in ss] for ss in groups])  # (G, S, 5)
    F = np.array([[s.knots_f for s in ss] for ss in groups])  # (G, S, 5)
    W = np.array([[float(s.n) for s in ss] for ss in groups])  # (G, S)
    C = S * 5
    X = np.sort(V.reshape(G, C), axis=1)  # candidate knot values per group
    # piecewise-linear CDF of every summary at every candidate
    less = V[:, :, None, :] < X[:, None, :, None]          # (G, S, C, 5)
    hi = np.clip(less.sum(-1), 1, 4)                        # (G, S, C)
    lo = hi - 1
    base = (np.arange(G * S, dtype=np.int64) * 5).reshape(G, S, 1)
    Vf, Ff = V.reshape(-1), F.reshape(-1)
    vlo, vhi = Vf[base + lo], Vf[base + hi]
    flo, fhi = Ff[base + lo], Ff[base + hi]
    denom = vhi - vlo
    safe = np.where(denom > 0, denom, 1.0)
    t = np.clip((X[:, None, :] - vlo) / safe, 0.0, 1.0)
    t = np.where(denom > 0, t, 1.0)
    Fx = flo + t * (fhi - flo)
    cdf = (W[:, :, None] * Fx).sum(1) / W.sum(1)[:, None]   # (G, C)
    # invert the merged CDF at p per group
    ge = cdf >= p
    first = np.argmax(ge, axis=1)
    i0 = np.maximum(first - 1, 0)
    rows = np.arange(G)
    x0, x1 = X[rows, i0], X[rows, first]
    f0, f1 = cdf[rows, i0], cdf[rows, first]
    df = f1 - f0
    t = np.where(df > 0, (p - f0) / np.where(df > 0, df, 1.0), 1.0)
    res = x0 + np.clip(t, 0.0, 1.0) * (x1 - x0)
    return np.where(ge.any(axis=1), res, X[:, -1])


def p2_summaries_from_sorted_groups(vals: np.ndarray, starts: np.ndarray,
                                    counts: np.ndarray, p: float
                                    ) -> List["P2Summary"]:
    """Vectorized batch build: one :class:`P2Summary` per group from
    group-partitioned, ascending-sorted values (group ``g`` occupies
    ``vals[starts[g]:starts[g]+counts[g]]``).  Result-equivalent to
    calling :meth:`P2Summary.from_values` per group, but the five knot
    gathers run once across all groups — the hot path for sharded
    ``stats pXX(...) by ...`` over many groups."""
    starts = np.asarray(starts, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    G = len(counts)
    fracs = (0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0)
    knot_vals = np.zeros((G, 5))
    if vals.size:
        nm1 = np.maximum(counts - 1, 0)
        safe_start = np.minimum(starts, vals.size - 1)
        for j, f in enumerate(fracs):
            idx = f * nm1
            lo = np.floor(idx).astype(np.int64)
            hi = np.minimum(lo + 1, nm1)
            w = idx - lo
            vlo = vals[np.minimum(safe_start + lo, vals.size - 1)]
            vhi = vals[np.minimum(safe_start + hi, vals.size - 1)]
            knot_vals[:, j] = vlo * (1.0 - w) + vhi * w
    out: List[P2Summary] = []
    for g in range(G):
        n = int(counts[g])
        if n == 0:
            out.append(P2Summary(p, 0, raw=(), point=math.nan))
        elif n <= P2Summary.RAW_MAX:
            s = int(starts[g])
            out.append(P2Summary(p, n, raw=tuple(vals[s:s + n].tolist()),
                                 point=float(knot_vals[g, 2])))
        else:
            kv = knot_vals[g]
            out.append(P2Summary(p, n, tuple(kv.tolist()), fracs,
                                 point=float(kv[2])))
    return out


def _clean_knots(vs: List[float], fs: List[float]):
    """Strictly increasing knot values with nondecreasing fractions
    (duplicate values keep the largest fraction) — a valid piecewise-
    linear CDF.  Inputs are already sorted by value."""
    out_v: List[float] = []
    out_f: List[float] = []
    last_f = 0.0
    for v, f in zip(vs, fs):
        if f < last_f:
            f = last_f
        last_f = f
        if out_v and v == out_v[-1]:
            out_f[-1] = f  # keep the largest fraction of a value run
        else:
            out_v.append(v)
            out_f.append(f)
    return out_v, out_f


def merge_quantile_summaries(summaries: Iterable["P2Summary"],
                             p: Optional[float] = None) -> float:
    """Distributed quantile: merge shard summaries into one estimate.

    Order-insensitive by construction: raw samples from small summaries
    are pooled into one sorted sample, knot summaries are sorted by a
    canonical key, and the merged CDF — the sample-count-weighted
    average of the per-summary piecewise-linear CDFs — is inverted at
    ``p``.  Empty summaries are identity elements, and a merge of a
    single non-empty summary returns its own ``point`` estimate
    unchanged.  The result always lies within the union of the
    summaries' value ranges; see docs/sharding.md for the error bound.

    Pure-Python on purpose: inputs are a handful of 5-knot summaries
    per group, where interpreter-loop cost beats NumPy call overhead
    (the gather node runs one merge per group per quantile column).
    """
    ss = [s for s in summaries if s.n > 0]
    if not ss:
        return math.nan
    if p is None:
        p = ss[0].p
    if len(ss) == 1:
        return ss[0].point
    raw_pool: List[float] = []
    knotted: List[P2Summary] = []
    for s in ss:
        if s.raw is not None:
            raw_pool.extend(s.raw)
        else:
            knotted.append(s)
    if not knotted:
        return exact_quantile(raw_pool, p)
    knotted.sort(key=P2Summary._sort_key)
    parts = []
    for s in knotted:
        vs, fs = s.knots_v, s.knots_f
        if any(vs[i] >= vs[i + 1] for i in range(len(vs) - 1)):
            vs, fs = _clean_knots(list(vs), list(fs))
        parts.append((s.n, (vs, fs)))
    if raw_pool:
        raw_pool.sort()
        m = len(raw_pool)
        if m > 17:
            # condense a large pooled sample to 17 exact quantile knots
            # so the CDF walk stays O(knots); the piecewise-linear error
            # this introduces is far inside the documented bound
            vs, fs = [], []
            for i in range(17):
                f = i / 16.0
                idx = f * (m - 1)
                lo = int(idx)
                hi = min(lo + 1, m - 1)
                vs.append(raw_pool[lo] * (1 - (idx - lo))
                          + raw_pool[hi] * (idx - lo))
                fs.append(f)
            parts.append((m, _clean_knots(vs, fs)))
        else:
            fs = ([0.5] if m == 1
                  else [i / (m - 1) for i in range(m)])
            parts.append((m, _clean_knots(raw_pool, fs)))
    total = float(sum(w for w, _ in parts))
    xs = sorted({x for _, (vs, _fs) in parts for x in vs})
    acc = [0.0] * len(xs)
    for w, (vs, fs) in parts:
        j = 0
        k = len(vs)
        for i, x in enumerate(xs):
            while j < k and vs[j] < x:
                j += 1
            if j == 0:
                fv = fs[0]
            elif j == k:
                fv = fs[-1]
            elif vs[j] == x:
                fv = fs[j]
            else:
                t = (x - vs[j - 1]) / (vs[j] - vs[j - 1])
                fv = fs[j - 1] + t * (fs[j] - fs[j - 1])
            acc[i] += w * fv
    prev_x, prev_f = xs[0], acc[0] / total
    if prev_f >= p:
        return prev_x
    for i in range(1, len(xs)):
        f = acc[i] / total
        if f >= p:
            if f <= prev_f:
                return xs[i]
            t = (p - prev_f) / (f - prev_f)
            return prev_x + t * (xs[i] - prev_x)
        prev_x, prev_f = xs[i], f
    return xs[-1]


class QuantileSet:
    """min / p25 / median / p75 / max in O(1) memory."""

    def __init__(self) -> None:
        self.stats = StreamStats()
        self._p25 = P2Quantile(0.25)
        self._p50 = P2Quantile(0.50)
        self._p75 = P2Quantile(0.75)

    def add(self, x: float) -> None:
        self.stats.add(x)
        self._p25.add(x)
        self._p50.add(x)
        self._p75.add(x)

    def summary(self) -> Dict[str, float]:
        s = self.stats
        return {
            "count": s.n,
            "min": s.min if s.n else math.nan,
            "p25": self._p25.value,
            "median": self._p50.value,
            "p75": self._p75.value,
            "max": s.max if s.n else math.nan,
            "mean": s.mean if s.n else math.nan,
            "std": s.std,
        }


def exact_quantile(xs: List[float], p: float) -> float:
    """Exact quantile (linear interpolation) — the test oracle."""
    if not xs:
        return math.nan
    srt = sorted(xs)
    idx = p * (len(srt) - 1)
    lo = int(math.floor(idx))
    hi = min(lo + 1, len(srt) - 1)
    frac = idx - lo
    return srt[lo] * (1 - frac) + srt[hi] * frac
