"""Fleet self-observability: tracing, metrics, and self-ingestion.

The fleet (coordinator, :class:`~repro.core.service.QueryService`,
workers, replicas) historically exposed its vitals through scattered
stats dicts — ``explain()``, ``last_query_stats``, breaker and hedge
counters, per-worker ``explain`` ops.  This module unifies them behind
three layers:

1. **Distributed tracing** — :class:`Tracer` produces :class:`Span`
   records (``trace_id``/``span_id``/``parent_id``, monotonic start /
   duration, typed attributes) around every query phase: admission,
   plan compile, per-shard scatter, hedge attempts, retries, merge,
   finalize, gather.  Trace context travels over the wire protocol as
   an optional ``trace`` field on ``scatter``/``gather`` requests,
   negotiated at ``hello`` (a worker advertises ``"trace": True``;
   old workers never see the field), so one trace stitches coordinator
   and worker spans.  Finished traces land in a bounded ring buffer;
   traces slower than a threshold are retained in a slow-query log.

2. **Unified metrics registry** — :class:`Registry` holds counters,
   gauges, and histograms with a small label model, plus pull-based
   *collectors*: callables that snapshot live component state (shard
   counters, breaker states, replica stats, cache hit rates) on
   demand with zero hot-path cost.  ``explain()`` and
   ``QueryService.stats()`` are views over the same collector
   functions, so the registry and the legacy dicts cannot diverge.

3. **Self-ingestion** — :class:`SelfMonitor` periodically snapshots
   the registry into :class:`~repro.core.schema.MetricRecord` rows
   (``kind="fleet"``, ``job="_fleet"``) and inserts them into a
   dedicated ``_telemetry`` store, so splunklite queries, dashboards,
   and detectors run over the fleet's own vitals exactly like tenant
   data — continuously, over the remote fleet, including under fault
   injection.

Run ``python -m repro.core.telemetry --help`` for the ops CLI
(trace-tree pretty printing, registry JSON dumps, a live demo).

Naming conventions (see docs/observability.md): metric names are
lowercase dotted paths ``<component>.<noun>[_<unit>]`` (e.g.
``remote.retries``, ``service.queue_depth``, ``cache.partial.hits``);
labels are few and low-cardinality (``shard``, ``tenant``, ``op``).
Self-ingested field keys keep the dots — they are valid
:data:`~repro.core.schema._KEY_RE` keys and valid splunklite field
names.
"""
from __future__ import annotations

import argparse
import bisect
import json
import os
import re
import sys
import threading
import time
from collections import OrderedDict, deque
from typing import (Any, Callable, Dict, Iterable, List, Optional,
                    Sequence, Tuple)

__all__ = [
    "Span", "Tracer", "Counter", "Gauge", "Histogram", "Registry",
    "Telemetry", "SelfMonitor", "format_trace", "main",
    "TRACE_RING_MAX", "SLOW_QUERY_THRESHOLD_S",
]

TRACE_RING_MAX = 128          # finished traces retained in the ring
LIVE_TRACE_MAX = 256          # open traces before oldest is evicted
SLOW_LOG_MAX = 32             # slow-query exemplars retained
SLOW_QUERY_THRESHOLD_S = 0.25
HIST_BOUNDS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
               0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_SAN_RE = re.compile(r"[^A-Za-z0-9_.]")


def _new_id() -> str:
    """64-bit random hex id (trace and span ids share the format)."""
    return os.urandom(8).hex()


def sanitize_metric_key(name: str) -> str:
    """Coerce ``name`` into a valid record field key (schema
    ``_KEY_RE``): illegal characters become ``_`` and a leading
    non-letter gets an underscore prefix.  Dots are preserved — they
    are legal in both field keys and splunklite field names."""
    out = _SAN_RE.sub("_", name)
    if not out or not (out[0].isalpha() or out[0] == "_"):
        out = "_" + out
    return out


# ---------------------------------------------------------------------------
# spans + tracer
# ---------------------------------------------------------------------------

class Span:
    """One timed operation inside a trace.

    ``trace_id`` groups spans into a request; ``parent_id`` links the
    tree (``None`` marks the root).  ``start`` is wall-clock (for
    cross-process ordering in displays); duration is measured on the
    monotonic clock.  ``attrs`` carries typed attributes (shard index,
    attempt number, cache disposition, ...).  Use as a context
    manager — an exception marks the span ``status="error"``."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "node",
                 "start", "duration_s", "status", "attrs",
                 "_t0", "_tracer", "_finished")

    recording = True

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 parent_id: Optional[str],
                 attrs: Optional[Dict[str, Any]] = None) -> None:
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.name = name
        self.node = tracer.node
        self.start = time.time()
        self.duration_s = 0.0
        self.status = "ok"
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self._t0 = time.monotonic()
        self._tracer = tracer
        self._finished = False

    # -- attribute + lifecycle --------------------------------------------
    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def child(self, name: str, attrs: Optional[Dict[str, Any]] = None
              ) -> "Span":
        return self._tracer.start_span(name, parent=self, attrs=attrs)

    def ctx(self) -> Dict[str, str]:
        """Wire-propagatable trace context."""
        return {"trace_id": self.trace_id, "span_id": self.span_id}

    def finish(self, status: Optional[str] = None) -> "Span":
        if self._finished:
            return self
        self._finished = True
        self.duration_s = time.monotonic() - self._t0
        if status is not None:
            self.status = status
        self._tracer._record(self)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None and self.status == "ok":
            self.status = "error"
            self.attrs.setdefault("error", repr(exc))
        self.finish()

    def to_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "node": self.node, "start": self.start,
                "duration_s": self.duration_s, "status": self.status,
                "attrs": dict(self.attrs)}


class _NullSpan:
    """Do-nothing span returned when tracing is disabled; supports the
    full :class:`Span` surface so call sites stay branch-free."""

    __slots__ = ()
    recording = False
    trace_id = ""
    span_id = ""
    parent_id = None
    name = ""
    node = ""
    start = 0.0
    duration_s = 0.0
    status = "ok"
    attrs: Dict[str, Any] = {}

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def child(self, name: str, attrs: Optional[Dict] = None) -> "_NullSpan":
        return self

    def ctx(self) -> Dict[str, str]:
        return {}

    def finish(self, status: Optional[str] = None) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def to_dict(self) -> Dict[str, Any]:
        return {}


NULL_SPAN = _NullSpan()


class Tracer:
    """Produces spans and collects finished traces.

    A trace is *sealed* when its root span (``parent_id is None``)
    finishes: its spans move from the live table into a bounded ring
    buffer, and traces slower than ``slow_threshold_s`` are copied
    into the slow-query log with an exemplar.  Spans adopted from
    remote processes (:meth:`adopt`) splice into whichever table
    currently holds the trace.  All public methods are thread-safe."""

    def __init__(self, enabled: bool = True, node: str = "coordinator",
                 ring_max: int = TRACE_RING_MAX,
                 slow_threshold_s: float = SLOW_QUERY_THRESHOLD_S,
                 slow_log_max: int = SLOW_LOG_MAX) -> None:
        self.enabled = bool(enabled)
        self.node = node
        self.ring_max = int(ring_max)
        self.slow_threshold_s = float(slow_threshold_s)
        self._lock = threading.Lock()
        self._live: "OrderedDict[str, List[Dict]]" = OrderedDict()
        self._ring: "OrderedDict[str, List[Dict]]" = OrderedDict()
        self._slow: deque = deque(maxlen=int(slow_log_max))
        self._tls = threading.local()
        self.spans_started = 0
        self.spans_dropped = 0

    # -- span creation ----------------------------------------------------
    def start_span(self, name: str, parent: Optional[Span] = None,
                   parent_ctx: Optional[Dict[str, str]] = None,
                   attrs: Optional[Dict[str, Any]] = None):
        """Start a span.  ``parent`` links locally; ``parent_ctx``
        (a ``{"trace_id", "span_id"}`` dict off the wire) links across
        processes.  With neither, a new root trace begins."""
        if not self.enabled:
            return NULL_SPAN
        if parent is not None and parent.recording:
            trace_id, parent_id = parent.trace_id, parent.span_id
        elif parent_ctx and parent_ctx.get("trace_id"):
            trace_id = str(parent_ctx["trace_id"])
            parent_id = str(parent_ctx.get("span_id") or "") or None
        else:
            trace_id, parent_id = _new_id(), None
        with self._lock:
            self.spans_started += 1
        return Span(self, name, trace_id, parent_id, attrs)

    # -- thread-local "current span" --------------------------------------
    def current(self):
        """The span most recently activated on this thread (or the
        null span)."""
        return getattr(self._tls, "span", NULL_SPAN)

    class _Activation:
        __slots__ = ("_tracer", "_span", "_prev")

        def __init__(self, tracer: "Tracer", span) -> None:
            self._tracer, self._span, self._prev = tracer, span, None

        def __enter__(self):
            self._prev = getattr(self._tracer._tls, "span", NULL_SPAN)
            self._tracer._tls.span = self._span
            return self._span

        def __exit__(self, *exc) -> None:
            self._tracer._tls.span = self._prev

    def activate(self, span) -> "Tracer._Activation":
        """Context manager installing ``span`` as this thread's
        current span (picked up by downstream layers that accept no
        explicit parent)."""
        return Tracer._Activation(self, span)

    # -- collection -------------------------------------------------------
    def _record(self, span: Span) -> None:
        d = span.to_dict()
        with self._lock:
            if span.parent_id is None:
                spans = self._live.pop(span.trace_id, [])
                spans.append(d)
                self._seal_locked(span.trace_id, spans, d)
            else:
                self._append_live_locked(span.trace_id, d)

    def _append_live_locked(self, trace_id: str, d: Dict) -> None:
        if trace_id in self._ring:           # root already sealed
            self._ring[trace_id].append(d)
            return
        bucket = self._live.get(trace_id)
        if bucket is None:
            bucket = self._live[trace_id] = []
            while len(self._live) > LIVE_TRACE_MAX:
                self._live.popitem(last=False)
                self.spans_dropped += 1
        bucket.append(d)

    def _seal_locked(self, trace_id: str, spans: List[Dict],
                     root: Dict) -> None:
        self._ring[trace_id] = spans
        self._ring.move_to_end(trace_id)
        while len(self._ring) > self.ring_max:
            self._ring.popitem(last=False)
        if root["duration_s"] >= self.slow_threshold_s:
            self._slow.append({
                "ts": root["start"], "trace_id": trace_id,
                "name": root["name"],
                "duration_s": root["duration_s"],
                "attrs": dict(root["attrs"]),
                "exemplar": [dict(s) for s in spans],
            })

    def adopt(self, spans: Iterable[Dict]) -> int:
        """Splice finished span dicts from another process (worker
        replies) into their traces.  Returns the count adopted."""
        n = 0
        with self._lock:
            for d in spans or ():
                tid = d.get("trace_id")
                if not tid:
                    continue
                self._append_live_locked(str(tid), dict(d))
                n += 1
        return n

    def take_trace(self, trace_id: str) -> List[Dict]:
        """Remove and return every span recorded for ``trace_id``
        (workers use this to ship a request's spans back in the
        reply)."""
        with self._lock:
            out = self._live.pop(trace_id, [])
            out += self._ring.pop(trace_id, [])
        return out

    # -- inspection -------------------------------------------------------
    def trace(self, trace_id: str) -> List[Dict]:
        with self._lock:
            spans = self._ring.get(trace_id) or self._live.get(trace_id)
            return [dict(s) for s in spans] if spans else []

    def last_trace(self) -> Tuple[Optional[str], List[Dict]]:
        """(trace_id, spans) of the most recently sealed trace."""
        with self._lock:
            if not self._ring:
                return None, []
            tid = next(reversed(self._ring))
            return tid, [dict(s) for s in self._ring[tid]]

    def finished_traces(self) -> List[str]:
        with self._lock:
            return list(self._ring)

    def slow_queries(self) -> List[Dict]:
        with self._lock:
            return [dict(e) for e in self._slow]

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"spans_started": self.spans_started,
                    "spans_dropped": self.spans_dropped,
                    "traces_finished": len(self._ring),
                    "traces_live": len(self._live),
                    "slow_queries": len(self._slow)}


def format_trace(spans: Sequence[Dict], unit_us: bool = True) -> str:
    """Render a span list as an indented tree, children ordered by
    start time; orphaned spans (parent not present — e.g. dropped by
    the ring) attach under a synthetic root."""
    if not spans:
        return "(empty trace)"
    by_id = {s["span_id"]: s for s in spans}
    children: Dict[Optional[str], List[Dict]] = {}
    for s in spans:
        pid = s.get("parent_id")
        if pid is not None and pid not in by_id:
            pid = None
        children.setdefault(pid, []).append(s)
    for kids in children.values():
        kids.sort(key=lambda s: (s.get("start", 0.0), s.get("name", "")))
    lines: List[str] = []

    def emit(span: Dict, depth: int) -> None:
        dur = span.get("duration_s", 0.0)
        dur_txt = (f"{dur * 1e6:10.1f}us" if unit_us
                   else f"{dur * 1e3:10.3f}ms")
        status = span.get("status", "ok")
        mark = {"ok": " ", "error": "!", "cancelled": "x"}.get(status, "?")
        attrs = span.get("attrs") or {}
        attr_txt = ("  " + " ".join(f"{k}={attrs[k]!r}"
                                    for k in sorted(attrs)) if attrs else "")
        lines.append(f"{dur_txt} {mark} {'  ' * depth}"
                     f"{span.get('node', '?')}/{span.get('name', '?')}"
                     f"{attr_txt}")
        for kid in children.get(span["span_id"], ()):
            emit(kid, depth + 1)

    for root in children.get(None, ()):
        emit(root, 0)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic counter.  ``inc`` is lock-protected; reads are a
    single attribute load."""

    __slots__ = ("name", "labels", "value", "_lock")
    kind = "counter"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]
                 ) -> None:
        self.name, self.labels = name, labels
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name, self.value)]


class Gauge:
    """Point-in-time value; ``set`` replaces, ``add`` adjusts."""

    __slots__ = ("name", "labels", "value", "_lock")
    kind = "gauge"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]
                 ) -> None:
        self.name, self.labels = name, labels
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name, self.value)]


class Histogram:
    """Fixed-bound histogram with count/sum/max and estimated
    percentiles (linear interpolation inside the winning bucket)."""

    __slots__ = ("name", "labels", "bounds", "counts", "count", "sum",
                 "max", "_lock")
    kind = "histogram"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 bounds: Sequence[float] = HIST_BOUNDS) -> None:
        self.name, self.labels = name, labels
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v > self.max:
                self.max = v

    def quantile(self, q: float) -> float:
        with self._lock:
            total, counts = self.count, list(self.counts)
        if total == 0:
            return 0.0
        rank = q * total
        seen = 0.0
        for i, c in enumerate(counts):
            if seen + c >= rank and c:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (rank - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.max

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name + ".count", float(self.count)),
                (self.name + ".sum", self.sum),
                (self.name + ".max", self.max),
                (self.name + ".p50", self.quantile(0.50)),
                (self.name + ".p95", self.quantile(0.95)),
                (self.name + ".p99", self.quantile(0.99))]


def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Registry:
    """Unified metric registry.

    Two ingestion styles:

    * **instruments** — :meth:`counter` / :meth:`gauge` /
      :meth:`histogram` get-or-create a named instrument (with an
      optional small label set) for code that pushes measurements;
    * **collectors** — :meth:`register_collector` attaches a callable
      returning ``{name: value}`` evaluated only at snapshot time, so
      hot paths keep their plain attribute counters and the registry
      stays the single read-side source (``explain()`` /
      ``QueryService.stats()`` call the same collector functions).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: "OrderedDict[Tuple[str, Tuple], Any]" = OrderedDict()
        self._collectors: "OrderedDict[str, Callable[[], Dict[str, float]]]" \
            = OrderedDict()

    # -- instruments ------------------------------------------------------
    def _instrument(self, cls, name: str, labels: Dict[str, Any],
                    **kw: Any):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = self._metrics[key] = cls(name, key[1], **kw)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}")
            return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._instrument(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._instrument(Gauge, name, labels)

    def histogram(self, name: str, bounds: Sequence[float] = HIST_BOUNDS,
                  **labels: Any) -> Histogram:
        return self._instrument(Histogram, name, labels, bounds=bounds)

    # -- collectors -------------------------------------------------------
    def register_collector(self, name: str,
                           fn: Callable[[], Dict[str, float]]) -> None:
        with self._lock:
            self._collectors[name] = fn

    def unregister_collector(self, name: str) -> None:
        with self._lock:
            self._collectors.pop(name, None)

    def collect(self, name: str) -> Dict[str, float]:
        """Evaluate one named collector (the ``explain()``/``stats()``
        read path uses this so legacy views and the registry share a
        single source)."""
        with self._lock:
            fn = self._collectors.get(name)
        return dict(fn()) if fn is not None else {}

    # -- snapshots --------------------------------------------------------
    def snapshot(self) -> List[Dict[str, Any]]:
        """Every sample: ``{"name", "labels", "value"}`` — instruments
        first, then collector output (empty labels)."""
        with self._lock:
            instruments = list(self._metrics.values())
            collectors = list(self._collectors.items())
        out: List[Dict[str, Any]] = []
        for inst in instruments:
            labels = dict(inst.labels)
            for name, value in inst.samples():
                out.append({"name": name, "labels": labels,
                            "value": float(value)})
        for cname, fn in collectors:
            try:
                data = fn()
            except Exception:       # a sick component must not kill scrapes
                data = {cname + ".collector_errors": 1.0}
            for name, value in data.items():
                if isinstance(value, (int, float)) and not isinstance(
                        value, bool):
                    out.append({"name": name, "labels": {},
                                "value": float(value)})
        return out

    def flat_snapshot(self) -> Dict[str, float]:
        """Samples flattened to ``{field_key: value}`` with labels
        folded into the key (``name.k_v``) and keys sanitized to the
        record-schema grammar — the self-ingestion wire format."""
        flat: Dict[str, float] = {}
        for s in self.snapshot():
            key = s["name"]
            for k, v in sorted(s["labels"].items()):
                key += f".{k}_{v}"
            flat[sanitize_metric_key(key)] = s["value"]
        return flat


# ---------------------------------------------------------------------------
# facade + self-ingestion
# ---------------------------------------------------------------------------

class Telemetry:
    """One tracer + one registry, shared by every fleet layer.

    Stores and services create a default instance with tracing *off*
    (registry collectors are pull-based and free); pass
    ``Telemetry(tracing=True)`` to record spans.  The instance is
    inherited downward — ``QueryService`` adopts its store's
    telemetry, the remote aggregator shares its instance with every
    ``RemoteShard``/``ReplicaSet`` member."""

    def __init__(self, tracing: bool = False, node: str = "coordinator",
                 slow_threshold_s: float = SLOW_QUERY_THRESHOLD_S,
                 ring_max: int = TRACE_RING_MAX) -> None:
        self.tracer = Tracer(enabled=tracing, node=node,
                             ring_max=ring_max,
                             slow_threshold_s=slow_threshold_s)
        self.registry = Registry()

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    def span(self, name: str, parent=None, parent_ctx=None, attrs=None):
        return self.tracer.start_span(name, parent=parent,
                                      parent_ctx=parent_ctx, attrs=attrs)


class SelfMonitor:
    """Pumps registry snapshots into a ``_telemetry`` store.

    Each :meth:`pump` emits one ``kind="fleet"`` record whose fields
    are the flat registry snapshot, plus one ``kind="event"`` record
    per new slow query.  ``sink`` is anything with ``insert(record)``
    (an in-memory :class:`~repro.core.aggregator.MetricStore`, a columnar
    store, or a shard of the fleet itself).  :meth:`maybe_pump` is the
    interval-gated form for embedding in existing pump loops."""

    def __init__(self, telemetry: Telemetry, sink: Any,
                 host: str = "fleet-coordinator", job: str = "_fleet",
                 interval_s: float = 5.0) -> None:
        self.telemetry = telemetry
        self.sink = sink
        self.host = host
        self.job = job
        self.interval_s = float(interval_s)
        self.pumps = 0
        self.records_emitted = 0
        self._last_pump = 0.0
        self._slow_seen = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def collect(self, now: Optional[float] = None) -> List[Any]:
        """Build (without inserting) this cycle's records."""
        from .schema import MetricRecord
        ts = time.time() if now is None else float(now)
        fields = self.telemetry.registry.flat_snapshot()
        for name, value in self.telemetry.tracer.stats().items():
            fields[sanitize_metric_key("tracer." + name)] = float(value)
        records = [MetricRecord(ts=ts, host=self.host, job=self.job,
                                kind="fleet", fields=fields)]
        slow = self.telemetry.tracer.slow_queries()
        with self._lock:
            fresh = slow[self._slow_seen:]
            self._slow_seen = len(slow)
        for entry in fresh:
            records.append(MetricRecord(
                ts=float(entry["ts"]), host=self.host, job=self.job,
                kind="event",
                fields={"event": "slow_query",
                        "trace_id": entry["trace_id"],
                        "name": entry["name"],
                        "duration_s": float(entry["duration_s"])}))
        return records

    def pump(self, now: Optional[float] = None) -> int:
        """Snapshot + insert; returns the number of records emitted."""
        records = self.collect(now)
        for rec in records:
            self.sink.insert(rec)
        with self._lock:
            self.pumps += 1
            self.records_emitted += len(records)
            self._last_pump = time.monotonic()
        return len(records)

    def maybe_pump(self, now: Optional[float] = None) -> int:
        with self._lock:
            due = (time.monotonic() - self._last_pump) >= self.interval_s
        return self.pump(now) if due else 0

    # -- optional background pump -----------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="self-monitor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5.0)

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.pump()
            except Exception:
                pass            # the monitor must never take down the fleet


# ---------------------------------------------------------------------------
# ops CLI
# ---------------------------------------------------------------------------

def _cmd_trace(path: str, unit_ms: bool) -> int:
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    spans = data.get("spans", data) if isinstance(data, dict) else data
    print(format_trace(spans, unit_us=not unit_ms))
    return 0


def _cmd_registry(path: Optional[str]) -> int:
    if path:
        with open(path, "r", encoding="utf-8") as fh:
            print(json.dumps(json.load(fh), indent=2, sort_keys=True))
        return 0
    print(json.dumps({}, indent=2))
    return 0


def _cmd_demo(shards: int, slow_ms: float) -> int:
    """Run a tiny traced fleet in-process and print its trace tree,
    registry snapshot, and a self-ingestion query."""
    import tempfile

    from .aggregator import MetricStore
    from .schema import MetricRecord
    from .shards import ShardedAggregator
    from . import splunklite

    telemetry = Telemetry(tracing=True, slow_threshold_s=slow_ms / 1e3)
    with tempfile.TemporaryDirectory() as tmp:
        agg = ShardedAggregator(num_shards=shards, directory=tmp,
                                seal_threshold=256, telemetry=telemetry)
        for i in range(1024):
            agg.insert(MetricRecord(
                ts=1e6 + i, host=f"n{i % 8}", job=f"job.{i % 4}",
                kind="perf", fields={"gflops": float(i % 97)}))
        q = ("search kind=perf | stats avg(gflops) count by job "
             "| sort -avg_gflops")
        rows, _stats = agg.query_with_stats(q)
        tid, spans = telemetry.tracer.last_trace()
        print(f"# query: {q}\n# rows: {len(rows)}   trace: {tid}\n")
        print(format_trace(spans))
        tstore = MetricStore()
        monitor = SelfMonitor(telemetry, tstore, interval_s=0.0)
        monitor.pump()
        print("\n# registry snapshot (flat):")
        print(json.dumps(telemetry.registry.flat_snapshot(), indent=2,
                         sort_keys=True))
        print("\n# self-ingestion query:")
        for r in splunklite.query(
                tstore, "search kind=fleet | head 1"):
            print(json.dumps(r, sort_keys=True, default=str))
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.telemetry",
        description="Fleet telemetry ops tools: pretty-print trace "
                    "trees, dump registry snapshots, run a traced demo.")
    sub = p.add_subparsers(dest="cmd", required=True)
    pt = sub.add_parser("trace", help="pretty-print a trace tree from a "
                                      "JSON span dump")
    pt.add_argument("path", help="JSON file: a span list or "
                                 "{'spans': [...]}")
    pt.add_argument("--ms", action="store_true",
                    help="durations in milliseconds (default: us)")
    pr = sub.add_parser("registry", help="pretty-print a registry "
                                         "snapshot JSON dump")
    pr.add_argument("path", nargs="?", help="snapshot JSON file")
    pd = sub.add_parser("demo", help="run a traced in-process fleet and "
                                     "print trace + registry + "
                                     "self-ingestion output")
    pd.add_argument("--shards", type=int, default=2)
    pd.add_argument("--slow-ms", type=float, default=0.0,
                    help="slow-query threshold in ms (0 logs everything)")
    args = p.parse_args(argv)
    if args.cmd == "trace":
        return _cmd_trace(args.path, args.ms)
    if args.cmd == "registry":
        return _cmd_registry(args.path)
    return _cmd_demo(args.shards, args.slow_ms)


if __name__ == "__main__":
    sys.exit(main())
