"""Integration of the monitoring daemon with JAX training/serving loops.

``TrainMonitor`` is what an application (or our launcher) embeds: it owns
the hpcmd daemon, registers the standard source set, extracts static
per-step cost figures from the compiled executable, and receives one cheap
callback per step.  Sampling stays on the daemon's clock-aligned interval,
so per-step overhead is two integer updates — the paper's negligible-
overhead requirement (validated by benchmarks/overhead.py).
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Dict, Optional

from repro.core import hlo_cost
from repro.core.daemon import DaemonConfig, Hpcmd, JobManifest
from repro.core.derived import HardwareSpec, TPU_V5E, roofline_terms
from repro.core.sources import (CollectiveSource, DeviceSource, EnvSource,
                                PipelineSource, PipelineStats, ProcSource,
                                StaticStepCost, StepClock, XlaCostSource)


class TrainMonitor:
    """Job-side monitoring harness.

    In-loop (deterministic) mode: call :meth:`on_step` every step; the
    monitor ticks the daemon when the sampling interval elapses.
    Thread mode: :meth:`start` runs the daemon loop in the background.
    """

    def __init__(self, workdir: os.PathLike, manifest: JobManifest,
                 host: Optional[str] = None, interval_s: float = 5.0,
                 hw: HardwareSpec = TPU_V5E, enabled: bool = True,
                 align_to_clock: bool = True) -> None:
        self.enabled = enabled
        self.workdir = Path(workdir)
        self.manifest = manifest
        self.hw = hw
        self.clock = StepClock()
        self.pipeline_stats = PipelineStats()
        host = host or "host0"
        spool_dir = self.workdir / "spool" / host
        cfg = DaemonConfig(interval_s=interval_s,
                           align_to_clock=align_to_clock)
        self.daemon = Hpcmd(spool_dir, cfg, host=host, manifest=manifest)
        self.cost_source = XlaCostSource(self.clock, hw)
        self.daemon.add_source(self.cost_source)
        self.daemon.add_source(DeviceSource())
        self.daemon.add_source(ProcSource())
        self.daemon.add_source(PipelineSource(self.pipeline_stats))
        self.daemon.add_source(EnvSource(extra={
            "app": manifest.app, "shape": manifest.shape,
            "num_hosts": manifest.num_hosts,
            "num_chips": manifest.num_chips,
            "mesh": manifest.mesh_shape}))
        # persist the manifest for the aggregator / scheduler integration
        manifest.save(self.workdir / "manifests" / f"{manifest.job_id}.json")
        self._next_tick = 0.0
        self.static_cost: Optional[StaticStepCost] = None
        self.roofline: Optional[Dict[str, float]] = None

    # ------------------------------------------------------------- compile
    def register_compiled(self, compiled, tokens_per_step: int = 0,
                          num_chips: Optional[int] = None) -> Dict[str, float]:
        """Extract static per-step cost figures from a compiled step.

        Returns the figure dict (also used by the dry-run roofline path).
        """
        chips = num_chips or self.manifest.num_chips
        try:
            text = compiled.as_text()
        except Exception:  # noqa: BLE001 — some backends can't re-serialize
            text = ""
        # loop-aware static analysis (core/hlo_cost.py): exact per-step
        # FLOPs / HBM traffic / collective bytes off the executable.
        cost = hlo_cost.analyze_hlo(text)
        static = StaticStepCost(
            flops=cost.flops, bytes=cost.traffic_bytes,
            collective_bytes=cost.collective_bytes,
            num_chips=chips, tokens_per_step=tokens_per_step)
        self.static_cost = static
        self.cost_source.set_cost(static)
        if self.enabled:
            self.daemon.add_source(CollectiveSource(cost.as_fields()))
        terms = roofline_terms(cost.flops * chips,
                               cost.traffic_bytes * chips,
                               cost.collective_bytes * chips,
                               chips, self.hw)
        self.roofline = terms.as_dict()
        return {"flops": cost.flops, "bytes": cost.traffic_bytes,
                "collective_bytes": cost.collective_bytes,
                **terms.as_dict()}

    def set_static_cost(self, cost: StaticStepCost) -> None:
        """Direct injection (multi-host simulation / tests)."""
        self.static_cost = cost
        self.cost_source.set_cost(cost)

    # ---------------------------------------------------------------- steps
    def on_step(self, step: int, loss: float = float("nan"),
                tokens: int = 0, now: Optional[float] = None) -> None:
        if not self.enabled:
            return
        now = time.time() if now is None else now
        self.clock.record(step, tokens=tokens, loss=loss, ts=now)
        if now >= self._next_tick:
            self.daemon.tick(now)
            self._next_tick = self.daemon.next_sample_time(now)

    def on_batch_fetched(self, tokens: int, wait_s: float) -> None:
        if self.enabled:
            self.pipeline_stats.on_batch(tokens, wait_s)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self.enabled:
            self.daemon.start()

    def stop(self) -> None:
        if self.enabled:
            self.daemon.stop(final_tick=True)

    def suspended(self):
        return self.daemon.suspended()

    def __enter__(self) -> "TrainMonitor":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def load_manifests(workdir: os.PathLike) -> Dict[str, JobManifest]:
    """Read every job manifest the launcher has written under workdir."""
    out: Dict[str, JobManifest] = {}
    mdir = Path(workdir) / "manifests"
    if mdir.is_dir():
        for p in sorted(mdir.glob("*.json")):
            man = JobManifest.load(p)
            if man is not None:
                out[man.job_id] = man
    return out
