"""Deterministic fault injection + robustness primitives (docs/faults.md).

The paper's monitoring pipeline runs continuously on every node of a
production HPC system, where worker crashes, flaky links, and disk
corruption are routine — monitoring is only trustworthy if it survives
the faults it is meant to observe.  This module is the harness that
*proves* the fleet does: a seedable :class:`FaultPlan` draws faults
deterministically per site, :class:`FaultyTransport` injects them into
the wire (drop / delay / truncate / bit-flip), and a process-global
storage hook lets ``segmentio`` tear segment commits or fail seals with
``ENOSPC`` — all without touching production hot paths (the hooks are
single ``None`` checks when no plan is installed).

It also hosts the robustness *primitives* the hardened paths use in
production, kept dependency-free so they are unit-testable with fake
clocks:

:class:`RetryPolicy`
    Capped exponential backoff under an optional deadline budget.
    ``run()`` retries a callable on the given exception types and
    raises :class:`RetryBudgetExceeded` when the next backoff would
    cross the deadline — callers translate that into their own typed
    deadline error.  ``sleep``/``now`` are injectable.

:class:`CircuitBreaker`
    closed → open after N consecutive failures → half-open after a
    reset timeout, with a **single-flight** half-open probe: exactly
    one caller gets through to test the worker; everyone else is
    rejected until the probe's outcome is recorded.

:func:`crc32c`
    The checksum every integrity trailer uses (wire frames, segment
    ``.bin`` payloads, WAL lines).  Uses the C ``crc32c`` extension
    when installed, else ``zlib.crc32`` (also C speed) — the *name* is
    part of the format, the polynomial is pinned per deployment by
    whichever implementation wrote the data, and both sides of every
    checksum here run in the same process tree, so mixing cannot occur.

Everything is deterministic given the seed: the chaos-parity suite
replays fault schedules bit-for-bit, and CI runs fixed seeds.
"""

from __future__ import annotations

import errno
import random
import threading
import time
import zlib
from collections import Counter
from typing import Callable, Dict, Iterable, Optional, Tuple

try:  # optional C extension; zlib.crc32 is the baked-in fallback
    from crc32c import crc32c as _crc32_fn  # type: ignore
    CRC_IMPL = "crc32c"
except ImportError:  # pragma: no cover - environment-dependent
    _crc32_fn = zlib.crc32
    CRC_IMPL = "crc32-zlib"


def crc32c(data, value: int = 0) -> int:
    """Checksum used by every integrity trailer (see module docstring).
    Incremental: pass the previous value to continue over chunks."""
    return _crc32_fn(data, value) & 0xFFFFFFFF


# ===========================================================================
# Fault plans
# ===========================================================================

#: wire fault kinds a transport site may draw
WIRE_FAULTS = ("drop", "delay", "truncate", "bitflip")
#: storage fault kinds the ``seal`` site may draw
SEAL_FAULTS = ("enospc", "torn_bin", "torn_manifest")


class FaultPlan:
    """A deterministic, seedable schedule of faults.

    ``rates`` maps an injection *site* (``"send"``, ``"recv"``,
    ``"seal"``) to ``{fault kind: probability}``; every :meth:`draw`
    consults the site's rates against one PRNG stream derived from
    ``seed``, so the same seed replays the same fault sequence for the
    same sequence of draws.  :meth:`force` enqueues scripted one-shot
    faults that fire before any probabilistic draw — unit tests use it
    to place exactly one fault at exactly one site.

    Thread-safe: the coordinator's pooled connections draw from one
    plan concurrently; the lock keeps the PRNG stream and the injected
    counters coherent (the *interleaving* across threads is scheduling-
    dependent, but single-threaded chaos suites are fully
    deterministic).
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, Dict[str, float]]] = None,
                 delay_range_s: Tuple[float, float] = (0.0005, 0.005)
                 ) -> None:
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self.rates = {site: dict(kinds)
                      for site, kinds in (rates or {}).items()}
        self.delay_range_s = (float(delay_range_s[0]),
                              float(delay_range_s[1]))
        self._forced: Dict[str, list] = {}
        self.injected: Counter = Counter()

    def force(self, site: str, kind: str, times: int = 1) -> None:
        """Queue ``times`` scripted faults at ``site`` — consumed by
        the next draws there, ahead of any probabilistic fault."""
        with self._lock:
            self._forced.setdefault(site, []).extend([kind] * int(times))

    def draw(self, site: str) -> Optional[str]:
        """The fault to inject at ``site`` now, or ``None``."""
        with self._lock:
            queue = self._forced.get(site)
            if queue:
                kind = queue.pop(0)
                self.injected[(site, kind)] += 1
                return kind
            kinds = self.rates.get(site)
            if not kinds:
                return None
            r = self._rng.random()
            acc = 0.0
            for kind, p in kinds.items():
                acc += p
                if r < acc:
                    self.injected[(site, kind)] += 1
                    return kind
            return None

    def delay_s(self) -> float:
        lo, hi = self.delay_range_s
        with self._lock:
            return lo + (hi - lo) * self._rng.random()

    def randrange(self, n: int) -> int:
        with self._lock:
            return self._rng.randrange(n)

    def corrupt(self, data: bytes, skip: int = 0) -> bytes:
        """Flip one random bit of ``data`` (beyond the first ``skip``
        bytes).  Transports skip the 4-byte length header: a corrupted
        *length* turns an integrity fault into a framing stall, which
        is a different site (``truncate``/``drop`` cover it)."""
        if len(data) <= skip:
            return data
        i = skip + self.randrange(len(data) - skip)
        bit = 1 << self.randrange(8)
        out = bytearray(data)
        out[i] ^= bit
        return bytes(out)

    def injected_total(self) -> int:
        with self._lock:
            return sum(self.injected.values())


class FaultyTransport:
    """Socket wrapper that injects :class:`FaultPlan` wire faults.

    Wraps the client-side socket of a ``WorkerClient`` (the server side
    of the same link is exercised symmetrically — a fault on ``send``
    corrupts what the worker reads, a fault on ``recv`` corrupts what
    the coordinator decodes).  Fault kinds:

    ``drop``      close the socket instead of transferring — the peer
                  sees EOF, this side gets ``OSError`` (connection
                  reset semantics).
    ``delay``     sleep a bounded random interval, then transfer.
    ``truncate``  transfer a strict prefix, then close — a torn frame.
    ``bitflip``   transfer everything with one bit flipped (header
                  bytes exempt) — caught by the frame checksum.

    Only the data-path calls (``sendall``/``recv``) inject; everything
    else proxies to the real socket, so timeouts, ``fileno()`` (the
    hedged-scatter ``select``), and options behave normally.
    """

    def __init__(self, sock, plan: FaultPlan) -> None:
        self._sock = sock
        self._plan = plan

    # ------------------------------------------------------------ injection --
    def sendall(self, data: bytes) -> None:
        kind = self._plan.draw("send")
        if kind == "drop":
            self.close()
            raise OSError(errno.ECONNRESET, "injected send drop")
        if kind == "delay":
            time.sleep(self._plan.delay_s())
        elif kind == "truncate" and len(data) > 1:
            self._sock.sendall(data[:self._plan.randrange(len(data))
                                    or 1])
            self.close()
            raise OSError(errno.ECONNRESET, "injected send truncation")
        elif kind == "bitflip":
            data = self._plan.corrupt(data, skip=4)
        self._sock.sendall(data)

    def recv(self, n: int) -> bytes:
        kind = self._plan.draw("recv")
        if kind == "drop":
            self.close()
            raise OSError(errno.ECONNRESET, "injected recv drop")
        if kind == "delay":
            time.sleep(self._plan.delay_s())
        chunk = self._sock.recv(n)
        if kind == "truncate" and chunk:
            prefix = chunk[:self._plan.randrange(len(chunk)) or 1]
            self.close()
            return prefix  # EOF follows: peer reads a torn frame
        if kind == "bitflip" and chunk:
            if len(chunk) > 4:
                chunk = self._plan.corrupt(chunk, skip=4)
            else:
                # ``recv_exact`` reads the 4-byte length word (and crc
                # trailer) as its own recv call, so a flip here would
                # corrupt the *length* — a framing stall only the op
                # deadline can catch, which is the ``truncate``/``drop``
                # site's job (see :meth:`FaultPlan.corrupt`).  Re-arm
                # the fault so it lands on a checksummable payload read,
                # mirroring the send-side header exemption.
                self._plan.force("recv", "bitflip")
        return chunk

    # -------------------------------------------------------------- passthru --
    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __getattr__(self, name):
        return getattr(self._sock, name)


# ===========================================================================
# Storage fault hook
# ===========================================================================
#
# segmentio consults this module-global before tearing into a segment
# commit.  The cost on the production path is one attribute read and a
# None check per *seal* (not per row); installing a plan is strictly a
# test/bench/worker-op action.

_storage_plan: Optional[FaultPlan] = None


def install_storage_faults(plan: Optional[FaultPlan]) -> None:
    """Install (or with ``None`` clear) the process-global storage
    fault plan consulted by ``segmentio.save_segment``."""
    global _storage_plan
    _storage_plan = plan


def storage_fault(site: str) -> Optional[str]:
    plan = _storage_plan
    if plan is None:
        return None
    return plan.draw(site)


def enospc(path) -> OSError:
    exc = OSError(errno.ENOSPC, "No space left on device (injected)")
    exc.filename = str(path)
    return exc


# ===========================================================================
# Retry with capped exponential backoff under a deadline budget
# ===========================================================================


class RetryBudgetExceeded(TimeoutError):
    """The next backoff would cross the op's deadline budget.  Callers
    translate this into their own typed deadline error (the remote tier
    raises ``DeadlineExceeded``)."""


class RetryPolicy:
    """Capped exponential backoff: attempt ``k`` (0-based) sleeps
    ``min(base * multiplier**k, max)`` before retrying.  ``deadline_s``
    bounds the whole ``run()`` — when the next backoff would cross it,
    :class:`RetryBudgetExceeded` is raised *instead of sleeping*, so an
    op never overstays its budget just to fail again.  Stateless config
    (safe to share across shards); ``sleep``/``now`` are injectable for
    fake-clock tests."""

    def __init__(self, max_attempts: int = 3,
                 base_delay_s: float = 0.02,
                 max_delay_s: float = 0.25,
                 multiplier: float = 2.0,
                 deadline_s: Optional[float] = None,
                 sleep: Callable[[float], None] = time.sleep,
                 now: Callable[[], float] = time.monotonic) -> None:
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.max_attempts = int(max_attempts)
        self.base_delay_s = float(base_delay_s)
        self.max_delay_s = float(max_delay_s)
        self.multiplier = float(multiplier)
        self.deadline_s = deadline_s
        self.sleep = sleep
        self.now = now

    def backoff_s(self, attempt: int) -> float:
        """Backoff after 0-based ``attempt`` failed."""
        return min(self.base_delay_s * self.multiplier ** attempt,
                   self.max_delay_s)

    def run(self, fn: Callable, retry_on: Tuple[type, ...],
            deadline_s: Optional[float] = None):
        """Call ``fn`` until it returns, a non-retryable exception
        escapes, attempts are exhausted (the last exception re-raises),
        or the deadline budget is hit (:class:`RetryBudgetExceeded`)."""
        budget = self.deadline_s if deadline_s is None else deadline_s
        deadline = None if budget is None else self.now() + float(budget)
        attempt = 0
        while True:
            try:
                return fn()
            except retry_on as exc:
                attempt += 1
                if attempt >= self.max_attempts:
                    raise
                delay = self.backoff_s(attempt - 1)
                if deadline is not None and \
                        self.now() + delay > deadline:
                    raise RetryBudgetExceeded(
                        f"retry budget exhausted after {attempt} "
                        f"attempt(s): {exc}") from exc
                self.sleep(delay)


# ===========================================================================
# Circuit breaker
# ===========================================================================


class CircuitBreaker:
    """Per-worker circuit breaker: closed → open after
    ``failure_threshold`` *consecutive* failures → half-open after
    ``reset_timeout_s``, where exactly **one** probe is allowed through
    (single-flight); the probe's success closes the circuit, its
    failure re-opens it for another full timeout.

    The breaker only *gates* (:meth:`allow`) and *observes*
    (:meth:`record_success` / :meth:`record_failure`); the caller
    raises its own typed error on rejection (the remote tier raises
    ``CircuitOpen``, a ``WorkerUnavailable`` subclass, so replica-set
    failover and degraded reads treat an open circuit exactly like a
    dead worker — fail fast, no connect attempt).  ``now`` is
    injectable for fake-clock tests."""

    def __init__(self, failure_threshold: int = 5,
                 reset_timeout_s: float = 1.0,
                 now: Callable[[], float] = time.monotonic) -> None:
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = int(failure_threshold)
        self.reset_timeout_s = float(reset_timeout_s)
        self.now = now
        self._lock = threading.Lock()
        self.state = "closed"
        self.failures = 0            # consecutive
        self.opened_at: Optional[float] = None
        self._probing = False
        self.opens = 0               # times the circuit tripped
        self.rejections = 0          # calls refused while open/probing

    def allow(self) -> bool:
        """Whether a call may proceed now.  In half-open state, the
        first caller becomes the single-flight probe; concurrent
        callers are rejected until the probe reports back."""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                if (self.opened_at is not None and
                        self.now() - self.opened_at >=
                        self.reset_timeout_s):
                    self.state = "half_open"
                    self._probing = True
                    return True
                self.rejections += 1
                return False
            # half_open
            if self._probing:
                self.rejections += 1
                return False
            self._probing = True
            return True

    def record_success(self) -> None:
        with self._lock:
            self.state = "closed"
            self.failures = 0
            self._probing = False
            self.opened_at = None

    def record_abort(self) -> None:
        """The gated call was abandoned without learning anything about
        the worker (e.g. a scatter aborted mid-merge because *another*
        shard failed): release the single-flight probe slot without
        counting a success or failure.  A half-open circuit returns to
        open (fresh timeout) so the next probe is again single-flight —
        without this, an abandoned probe would reject callers forever."""
        with self._lock:
            self._probing = False
            if self.state == "half_open":
                self.state = "open"
                self.opened_at = self.now()

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            self._probing = False
            if (self.state == "half_open"
                    or self.failures >= self.failure_threshold):
                if self.state != "open":
                    self.opens += 1
                self.state = "open"
                self.opened_at = self.now()

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"state": self.state,
                    "consecutive_failures": self.failures,
                    "opens": self.opens,
                    "rejections": self.rejections}


def sum_breaker_stats(snaps: Iterable[Dict[str, object]]
                      ) -> Dict[str, int]:
    """Fleet-level rollup of breaker snapshots (explain/stats)."""
    out = {"breakers": 0, "open": 0, "half_open": 0,
           "opens": 0, "rejections": 0}
    for s in snaps:
        out["breakers"] += 1
        st = s.get("state")
        if st == "open":
            out["open"] += 1
        elif st == "half_open":
            out["half_open"] += 1
        out["opens"] += int(s.get("opens", 0))
        out["rejections"] += int(s.get("rejections", 0))
    return out


def breaker_telemetry_samples(snaps: Iterable[Dict[str, object]]
                              ) -> Dict[str, float]:
    """Breaker snapshots as pull-collector samples for a telemetry
    ``Registry`` (``breaker.*`` dotted names).  Same rollup as
    :func:`sum_breaker_stats` — one source of truth for explain()
    output, fleet dashboards and the self-ingested ``_telemetry``
    stream."""
    agg = sum_breaker_stats(snaps)
    return {"breaker." + k: float(v) for k, v in agg.items()}
