"""Streaming statistical anomaly detection — the paper's §4.6 outlook
("automatic analysis using machine learning techniques is under
development", citing Borghesi et al. online anomaly detection), built out.

Two O(1)-memory detectors per (job, host, metric) stream:

* :class:`EwmaDetector` — exponentially-weighted mean/variance; flags
  samples with |z| above a threshold after a warmup period.  Catches
  sudden regressions (a node whose GFLOP/s halves after a failover).
* :class:`CusumDetector` — two-sided CUSUM changepoint statistic on the
  EWMA-normalized residuals; catches slow drifts that never produce a
  single outlier sample (e.g. creeping input-pipeline stalls).

:class:`AnomalyBank` attaches to the aggregator like the rule-based
:class:`~repro.core.detectors.DetectorBank` and emits the same
:class:`DetectorEvent` records, so the elastic supervisor and the reports
consume both uniformly.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.detectors import DetectorEvent
from repro.core.schema import MetricRecord


class EwmaDetector:
    """Per-stream EWMA mean/var with z-score alarms."""

    __slots__ = ("alpha", "z_thresh", "warmup", "n", "mean", "var")

    def __init__(self, alpha: float = 0.15, z_thresh: float = 4.0,
                 warmup: int = 8) -> None:
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.warmup = warmup
        self.n = 0
        self.mean = 0.0
        self.var = 0.0

    def update(self, x: float) -> Optional[float]:
        """Feed one sample; returns the z-score if anomalous else None."""
        self.n += 1
        if self.n == 1:
            self.mean = x
            return None
        resid = x - self.mean
        std = math.sqrt(self.var) if self.var > 0 else 0.0
        z = resid / std if std > 1e-12 else 0.0
        # update AFTER scoring so the anomaly does not mask itself
        self.mean += self.alpha * resid
        self.var = (1 - self.alpha) * (self.var + self.alpha * resid ** 2)
        if self.n > self.warmup and abs(z) >= self.z_thresh:
            return z
        return None


class CusumDetector:
    """Two-sided CUSUM on standardized residuals (drift detection)."""

    __slots__ = ("k", "h", "pos", "neg", "ewma")

    def __init__(self, k: float = 0.5, h: float = 8.0,
                 alpha: float = 0.1) -> None:
        self.k = k          # slack (in std units)
        self.h = h          # alarm threshold (in std units)
        self.pos = 0.0
        self.neg = 0.0
        self.ewma = EwmaDetector(alpha=alpha, z_thresh=float("inf"))

    def update(self, x: float) -> Optional[str]:
        e = self.ewma
        e.n += 1
        if e.n == 1:
            e.mean = x
            return None
        std = math.sqrt(e.var) if e.var > 0 else 0.0
        z = (x - e.mean) / std if std > 1e-12 else 0.0
        resid = x - e.mean
        e.mean += e.alpha * resid
        e.var = (1 - e.alpha) * (e.var + e.alpha * resid ** 2)
        if e.n <= 8:
            return None
        self.pos = max(0.0, self.pos + z - self.k)
        self.neg = max(0.0, self.neg - z - self.k)
        if self.pos > self.h:
            self.pos = 0.0
            return "upward-drift"
        if self.neg > self.h:
            self.neg = 0.0
            return "downward-drift"
        return None


DEFAULT_METRICS = ("gflops", "step_time_s", "hbm_gbs", "input_stall_frac")


@dataclass
class AnomalyBank:
    """Streaming per-(job, host, metric) anomaly detection."""

    metrics: Tuple[str, ...] = DEFAULT_METRICS
    z_thresh: float = 4.0
    events: List[DetectorEvent] = field(default_factory=list)
    _ewma: Dict[Tuple[str, str, str], EwmaDetector] = field(
        default_factory=dict)
    _cusum: Dict[Tuple[str, str, str], CusumDetector] = field(
        default_factory=dict)

    def feed(self, rec: MetricRecord) -> List[DetectorEvent]:
        out: List[DetectorEvent] = []
        for metric in self.metrics:
            v = rec.get(metric)
            if not isinstance(v, (int, float)):
                continue
            key = (rec.job, rec.host, metric)
            ew = self._ewma.setdefault(
                key, EwmaDetector(z_thresh=self.z_thresh))
            z = ew.update(float(v))
            if z is not None:
                out.append(DetectorEvent(
                    ts=rec.ts, job=rec.job, detector="ewma_anomaly",
                    severity="warning",
                    message=(f"{metric} on {rec.host} deviates "
                             f"{z:+.1f} sigma from its EWMA baseline "
                             f"(value {v:.4g}, mean {ew.mean:.4g})"),
                    fields={"host": rec.host, "metric": metric,
                            "z": round(z, 2), "value": float(v)}))
            cs = self._cusum.setdefault(key, CusumDetector())
            drift = cs.update(float(v))
            if drift is not None:
                out.append(DetectorEvent(
                    ts=rec.ts, job=rec.job, detector="cusum_drift",
                    severity="info",
                    message=(f"{metric} on {rec.host} shows sustained "
                             f"{drift} vs its baseline"),
                    fields={"host": rec.host, "metric": metric,
                            "direction": drift}))
        self.events.extend(out)
        return out
