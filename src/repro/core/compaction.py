"""Segment compaction, cold-tier compression, and retention rollups.

The paper's Splunk backend stays interactive over months of per-job
data because its indexes *age*: fresh events live in small hot buckets,
then roll to large warm/cold buckets, and summary indexing keeps
fleet-wide dashboards off the raw events entirely (§4.3).  Our columnar
store seals one segment per ``seal_threshold`` records, so a streaming
fleet becomes file-count-bound long before it is bandwidth-bound: a
cold query pays a manifest load, an mmap and per-segment planner
overhead for every tiny seal.  This module adds the aging machinery:

* :class:`Compactor.compact` merges runs of small, time-adjacent sealed
  segments into large ones — string dictionaries re-encoded, zone maps
  rebuilt, the content-derived ``Segment.uid`` recomputed from the
  union of the inputs' dedup keys (so the same rows always produce the
  same uid, wherever compacted).  Durable stores write the merged
  segment with the **cold-tier** compressed encoding
  (``segmentio.save_segment(compress=True)``) and then atomically swap:
  the merged manifest — carrying a ``replaces`` list naming the retired
  stems — is the commit point; retired file pairs are deleted after
  (manifest first, then data).  A crash anywhere in the window leaves
  either the old segments (merged ``.bin`` orphaned, invisible) or
  both (the loader skips and deletes the replaced stems).  Retired
  uids are dropped from the :class:`PartialAggregateCache`; the merged
  uid warms on first touch.

* :class:`Compactor.apply_retention` builds time-bucketed **rollup
  segments** (raw → 1m → 1h, mirroring Splunk summary indexing): one
  row per ``(bucket, host, job, kind)`` holding mergeable
  partial-aggregate columns (count / numeric count / sum / min / max /
  M2) per metric field.  The incremental query planner substitutes
  them for the raw segments they cover when — and only when — the plan
  is provably answerable from buckets (docs/storage.md lists the
  eligibility rules).  With ``raw_max_age_s`` set, raw segments old
  enough *and* covered by a rollup are dropped entirely — the
  retention trade: row-level reads over that range are gone, bucketed
  aggregates remain.

Both operations refuse ``read_only`` stores and bump the store's
mutation generation (``_version()``), so remote etag caches can never
serve pre-compaction replies for post-compaction state.
"""

from __future__ import annotations

import functools
import hashlib
import math
import time
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.columnar import (ColumnarMetricStore, NumColumn, Segment,
                                 StrColumn, _segment_logical_bytes,
                                 _stem_seqs, merge_transient_segments,
                                 segment_uid)

# Rollup segments store partial-aggregate stat columns under reserved
# names; ``__ru_rows__`` is the per-bucket row count (plain `count`).
ROLLUP_ROWS = "__ru_rows__"
ROLLUP_STATS = ("cnt", "num", "sum", "min", "max", "m2")
ROLLUP_DIMS = ("host", "job", "kind")


def rollup_stat_col(stat: str, field: str) -> str:
    return f"__ru_{stat}__{field}"


def _seg_keys(seg: Segment) -> Optional[Set[bytes]]:
    """A sealed segment's dedup keys: stashed at seal for in-process
    segments, read from the manifest for mapped ones."""
    keys = getattr(seg, "_keys", None)
    if keys is not None:
        return set(keys)
    reader = getattr(seg, "dedup_keys", None)
    if reader is not None:
        return set(reader())
    return None


def _seg_bytes(seg: Segment) -> int:
    man = getattr(seg, "_man", None)
    if man is not None:
        return int(man.get("bin_bytes", 0))
    return _segment_logical_bytes(seg)


def rollup_safe(seg: Segment) -> bool:
    """A raw segment is rollup-eligible only when no metric field
    shadows a reserved attribute: the rollup's bucket keys come from
    the query *view* columns, and a shadowed ``ts``/dim can be missing
    or non-string per row, which bucket rows cannot represent."""
    return not any(k in seg.field_names for k in ("ts",) + ROLLUP_DIMS)


def rollup_uid(gran: float, covers: Sequence[str]) -> str:
    """Content-derived rollup identity: a pure function of the
    granularity and the covered segments' uids, so rebuilding the same
    rollup anywhere yields the same uid (cache-key semantics match
    :func:`repro.core.columnar.segment_uid`)."""
    canon = ("rollup", float(gran), tuple(sorted(covers)))
    return hashlib.blake2b(repr(canon).encode("utf-8"),
                           digest_size=16).hexdigest()


def build_rollup(segs: Sequence[Segment], gran: float
                 ) -> Optional[Segment]:
    """One rollup segment over ``segs``: a row per
    ``(bucket, host, job, kind)`` with partial-aggregate stat columns
    per metric field.  Fields with an object-typed column anywhere in
    the inputs cannot be aggregated from buckets and are recorded in
    ``rollup["excluded"]`` (a plan touching them falls back to raw).
    Returns ``None`` when the inputs hold no rows."""
    gran = float(gran)
    total = int(sum(s.n for s in segs))
    if total == 0 or gran <= 0:
        return None
    # ---- gather bucket + dim keys across segments -----------------------
    ts = np.concatenate([s.attrs["ts"].vals for s in segs])
    bucket = np.floor(ts / gran) * gran
    ub, binv = np.unique(bucket, return_inverse=True)
    dim_codes: List[np.ndarray] = []
    dim_indexes: List[Dict[str, int]] = []
    for dim in ROLLUP_DIMS:
        index: Dict[str, int] = {}
        codes = np.empty(total, np.int64)
        pos = 0
        for s in segs:
            col = s.attrs[dim]
            remap = (np.array([index.setdefault(v, len(index))
                               for v in col.vocab.tolist()], np.int64)
                     if len(col.vocab) else np.empty(0, np.int64))
            codes[pos:pos + s.n] = remap[col.codes]
            pos += s.n
        dim_codes.append(codes)
        dim_indexes.append(index)
    sizes = [len(ub)] + [max(len(ix), 1) for ix in dim_indexes]
    combined = binv.astype(np.int64)
    for codes, size in zip(dim_codes, sizes[1:]):
        combined = combined * size + codes
    uniq, inv = np.unique(combined, return_inverse=True)
    G = len(uniq)
    # decompose group tokens back into per-key indices (bucket index is
    # the most significant digit, so groups come out time-sorted — the
    # Segment invariant)
    token = uniq.copy()
    key_idx: List[np.ndarray] = []
    for size in reversed(sizes[1:]):
        key_idx.append(token % size)
        token //= size
    key_idx.append(token)
    key_idx.reverse()  # [bucket, host, job, kind]
    attrs: Dict[str, object] = {
        "ts": NumColumn(ub[key_idx[0]], np.ones(G, bool),
                        np.zeros(G, bool))}
    for j, dim in enumerate(ROLLUP_DIMS):
        index = dim_indexes[j]
        vocab = np.array(list(index), dtype=object)
        attrs[dim] = StrColumn(key_idx[j + 1].astype(np.int32), vocab,
                               dict(index))
    # ---- per-field partial-aggregate columns ----------------------------
    names: Dict[str, None] = {}
    for s in segs:
        for k in s.field_names:
            names.setdefault(k)
    excluded: List[str] = []
    field_cols: Dict[str, object] = {}
    ones = np.ones(G, bool)
    zeros_b = np.zeros(G, bool)
    for fname in names:
        kinds = {s.cols[fname].kind for s in segs if fname in s.cols}
        if "obj" in kinds:
            excluded.append(fname)
            continue
        present = np.zeros(total, bool)
        numeric = np.zeros(total, bool)
        vals = np.zeros(total)
        pos = 0
        for s in segs:
            col = s.cols.get(fname) if fname in set(s.field_names) else None
            if col is not None:
                if col.kind == "num":
                    p = col.present
                    nm = p & ~np.isnan(col.vals)
                    present[pos:pos + s.n] = p
                    numeric[pos:pos + s.n] = nm
                    vals[pos:pos + s.n] = np.where(nm, col.vals, 0.0)
                else:  # str: present, never numeric
                    present[pos:pos + s.n] = col.codes >= 0
            pos += s.n
        cnt = np.bincount(inv[present], minlength=G).astype(float)
        ngids = inv[numeric]
        nvals = vals[numeric]
        num = np.bincount(ngids, minlength=G).astype(float)
        sums = (np.bincount(ngids, weights=nvals, minlength=G)
                if ngids.size else np.zeros(G))
        mins = np.full(G, np.inf)
        maxs = np.full(G, -np.inf)
        if ngids.size:
            np.minimum.at(mins, ngids, nvals)
            np.maximum.at(maxs, ngids, nvals)
        means = sums / np.maximum(num, 1)
        m2 = (np.bincount(ngids, weights=(nvals - means[ngids]) ** 2,
                          minlength=G) if ngids.size else np.zeros(G))
        has_num = num > 0
        field_cols[rollup_stat_col("cnt", fname)] = \
            NumColumn(cnt, ones, ones.copy())
        field_cols[rollup_stat_col("num", fname)] = \
            NumColumn(num, ones, ones.copy())
        field_cols[rollup_stat_col("sum", fname)] = \
            NumColumn(sums, ones, zeros_b.copy())
        field_cols[rollup_stat_col("min", fname)] = \
            NumColumn(np.where(has_num, mins, np.nan), has_num,
                      zeros_b.copy())
        field_cols[rollup_stat_col("max", fname)] = \
            NumColumn(np.where(has_num, maxs, np.nan), has_num,
                      zeros_b.copy())
        field_cols[rollup_stat_col("m2", fname)] = \
            NumColumn(m2, ones, zeros_b.copy())
    field_cols[ROLLUP_ROWS] = NumColumn(
        np.bincount(inv, minlength=G).astype(float), ones, ones.copy())
    out = Segment(G, attrs, field_cols)
    covers = sorted(s.uid for s in segs if s.uid is not None)
    out.tier = f"rollup-{gran:g}"
    out.rollup = {"gran": gran, "covers": covers,
                  "excluded": sorted(excluded)}
    out.uid = rollup_uid(gran, covers)
    return out


class Compactor:
    """Compaction + retention over one :class:`ColumnarMetricStore`.

    Stateless apart from the store reference; aggregators construct one
    per call (``store.compact(...)`` / ``store.apply_retention(...)``
    delegate here).  Refuses read-only stores — a degraded-mode
    coordinator inspecting a dead worker's directory must never rewrite
    it under the worker's feet.
    """

    def __init__(self, store: ColumnarMetricStore) -> None:
        if getattr(store, "read_only", False):
            raise RuntimeError("compaction refused: store is read-only")
        self.store = store

    # ---------------------------------------------------------- compact --
    def compact(self, small_rows: int = 4096, target_rows: int = 65536,
                min_run: int = 2, compress: bool = True) -> Dict:
        """Merge consecutive runs of small sealed segments.

        A sealed segment with fewer than ``small_rows`` rows joins the
        current run; a run seals at ``target_rows`` merged rows and is
        only merged at all when it has at least ``min_run`` members.
        Durable stores persist merged segments compressed
        (``compress=True`` → cold tier) and atomically swap the files;
        memory-only stores just swap the in-memory list.  Returns (and
        records as ``store.last_compaction``) a stats dict including
        ``retired_uids`` — the remote tier forwards those to the
        coordinator so its decoded-scatter memos are dropped too.
        """
        store = self.store
        t0 = time.monotonic()
        small_rows = int(small_rows)
        target_rows = int(target_rows)
        min_run = max(2, int(min_run))
        # A raw segment referenced by any rollup's ``covers`` must keep
        # its uid: merging it would mint a new uid the rollup doesn't
        # know, so the planner could no longer prove the rollup and the
        # live segment set are disjoint (and a retention drop of the
        # old uid would then lose rows).  Such segments are pinned
        # until retention retires them.
        covered: set = set()
        for rseg in getattr(store, "_rollups", ()):
            covered.update((rseg.rollup or {}).get("covers", ()))
        runs: List[List[int]] = []
        run: List[int] = []
        run_rows = 0
        for i, seg in enumerate(store._sealed):
            mergeable = (seg.n < small_rows
                         and _seg_keys(seg) is not None
                         and seg.uid not in covered)
            if mergeable and run_rows + seg.n > target_rows and run:
                if len(run) >= min_run:
                    runs.append(run)
                run, run_rows = [], 0
            if mergeable:
                run.append(i)
                run_rows += seg.n
            else:
                if len(run) >= min_run:
                    runs.append(run)
                run, run_rows = [], 0
        if len(run) >= min_run:
            runs.append(run)
        stats: Dict = {
            "runs": len(runs), "segments_merged": 0, "segments_created": 0,
            "rows": 0, "retired_uids": [], "bytes_before": 0,
            "bytes_after": 0,
        }
        seg_dir = (store.directory / "segments"
                   if store.directory is not None else None)
        for run in reversed(runs):  # reverse: earlier indices stay valid
            segs = [store._sealed[i] for i in run]
            stems = [store._sealed_stems[i] for i in run]
            key_union: Set[bytes] = set()
            for s in segs:
                key_union |= _seg_keys(s)
            merged = functools.reduce(merge_transient_segments, segs)
            merged.uid = segment_uid(key_union)
            merged._keys = frozenset(key_union)
            bytes_before = sum(_seg_bytes(s) for s in segs)
            new_stem = None
            if seg_dir is not None:
                from repro.core import segmentio
                first = _stem_seqs(stems[0])
                mint = store._next_seq
                new_stem = "seg-{:08d}-m{:08d}".format(
                    first[0] if first else mint, mint)
                man_path = segmentio.save_segment(
                    seg_dir, new_stem, merged, key_union,
                    compress=compress, fsync=True,
                    extra={"replaces": [s for s in stems if s is not None]})
                # swap in the mapped (lazily decoded) form — frees the
                # small in-memory segments and exercises the exact
                # restart read path
                merged = segmentio.load_segment(man_path)
            store._next_seq += 1  # mutation generation
            store._sealed[run[0]:run[-1] + 1] = [merged]
            store._sealed_stems[run[0]:run[-1] + 1] = [new_stem]
            for s in segs:
                if s.uid is not None:
                    store.partial_cache.drop_segment(s.uid)
                    stats["retired_uids"].append(s.uid)
            if seg_dir is not None:
                from repro.core import segmentio
                # retire inputs: manifest first (uncommits), then data
                for stem in stems:
                    if stem is None:
                        continue
                    for suffix in (".json", ".bin"):
                        try:
                            (seg_dir / (stem + suffix)).unlink()
                        except OSError:
                            pass
                segmentio.fsync_dir(seg_dir)
            stats["segments_merged"] += len(segs)
            stats["segments_created"] += 1
            stats["rows"] += merged.n
            stats["bytes_before"] += bytes_before
            stats["bytes_after"] += _seg_bytes(merged)
        if runs:
            store._cache.clear()
        stats["segment_count"] = len(store._sealed)
        stats["duration_s"] = round(time.monotonic() - t0, 6)
        store.last_compaction = stats
        tel = getattr(store, "telemetry", None)
        if tel is not None:
            tel.registry.counter("compaction.runs").inc()
            tel.registry.counter("compaction.segments_merged").inc(
                stats["segments_merged"])
            tel.registry.counter("compaction.segments_created").inc(
                stats["segments_created"])
            tel.registry.counter("compaction.bytes_reclaimed").inc(
                max(0, stats["bytes_before"] - stats["bytes_after"]))
            tel.registry.histogram("compaction.duration_s").observe(
                stats["duration_s"])
        return stats

    # -------------------------------------------------------- retention --
    def apply_retention(self,
                        rollups: Sequence = ((60.0, 0.0), (3600.0, 0.0)),
                        raw_max_age_s: Optional[float] = None) -> Dict:
        """Build missing rollup tiers; optionally drop covered raw.

        ``rollups`` — ``(granularity_s, min_age_s)`` pairs (bare floats
        mean age 0): sealed raw segments whose newest timestamp is at
        least ``min_age_s`` behind the store watermark, and that no
        existing rollup of that granularity covers, are bucketed into
        one new rollup segment per granularity.  Tiers are built
        coarsest-independent (each rolls the raw directly, so 1m and 1h
        tiers are both exact).  ``raw_max_age_s`` — when set, raw
        segments older than this *and* covered by at least one rollup
        are deleted (files too); their bucketed aggregates remain
        queryable, their rows are gone.
        """
        store = self.store
        t0 = time.monotonic()
        stats: Dict = {"rollups_created": 0, "rollup_rows": 0,
                       "covered_segments": 0, "dropped_segments": 0,
                       "dropped_rows": 0}
        wm = store._watermark
        changed = False
        seg_dir = (store.directory / "segments"
                   if store.directory is not None else None)
        for tier in rollups:
            gran, min_age = ((float(tier), 0.0)
                             if isinstance(tier, (int, float))
                             else (float(tier[0]), float(tier[1])))
            covered: Set[str] = set()
            for rseg in store._rollups:
                if float(rseg.rollup["gran"]) == gran:
                    covered.update(rseg.rollup.get("covers", ()))
            cands = [seg for seg in store._sealed
                     if seg.uid is not None and seg.uid not in covered
                     and wm - seg.ts_max >= min_age and rollup_safe(seg)]
            if not cands:
                continue
            rseg = build_rollup(cands, gran)
            if rseg is None:
                continue
            stem = None
            if seg_dir is not None:
                from repro.core import segmentio
                mint = store._next_seq
                stem = "seg-{0:08d}-m{0:08d}".format(mint)
                segmentio.save_segment(
                    seg_dir, stem, rseg, (), compress=True, fsync=True,
                    extra={"tier": rseg.tier, "rollup": rseg.rollup})
            store._next_seq += 1
            store._rollups.append(rseg)
            store._rollup_stems.append(stem)
            stats["rollups_created"] += 1
            stats["rollup_rows"] += rseg.n
            stats["covered_segments"] += len(cands)
            changed = True
        if raw_max_age_s is not None:
            all_covered: Set[str] = set()
            for rseg in store._rollups:
                all_covered.update(rseg.rollup.get("covers", ()))
            for i in range(len(store._sealed) - 1, -1, -1):
                seg = store._sealed[i]
                if seg.uid is None or seg.uid not in all_covered:
                    continue
                if not (wm - seg.ts_max >= float(raw_max_age_s)):
                    continue
                store._sealed.pop(i)
                stem = store._sealed_stems.pop(i)
                store.partial_cache.drop_segment(seg.uid)
                if seg_dir is not None and stem is not None:
                    for suffix in (".json", ".bin"):
                        try:
                            (seg_dir / (stem + suffix)).unlink()
                        except OSError:
                            pass
                stats["dropped_segments"] += 1
                stats["dropped_rows"] += seg.n
                changed = True
            if stats["dropped_segments"] and seg_dir is not None:
                from repro.core import segmentio
                segmentio.fsync_dir(seg_dir)
        if changed:
            store._cache.clear()
        stats["duration_s"] = round(time.monotonic() - t0, 6)
        tel = getattr(store, "telemetry", None)
        if tel is not None:
            tel.registry.counter("retention.passes").inc()
            tel.registry.counter("retention.rollups_created").inc(
                stats["rollups_created"])
            tel.registry.counter("retention.dropped_segments").inc(
                stats["dropped_segments"])
            tel.registry.counter("retention.dropped_rows").inc(
                stats["dropped_rows"])
        return stats
