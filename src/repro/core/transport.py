"""Log transport — the rsyslog analog (paper §4.3).

Design goals copied from the paper: deliberately boring, text-based,
no custom hierarchical agents *required* — but per-"island" relays are
supported for large systems (the paper deploys intermediate rsyslog
servers per island).  Properties:

* append-only segment files with size-based rotation on the node side,
* at-least-once shipping with durable offsets (a shipper crash replays
  the tail; the aggregator tolerates duplicate lines),
* strictly line-oriented: a torn final line is never forwarded until the
  newline arrives.

All offsets are **byte** offsets: files are read in binary and decoded
per complete line, so multi-byte UTF-8 in metric fields can never drift
an offset against ``stat().st_size`` (reading decoded text and advancing
by character counts did exactly that, silently duplicating or truncating
lines).  UTF-8 never embeds ``0x0A`` in a multi-byte sequence, so
splitting on newlines before decoding is always safe.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional

SEGMENT_FMT = "segment-{:08d}.log"


class Spool:
    """Node-local append-only spool with size-based segment rotation.

    The rotation check uses a stat-seeded byte counter, not
    ``fh.tell()``: a freshly reopened append-mode handle reports
    position 0 until its first write, so a restarted daemon would keep
    appending to an already-oversized active segment.  Reopening an
    existing segment also newline-terminates any torn trailing write
    from a crash, so the fragment can never merge with the next line.
    """

    def __init__(self, root: os.PathLike, max_segment_bytes: int = 1 << 20,
                 fsync: bool = False) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_segment_bytes = max_segment_bytes
        self.fsync = fsync
        self._seq = self._latest_seq()
        self._fh = None
        self._size = 0
        self._open_active()

    def _latest_seq(self) -> int:
        seqs = [int(p.name.split("-")[1].split(".")[0])
                for p in self.root.glob("segment-*.log")]
        return max(seqs) if seqs else 0

    def _active_path(self) -> Path:
        return self.root / SEGMENT_FMT.format(self._seq)

    def _open_active(self) -> None:
        if self._fh is not None:
            self._fh.close()
        path = self._active_path()
        try:
            self._size = path.stat().st_size
        except OSError:
            self._size = 0
        self._fh = open(path, "ab")
        if self._size:
            with open(path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                torn = f.read(1) != b"\n"
            if torn:
                self._fh.write(b"\n")
                self._fh.flush()
                self._size += 1

    def write_line(self, line: str) -> None:
        if self._size >= self.max_segment_bytes:
            self._seq += 1
            self._open_active()
        data = line.rstrip("\n").encode("utf-8") + b"\n"
        self._fh.write(data)
        self._fh.flush()
        self._size += len(data)
        if self.fsync:
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def segments(self) -> List[Path]:
        return sorted(self.root.glob("segment-*.log"))


class Shipper:
    """Ships complete lines from a spool directory to a sink.

    The sink is any ``Callable[[str], None]`` taking one complete line.
    Offsets are persisted to ``<state_dir>/offsets.json`` after each
    batch, giving at-least-once delivery across shipper restarts.
    Fully-shipped, rotated segments are garbage collected.
    """

    def __init__(self, src_dir: os.PathLike, sink: Callable[[str], None],
                 state_dir: Optional[os.PathLike] = None,
                 delete_shipped: bool = True) -> None:
        self.src = Path(src_dir)
        self.sink = sink
        self.state_dir = Path(state_dir) if state_dir else self.src / ".shipper"
        self.state_dir.mkdir(parents=True, exist_ok=True)
        self.delete_shipped = delete_shipped
        self._offsets: Dict[str, int] = self._load_offsets()

    def _offsets_path(self) -> Path:
        return self.state_dir / "offsets.json"

    def _load_offsets(self) -> Dict[str, int]:
        try:
            with open(self._offsets_path(), encoding="utf-8") as f:
                return {str(k): int(v) for k, v in json.load(f).items()}
        except (OSError, ValueError):
            return {}

    def _save_offsets(self) -> None:
        tmp = self._offsets_path().with_suffix(".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(self._offsets, f)
        os.replace(tmp, self._offsets_path())

    def ship_once(self) -> int:
        """Forward all new complete lines.  Returns #lines shipped.

        Reads in binary and decodes per line: the persisted offsets are
        byte positions, directly comparable to ``stat().st_size``.
        """
        segments = sorted(self.src.glob("segment-*.log"))
        if not segments:
            return 0
        active = segments[-1]
        shipped = 0
        for seg in segments:
            offset = self._offsets.get(seg.name, 0)
            try:
                size = seg.stat().st_size
            except OSError:
                continue
            if size < offset:
                # segment truncated/replaced underneath us: re-ship from
                # the start (at-least-once; the aggregator deduplicates)
                offset = self._offsets[seg.name] = 0
            if size > offset:
                with open(seg, "rb") as f:
                    f.seek(offset)
                    chunk = f.read()
                # forward only complete lines
                end = chunk.rfind(b"\n")
                if end >= 0:
                    for raw in chunk[: end + 1].split(b"\n"):
                        raw = raw.rstrip(b"\r")
                        if raw:
                            self.sink(raw.decode("utf-8", errors="replace"))
                            shipped += 1
                    self._offsets[seg.name] = offset + end + 1
            if (self.delete_shipped and seg != active
                    and self._offsets.get(seg.name, 0) >= size):
                try:
                    seg.unlink()
                except OSError:
                    pass
                self._offsets.pop(seg.name, None)
        if shipped:
            self._save_offsets()
        return shipped


class StreamFileSink:
    """Sink that appends to a single stream file (an aggregator inbox)."""

    def __init__(self, path: os.PathLike) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def __call__(self, line: str) -> None:
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(line + "\n")


class IslandRelay:
    """Per-island fan-in: many node spools -> one island stream file.

    Mirrors the paper's intermediate per-island rsyslog servers.  A second
    Shipper instance then moves the island stream to the central inbox;
    relays compose arbitrarily deep.
    """

    def __init__(self, node_spool_dirs: Iterable[os.PathLike],
                 island_dir: os.PathLike, island_name: str = "island0") -> None:
        self.island_dir = Path(island_dir)
        self.island_dir.mkdir(parents=True, exist_ok=True)
        self.island_spool = Spool(self.island_dir / "spool")
        self._shippers = [
            Shipper(d, self.island_spool.write_line,
                    state_dir=self.island_dir / "state" / Path(d).name)
            for d in node_spool_dirs
        ]
        self.island_name = island_name

    def pump(self) -> int:
        return sum(s.ship_once() for s in self._shippers)

    def uplink(self, sink: Callable[[str], None]) -> Shipper:
        return Shipper(self.island_spool.root, sink,
                       state_dir=self.island_dir / "state" / "_uplink")


class TailReader:
    """Incremental reader of an inbox stream file (aggregator side).

    ``offset`` is a byte position.  When the file shrinks below it, or
    is replaced by a new inode (rotation or truncation by an
    operator/log-rotate — the replacement may already have grown past
    the old offset by the next poll), the reader resets to the start
    and resumes instead of stalling or skipping — duplicate re-reads
    are the aggregator's (deduplicated) problem, a silently frozen or
    gapped inbox is nobody's.
    """

    def __init__(self, path: os.PathLike) -> None:
        self.path = Path(path)
        self.offset = 0
        self.truncations_seen = 0
        self._ino: Optional[int] = None

    def read_new_lines(self) -> List[str]:
        try:
            st = self.path.stat()
        except OSError:
            return []
        size = st.st_size
        if ((self._ino is not None and st.st_ino != self._ino)
                or size < self.offset):
            self.offset = 0
            self.truncations_seen += 1
        self._ino = st.st_ino
        if size <= self.offset:
            return []
        with open(self.path, "rb") as f:
            f.seek(self.offset)
            chunk = f.read()
        end = chunk.rfind(b"\n")
        if end < 0:
            return []
        self.offset += end + 1
        return [raw.decode("utf-8", errors="replace")
                for raw in (r.rstrip(b"\r") for r in
                            chunk[: end + 1].split(b"\n")) if raw]
