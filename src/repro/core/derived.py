"""Derived performance metrics and the three-term TPU roofline model.

The paper derives GFLOP/s, memory bandwidth, and arithmetic intensity from
PMU counters and places jobs on a roofline built from CPU-RAM bandwidth
(§4.4).  Our TPU adaptation keeps the same two roofline axes (AI in
FLOP/byte vs performance in GFLOP/s) and extends the model with the
collective (ICI) term required for multi-chip jobs (DESIGN.md §6.3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks for the target part (defaults: TPU v5e)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: float = 16e9          # HBM capacity per chip

    @property
    def ridge_ai(self) -> float:
        """Arithmetic intensity at the roofline ridge point."""
        return self.peak_flops / self.hbm_bw

    def attainable_flops(self, ai: float) -> float:
        """Roofline-attainable FLOP/s at arithmetic intensity ``ai``."""
        return min(self.peak_flops, ai * self.hbm_bw)


TPU_V5E = HardwareSpec()


@dataclass(frozen=True)
class RooflineTerms:
    """The three per-step time terms (seconds) for a compiled step on a mesh."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower bound on step time assuming perfect overlap of the three
        engines (MXU / HBM / ICI): max of the terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        """Upper bound assuming zero overlap."""
        return self.compute_s + self.memory_s + self.collective_s

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant}


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, num_chips: int,
                   hw: HardwareSpec = TPU_V5E) -> RooflineTerms:
    """Three-term roofline from whole-program figures.

    ``hlo_flops``/``hlo_bytes`` are whole-step totals over all chips
    (XLA ``cost_analysis`` on the SPMD-partitioned module is per-chip
    already; callers must pass per-chip totals — see launch/dryrun.py).
    """
    return RooflineTerms(
        compute_s=hlo_flops / (num_chips * hw.peak_flops),
        memory_s=hlo_bytes / (num_chips * hw.hbm_bw),
        collective_s=collective_bytes / (num_chips * hw.ici_bw),
    )


# ---------------------------------------------------------------- job metrics

def achieved_gflops(flops_per_step: float, step_time_s: float) -> float:
    if step_time_s <= 0:
        return 0.0
    return flops_per_step / step_time_s / 1e9


def achieved_gbs(bytes_per_step: float, step_time_s: float) -> float:
    if step_time_s <= 0:
        return 0.0
    return bytes_per_step / step_time_s / 1e9


def arithmetic_intensity(flops: float, bytes_moved: float) -> float:
    if bytes_moved <= 0:
        return 0.0
    return flops / bytes_moved


def mfu(flops_per_step: float, step_time_s: float, num_chips: int,
        hw: HardwareSpec = TPU_V5E) -> float:
    """Model-FLOPs utilization in [0,1]."""
    if step_time_s <= 0 or num_chips <= 0:
        return 0.0
    return flops_per_step / (step_time_s * num_chips * hw.peak_flops)


def model_flops_per_token(n_params: int) -> float:
    """The standard 6·N approximation (fwd+bwd) per token."""
    return 6.0 * n_params


def useful_flops_ratio(model_flops: float, hlo_flops: float) -> float:
    """MODEL_FLOPS / HLO_FLOPS — how much of compiled compute is 'useful'.
    Catches remat recompute and redundancy waste (task spec §Roofline)."""
    if hlo_flops <= 0:
        return 0.0
    return model_flops / hlo_flops


def perf_fields(flops_per_step: float, bytes_per_step: float,
                collective_bytes_per_step: float, step_time_s: float,
                num_chips: int, hw: HardwareSpec = TPU_V5E) -> Dict[str, float]:
    """The standard derived-metric bundle hpcmd emits per perf sample."""
    gfl = achieved_gflops(flops_per_step, step_time_s)
    return {
        "gflops": gfl,
        "gflops_per_chip": gfl / max(num_chips, 1),
        "hbm_gbs": achieved_gbs(bytes_per_step, step_time_s),
        "ici_gbs": achieved_gbs(collective_bytes_per_step, step_time_s),
        "ai": arithmetic_intensity(flops_per_step, bytes_per_step),
        "mfu": mfu(flops_per_step, step_time_s, num_chips, hw),
        "step_time_s": step_time_s,
    }
