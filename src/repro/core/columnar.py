"""Columnar, time-partitioned metric storage engine.

The paper's analysis layer lives on interactive queries over "large
volumes of temporally ordered log-line data" (§4).  A flat Python list
of :class:`MetricRecord` objects cannot serve that interactively at
fleet scale, so the store keeps data the way an analytics engine does:

* **Segments** — immutable, time-ordered batches of records held as
  NumPy column arrays: float64 for numeric fields (with presence and
  int-ness side masks so original values materialize exactly), and
  dictionary-encoded int32 codes for string fields (``host``/``job``/
  ``kind``/``app``...).
* **Zone maps** — per-segment min/max for numeric columns plus the
  dictionary of every string column, so a query planner can skip whole
  segments without touching row data (predicate pushdown).
* **Append buffer** — inserts land in a mutable row buffer that seals
  into a segment once ``seal_threshold`` records accumulate.  Queries
  see the buffer through a transient (cached) segment, so results are
  always complete.
* **Segment-scoped dedup** — transport is at-least-once, so inserts are
  deduplicated by content hash.  Keys are owned by the segment they
  arrived in and evicted once the segment's newest timestamp falls a
  configurable horizon behind the store watermark, bounding memory
  (the seed kept one global, unbounded ``_seen`` set).
* **Durability** (opt-in via ``directory``) — sealed segments are
  written as self-describing column files (``repro.core.segmentio``)
  and memory-mapped back on restart; only the mutable append buffer is
  replayed, from a small write-ahead line log (``wal.log``).  Dedup
  keys persist with their segment, so a restarted store still rejects
  transport retransmits of already-indexed lines.
* **Segment identity + partial-aggregate cache** — every sealed
  segment carries a *content-derived* ``uid`` (a hash of its sorted
  dedup keys) that survives seal, restart, and whole-segment adoption
  into another store.  The store owns a bounded LRU
  :class:`PartialAggregateCache` keyed by ``(segment uid, query-plan
  fingerprint)`` that the incremental splunklite executor
  (``repro.core.splunklite``) fills with per-segment partial
  aggregation states: because segments are immutable, appends never
  invalidate an entry — a repeated query recomputes only the unsealed
  buffer and any newly sealed segments.  See docs/incremental.md.

The vectorized splunklite executor (``repro.core.splunklite``),
dashboards and detectors all run on the column arrays directly via
:meth:`ColumnarMetricStore.segments` / :meth:`ColumnarMetricStore.scan`;
``records`` / ``select`` remain as row-materializing compatibility
paths.
"""

from __future__ import annotations

import hashlib
import json
import math
import os
import threading
from collections import deque
from pathlib import Path
from typing import Deque, Dict, Iterable, Iterator, List, Optional, Set, Tuple

import numpy as np

from repro.core.schema import MetricRecord, encode_line, parse_line

_RESERVED = ("ts", "host", "job", "kind")


def _stem_seqs(stem: str) -> Optional[Tuple[int, ...]]:
    """Sequence numbers embedded in a segment file stem, or ``None``
    for foreign names.  Plain seals are ``seg-NNNNNNNN`` -> ``(N,)``;
    compaction/rollup artifacts are ``seg-NNNNNNNN-mMMMMMMMM`` ->
    ``(N, M)`` where ``N`` picks the artifact's *sort position* (the
    first seq of the run it replaced, so reloaded segment order matches
    the in-memory swap) and ``M`` is the mint counter that keeps the
    stem globally unique."""
    parts = stem.split("-")
    if len(parts) < 2 or parts[0] != "seg":
        return None
    try:
        seq = int(parts[1])
    except ValueError:
        return None
    if len(parts) == 2:
        return (seq,)
    if len(parts) == 3 and parts[2].startswith("m"):
        try:
            return (seq, int(parts[2][1:]))
        except ValueError:
            return None
    return None


class _Missing:
    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<missing>"


MISSING = _Missing()


def segment_uid(dedup_keys: Iterable[bytes]) -> str:
    """Stable, content-derived segment identity.

    Dedup keys are content hashes of the segment's records, so a hash
    over their sorted concatenation identifies the segment by *what it
    holds*: the uid survives seal → persist → restart → adoption into
    another store (the file pair is copied byte-for-byte), which is
    exactly the lifetime a cached per-segment partial aggregate must
    track.  Mutable append buffers have no uid (``uid is None``) and
    are never cached.
    """
    return hashlib.blake2b(b"".join(sorted(dedup_keys)),
                           digest_size=16).hexdigest()


class PartialAggregateCache:
    """Bounded LRU of per-segment partial-aggregation states.

    Keys are ``(segment uid, plan fingerprint)`` pairs; values are the
    ``{group key: {output name: partial state}}`` maps produced by the
    splunklite partial kernels for one sealed segment.  Sealed segments
    are immutable, so an entry can never go stale from appends — there
    is no store-version check here on purpose (that is the point of
    *per-segment* invalidation).  Entries leave the cache only by LRU
    eviction, :meth:`drop_segment`, or :meth:`clear`.

    Consumers must treat cached maps as read-only;
    ``splunklite.merge_partial_maps`` copies before merging.

    All operations are thread-safe: the LRU pop-then-reinsert dance in
    ``_lru_memo_get`` is not atomic on its own, and concurrent
    ``QueryService`` callers share one cache per store.
    """

    __slots__ = ("max_entries", "hits", "misses", "evictions", "_d",
                 "_lock")

    def __init__(self, max_entries: int = 512) -> None:
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._d: Dict[tuple, dict] = {}
        self._lock = threading.Lock()

    def get(self, key: tuple):
        """Cached value or ``None``; counts a hit/miss and refreshes
        the entry's LRU position."""
        with self._lock:
            val = _lru_memo_get(self._d, key)
            if val is None:
                self.misses += 1
            else:
                self.hits += 1
            return val

    def put(self, key: tuple, value: dict) -> None:
        if self.max_entries <= 0:
            return  # caching disabled: every lookup stays a miss
        with self._lock:
            if key in self._d:
                del self._d[key]  # overwrite must not evict a neighbor
            elif len(self._d) >= self.max_entries:
                self.evictions += 1
            _lru_memo_put(self._d, key, value, self.max_entries)

    def peek(self, key: tuple) -> bool:
        """Membership probe that does not touch counters or LRU order
        (``explain()`` uses this to report cache state)."""
        return key in self._d

    def drop_segment(self, uid: str) -> int:
        """Invalidate every plan's entry for one segment (the unit of
        invalidation).  Sealed segments are immutable, so entries only
        go stale when a segment is *retired* — compaction merging it
        into a bigger one, or retention dropping it behind a rollup.
        Compaction calls this per retired uid; in the remote topology
        the worker additionally reports retired uids to the
        coordinator, which evicts its decoded-partial-map scatter memos
        for that shard (``RemoteShard.compact``) — otherwise the
        ``not_modified`` fast path could keep serving maps merged from
        segments that no longer exist."""
        with self._lock:
            stale = [k for k in self._d if k[0] == uid]
            for k in stale:
                del self._d[k]
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def __len__(self) -> int:
        return len(self._d)


# ---------------------------------------------------------------- columns ---

class NumColumn:
    """float64 values; ``present`` marks rows that have the field at all
    (NaN can be a real value), ``is_int`` marks values that were Python
    ints so materialization is lossless."""

    kind = "num"
    __slots__ = ("vals", "present", "is_int")

    def __init__(self, vals: np.ndarray, present: np.ndarray,
                 is_int: np.ndarray) -> None:
        self.vals = vals
        self.present = present
        self.is_int = is_int

    def take(self, idx: np.ndarray) -> "NumColumn":
        return NumColumn(self.vals[idx], self.present[idx], self.is_int[idx])

    def value_at(self, i: int):
        v = self.vals[i]
        return int(v) if self.is_int[i] else float(v)

    def materialize(self) -> np.ndarray:
        out = self.vals.astype(object)
        if self.is_int.any():
            ints = self.vals[self.is_int].astype(np.int64).astype(object)
            out[self.is_int] = ints
        return out

    def present_mask(self) -> np.ndarray:
        return self.present


class StrColumn:
    """Dictionary-encoded strings: int32 codes into ``vocab``; -1 means
    the row does not have the field."""

    kind = "str"
    __slots__ = ("codes", "vocab", "index")

    def __init__(self, codes: np.ndarray, vocab: np.ndarray,
                 index: Dict[str, int]) -> None:
        self.codes = codes
        self.vocab = vocab
        self.index = index

    def take(self, idx: np.ndarray) -> "StrColumn":
        return StrColumn(self.codes[idx], self.vocab, self.index)

    def value_at(self, i: int):
        return self.vocab[self.codes[i]]

    def materialize(self) -> np.ndarray:
        return self.vocab[np.clip(self.codes, 0, None)]

    def present_mask(self) -> np.ndarray:
        return self.codes >= 0


class ObjColumn:
    """Fallback for columns that mix strings and numbers."""

    kind = "obj"
    __slots__ = ("vals", "present")

    def __init__(self, vals: np.ndarray, present: np.ndarray) -> None:
        self.vals = vals
        self.present = present

    def take(self, idx: np.ndarray) -> "ObjColumn":
        return ObjColumn(self.vals[idx], self.present[idx])

    def value_at(self, i: int):
        return self.vals[i]

    def materialize(self) -> np.ndarray:
        return self.vals

    def present_mask(self) -> np.ndarray:
        return self.present


def _encode_strs(values: List) -> StrColumn:
    index: Dict[str, int] = {}
    codes = np.empty(len(values), np.int32)
    for i, v in enumerate(values):
        if v is MISSING:
            codes[i] = -1
            continue
        code = index.get(v)
        if code is None:
            code = index[v] = len(index)
        codes[i] = code
    vocab = np.array(list(index), dtype=object)
    return StrColumn(codes, vocab, index)


def build_column(values: List):
    """Classify and build a column from python values (MISSING = absent)."""
    all_str = True
    all_num = True
    for v in values:
        if v is MISSING:
            continue
        if isinstance(v, str):
            all_num = False
            if not all_str:
                break
        elif isinstance(v, (int, float)):
            all_str = False
            if not all_num:
                break
        else:
            all_str = all_num = False
            break
    n = len(values)
    if all_num:
        vals = np.full(n, np.nan)
        present = np.zeros(n, bool)
        is_int = np.zeros(n, bool)
        for i, v in enumerate(values):
            if v is MISSING:
                continue
            present[i] = True
            vals[i] = float(v)
            is_int[i] = isinstance(v, int) or isinstance(v, bool)
        return NumColumn(vals, present, is_int)
    if all_str:
        return _encode_strs(values)
    vals = np.empty(n, dtype=object)
    present = np.zeros(n, bool)
    for i, v in enumerate(values):
        vals[i] = v
        present[i] = v is not MISSING
    return ObjColumn(vals, present)


# ---------------------------------------------------------------- segment ---

class Segment:
    """Immutable, time-ordered batch of records as columns + zone maps.

    ``attrs`` holds the four reserved record attributes (ts/host/job/
    kind); ``cols`` is the query view — attrs overridden by same-named
    metric fields, mirroring ``MetricRecord.as_dict()`` — and
    ``field_names`` lists the actual metric-field columns.  ``uid`` is
    the content-derived identity (:func:`segment_uid`) assigned at
    seal/load time; it stays ``None`` for transient buffer segments.
    ``tier`` names the storage tier holding the segment (``"hot"`` raw
    seals, ``"cold"`` compacted+compressed, ``"rollup-<gran>"`` for
    downsampled tiers); ``rollup`` is ``None`` for raw segments and the
    rollup descriptor ``{"gran", "covers", "excluded"}`` for bucketed
    rollup segments (see ``repro.core.compaction``).
    """

    __slots__ = ("n", "cols", "attrs", "field_names", "ts_min", "ts_max",
                 "uid", "tier", "rollup", "_zones", "_keys")

    def __init__(self, n: int, attrs: Dict[str, object],
                 field_cols: Dict[str, object]) -> None:
        self.n = n
        self.uid = None
        self.tier = "hot"
        self.rollup = None
        self._keys = None  # dedup keys, stashed at seal (compaction input)
        self.attrs = attrs
        self.field_names = list(field_cols)
        self.cols = dict(attrs)
        self.cols.update(field_cols)
        ts = attrs["ts"].vals
        self.ts_min = float(ts[0]) if n else math.inf
        self.ts_max = float(ts[-1]) if n else -math.inf
        self._zones: Dict[str, Tuple[float, float]] = {}

    def zone(self, name: str) -> Tuple[float, float]:
        """(min, max) over present non-NaN values; (inf, -inf) if none."""
        z = self._zones.get(name)
        if z is None:
            col = self.cols.get(name)
            if col is None or col.kind != "num":
                z = (-math.inf, math.inf)
            else:
                m = col.present & ~np.isnan(col.vals)
                if m.any():
                    v = col.vals[m]
                    z = (float(v.min()), float(v.max()))
                else:
                    z = (math.inf, -math.inf)
            self._zones[name] = z
        return z


def _segment_logical_bytes(seg: Segment) -> int:
    """Raw-equivalent byte estimate for an in-memory segment (matches
    the hot-tier ``.bin`` column encoding: 10B/row numeric, 4B/row
    dictionary code, 1B/row obj presence)."""
    total = 0
    for name in ("ts", "host", "job", "kind"):
        col = seg.attrs[name]
        total += (10 if col.kind == "num" else 4) * seg.n
    for name in seg.field_names:
        col = seg.cols[name]
        total += {"num": 10, "str": 4, "obj": 1}[col.kind] * seg.n
    return total


def columns_from_records(records: List[MetricRecord]) -> Segment:
    """Build a ts-sorted segment from MetricRecords."""
    order = sorted(range(len(records)), key=lambda i: float(records[i].ts))
    recs = [records[i] for i in order]
    n = len(recs)
    attrs: Dict[str, object] = {}
    ts = np.empty(n)
    ts_int = np.zeros(n, bool)
    for i, r in enumerate(recs):
        ts[i] = float(r.ts)
        ts_int[i] = isinstance(r.ts, int) and not isinstance(r.ts, bool)
    attrs["ts"] = NumColumn(ts, np.ones(n, bool), ts_int)
    attrs["host"] = _encode_strs([r.host for r in recs])
    attrs["job"] = _encode_strs([r.job for r in recs])
    attrs["kind"] = _encode_strs([r.kind for r in recs])
    names: Dict[str, None] = {}
    for r in recs:
        for k in r.fields:
            if k not in names:
                names[k] = None
    field_cols = {k: build_column([r.fields.get(k, MISSING) for r in recs])
                  for k in names}
    return Segment(n, attrs, field_cols)


def _concat_str_columns(a, b, na: int, nb: int, order: np.ndarray):
    """Concatenate two (possibly absent) dictionary columns, merging
    vocabularies, then reorder rows; absent sides contribute -1."""
    index: Dict[str, int] = {}
    codes = np.full(na + nb, -1, np.int32)
    pos = 0
    for col, m in ((a, na), (b, nb)):
        if col is not None and len(col.vocab):
            remap = np.array([index.setdefault(v, len(index))
                              for v in col.vocab.tolist()], np.int32)
            cc = col.codes
            codes[pos:pos + m] = np.where(cc >= 0,
                                          remap[np.clip(cc, 0, None)], -1)
        pos += m
    return StrColumn(codes[order], np.array(list(index), dtype=object),
                     index)


def merge_transient_segments(a: Segment, b: Segment) -> Segment:
    """Merge two ts-sorted buffer segments into one, row- and value-
    equivalent to rebuilding ``columns_from_records`` over both record
    batches at once.

    This is the incremental append-buffer path: the previously built
    transient segment (rows inserted before position ``k``) merges with
    a delta segment over only the new records, so a query after an
    append pays per-record Python cost only for the delta.  Ordering is
    exact: both inputs are ts-sorted with insertion-order ties and every
    ``a`` row was inserted before every ``b`` row, so a stable argsort
    over the concatenated timestamps reproduces the full rebuild's
    (ts, insertion index) order.  String dictionaries may end up in a
    different (still first-appearance) vocabulary order — code numbering
    is not query-observable.
    """
    na, nb = a.n, b.n
    ts = np.concatenate([a.attrs["ts"].vals, b.attrs["ts"].vals])
    order = np.argsort(ts, kind="stable")
    attrs: Dict[str, object] = {
        "ts": NumColumn(ts[order], np.ones(na + nb, bool),
                        np.concatenate([a.attrs["ts"].is_int,
                                        b.attrs["ts"].is_int])[order])}
    for key in ("host", "job", "kind"):
        attrs[key] = _concat_str_columns(a.attrs[key], b.attrs[key],
                                         na, nb, order)
    names: Dict[str, None] = dict.fromkeys(a.field_names)
    names.update(dict.fromkeys(b.field_names))
    a_fields = set(a.field_names)
    b_fields = set(b.field_names)
    field_cols: Dict[str, object] = {}
    for name in names:
        ca = a.cols[name] if name in a_fields else None
        cb = b.cols[name] if name in b_fields else None
        kinds = {c.kind for c in (ca, cb) if c is not None}
        if kinds == {"num"}:
            vals = np.full(na + nb, np.nan)
            present = np.zeros(na + nb, bool)
            is_int = np.zeros(na + nb, bool)
            pos = 0
            for col, m in ((ca, na), (cb, nb)):
                if col is not None:
                    vals[pos:pos + m] = col.vals
                    present[pos:pos + m] = col.present
                    is_int[pos:pos + m] = col.is_int
                pos += m
            field_cols[name] = NumColumn(vals[order], present[order],
                                         is_int[order])
        elif kinds == {"str"}:
            field_cols[name] = _concat_str_columns(ca, cb, na, nb, order)
        else:  # mixed kinds (or an obj side): object fallback
            vals = np.empty(na + nb, dtype=object)
            vals[:] = MISSING
            present = np.zeros(na + nb, bool)
            pos = 0
            for col, m in ((ca, na), (cb, nb)):
                if col is not None:
                    pm = col.present_mask()
                    section = vals[pos:pos + m]
                    section[pm] = col.materialize()[pm]
                    present[pos:pos + m] = pm
                pos += m
            field_cols[name] = ObjColumn(vals[order], present[order])
    return Segment(na + nb, attrs, field_cols)


def columns_from_rows(rows: List[Dict]) -> Tuple[int, Dict[str, object]]:
    """Build columns from row dicts (order preserved, no ts sorting)."""
    n = len(rows)
    names: Dict[str, None] = {}
    for r in rows:
        for k in r:
            if k not in names:
                names[k] = None
    cols = {k: build_column([r.get(k, MISSING) for r in rows])
            for k in names}
    return n, cols


def materialize_rows(n: int, cols: Dict[str, object]) -> List[Dict]:
    """Columns -> row dicts, omitting absent fields per row."""
    mats = []
    for name, col in cols.items():
        mats.append((name, col.materialize().tolist(),
                     col.present_mask().tolist()))
    out = []
    for i in range(n):
        row = {}
        for name, vals, present in mats:
            if present[i]:
                row[name] = vals[i]
        out.append(row)
    return out


def _segment_records(seg: Segment, idx: np.ndarray) -> List[MetricRecord]:
    attrs = {k: seg.attrs[k].take(idx).materialize().tolist()
             for k in _RESERVED}
    field_mats = []
    for name in seg.field_names:
        col = seg.cols[name].take(idx)
        field_mats.append((name, col.materialize().tolist(),
                           col.present_mask().tolist()))
    recs = []
    for i in range(len(idx)):
        fields = {}
        for name, vals, present in field_mats:
            if present[i]:
                fields[name] = vals[i]
        recs.append(MetricRecord(ts=attrs["ts"][i], host=attrs["host"][i],
                                 job=attrs["job"][i], kind=attrs["kind"][i],
                                 fields=fields))
    return recs


# ------------------------------------------------------------------- scan ---

class ColumnScan:
    """Filtered, merged column view over the store (the fast read path)."""

    __slots__ = ("n", "ts", "host_codes", "host_vocab", "job_codes",
                 "job_vocab", "_fields")

    def __init__(self, n, ts, host_codes, host_vocab, job_codes, job_vocab,
                 fields) -> None:
        self.n = n
        self.ts = ts
        self.host_codes = host_codes
        self.host_vocab = host_vocab
        self.job_codes = job_codes
        self.job_vocab = job_vocab
        self._fields = fields

    def field(self, name: str) -> Tuple[np.ndarray, np.ndarray]:
        """(float64 values, numeric-present mask) for a requested field."""
        return self._fields[name]


def _empty_scan(fields: Iterable[str]) -> ColumnScan:
    z = np.empty(0)
    zi = np.empty(0, np.int32)
    vocab = np.empty(0, dtype=object)
    return ColumnScan(0, z, zi, vocab, zi, vocab,
                      {f: (np.empty(0), np.empty(0, bool)) for f in fields})


SCAN_MEMO_MAX = 64


def _lru_memo_get(memo: Dict, key):
    """Fetch + LRU-refresh a memo entry (dicts iterate in insertion
    order, so re-inserting moves the entry to the back)."""
    hit = memo.pop(key, None)
    if hit is not None:
        memo[key] = hit
    return hit


def _lru_memo_put(memo: Dict, key, value, bound: int) -> None:
    if len(memo) >= bound:
        memo.pop(next(iter(memo)))
    memo[key] = value


# -------------------------------------------------------------------- store --

class ColumnarMetricStore:
    """Time-ordered, columnar metric store (drop-in for the old row list).

    ``seal_threshold`` — records buffered before sealing a segment.
    ``dedup_horizon_s`` — when set, dedup keys for a sealed segment are
    evicted once the store watermark moves this far past the segment's
    newest timestamp, bounding dedup memory.  The default ``None``
    keeps keys forever (the seed's behavior): eviction is opt-in
    because an aggregator that replays a multi-day archive and then
    re-tails its inbox would otherwise re-accept old lines as new.
    ``directory`` — when set, the store is durable: sealed segments are
    persisted under ``<directory>/segments/`` and loaded back via
    ``np.memmap`` on construction; accepted inserts are appended to
    ``<directory>/wal.log`` (canonical wire encoding) and replayed on
    restart.  Only one live store per directory is supported.
    ``wal_fsync`` — fsync the WAL after every accepted insert (and the
    segment files at seal); off by default, matching ``Spool``.
    ``partial_cache_entries`` — LRU bound on the per-segment
    partial-aggregate cache (one entry per (segment, plan fingerprint);
    see :class:`PartialAggregateCache` and docs/incremental.md).
    ``read_only`` — open a durable directory without taking ownership
    of it: segments mmap in and the WAL tail replays into the buffer,
    but nothing on disk is written (no WAL rewrite, no seals), and
    ``insert``/``seal`` raise ``RuntimeError``.  This is how a remote
    coordinator inspects a dead worker's shard directory in degraded
    mode (docs/remote.md) without violating the one-live-store-per-
    directory rule when the worker comes back.
    """

    def __init__(self, seal_threshold: int = 4096,
                 dedup_horizon_s: Optional[float] = None,
                 directory: Optional[os.PathLike] = None,
                 wal_fsync: bool = False,
                 partial_cache_entries: int = 512,
                 read_only: bool = False) -> None:
        self.seal_threshold = int(seal_threshold)
        self.dedup_horizon_s = dedup_horizon_s
        self._sealed: List[Segment] = []
        self._sealed_stems: List[Optional[str]] = []
        self._rollups: List[Segment] = []
        self._rollup_stems: List[Optional[str]] = []
        self.last_compaction: Optional[Dict] = None
        self._buffer: List[MetricRecord] = []
        self._buffer_keys: Set[bytes] = set()
        self._seen: Set[bytes] = set()
        self._epochs: Deque[Tuple[float, Set[bytes]]] = deque()
        self._watermark = -math.inf
        self.duplicates_dropped = 0
        self.dedup_evicted_keys = 0
        self.segment_load_errors = 0
        self.quarantined_segments = 0
        self._cache: Dict[str, tuple] = {}
        self._transient_base: Optional[Tuple[int, Segment]] = None
        self.partial_cache = PartialAggregateCache(partial_cache_entries)
        self.last_query_stats: Optional[Dict] = None
        # Optional telemetry registry hookup (attach_telemetry); the
        # store never creates one itself so bare stores stay free of
        # the dependency.
        self.telemetry = None
        # Re-entrancy: one lock serializes every structural mutation
        # (insert/seal/adopt/compact) and every version-scoped memo
        # access, so concurrent QueryService readers see consistent
        # (segments, version) snapshots while ingest proceeds.  RLock
        # because insert() seals at threshold and seal() re-enters.
        self._lock = threading.RLock()
        self.directory = Path(directory) if directory is not None else None
        self.wal_fsync = bool(wal_fsync)
        self.read_only = bool(read_only)
        if self.read_only and self.directory is None:
            raise ValueError("read_only requires a directory")
        self._wal = None
        self._next_seq = 0
        self._replaying = False
        if self.directory is not None:
            self._open_directory()

    # ------------------------------------------------------------- ingest --
    def __len__(self) -> int:
        with self._lock:
            return sum(s.n for s in self._sealed) + len(self._buffer)

    def _version(self) -> Tuple[int, int, int]:
        # _next_seq is a monotonic mutation generation: it advances on
        # every seal, compaction and retention pass (even memory-only),
        # and is restart-stable (recovered from segment filenames), so
        # a compaction that leaves (sealed, buffer) counts unchanged
        # still changes the version — remote etag checks can never
        # serve a pre-compaction cached reply for post-compaction state.
        with self._lock:
            return (len(self._sealed), len(self._buffer), self._next_seq)

    def insert(self, rec: MetricRecord) -> bool:
        if self.read_only and not self._replaying:
            raise RuntimeError("store is read-only")
        with self._lock:
            return self._insert_locked(rec)

    def _insert_locked(self, rec: MetricRecord) -> bool:
        encoded = encode_line(rec)
        key = hashlib.blake2b(encoded.encode(), digest_size=12).digest()
        if key in self._seen:
            self.duplicates_dropped += 1
            return False
        self._seen.add(key)
        self._buffer_keys.add(key)
        self._buffer.append(rec)
        if self._cache:
            # version-scoped memos (transient segment, records, scans)
            # are stale the moment the version changes — evict eagerly
            # instead of holding superseded materializations until the
            # same memo key is touched again.  The per-segment partial
            # cache is *not* version-scoped and survives untouched.
            self._cache.clear()
        ts = float(rec.ts)
        if ts > self._watermark:
            self._watermark = ts
        if self._wal is not None and not self._replaying:
            from repro.core.segmentio import wal_encode_line
            self._wal.write(wal_encode_line(encoded) + "\n")
            self._wal.flush()
            if self.wal_fsync:
                os.fsync(self._wal.fileno())
        if len(self._buffer) >= self.seal_threshold and not self.read_only:
            self.seal()
        return True

    def ingest_lines(self, lines: Iterable[str]) -> int:
        from repro.core.schema import parse_line
        n = 0
        for line in lines:
            rec = parse_line(line)
            if rec is not None and self.insert(rec):
                n += 1
        return n

    def seal(self) -> None:
        """Freeze the append buffer into an immutable segment.

        With a ``directory``, the segment is persisted *before* the WAL
        resets; a crash in between leaves both — replay dedups against
        the segment's persisted keys, so nothing duplicates or is lost.
        """
        if self.read_only:
            raise RuntimeError("store is read-only")
        with self._lock:
            self._seal_locked()

    def _seal_locked(self) -> None:
        if not self._buffer:
            return
        seg = columns_from_records(self._buffer)
        keys = self._buffer_keys
        seg.uid = segment_uid(keys)
        seg._keys = frozenset(keys)
        stem = None
        if self.directory is not None:
            from repro.core import segmentio
            stem = segmentio.SEGMENT_STEM_FMT.format(self._next_seq)
            # durability at seal is governed by wal_fsync, like the WAL
            # itself: the sealed rows stay replayable from the WAL until
            # _rewrite_wal below, so an unsynced seal loses nothing a
            # synced WAL would have kept
            segmentio.save_segment(self.directory / "segments", stem, seg,
                                   keys, fsync=self.wal_fsync)
        self._next_seq += 1
        self._sealed.append(seg)
        self._sealed_stems.append(stem)
        if self.dedup_horizon_s is not None:
            self._epochs.append((seg.ts_max, keys))
        self._buffer = []
        self._buffer_keys = set()
        self._transient_base = None
        if self._cache:
            self._cache.clear()
        if self.directory is not None:
            self._rewrite_wal()
        self._evict_dedup()

    def _evict_dedup(self) -> None:
        if self.dedup_horizon_s is None:
            return
        cutoff = self._watermark - self.dedup_horizon_s
        while self._epochs and self._epochs[0][0] < cutoff:
            _, keys = self._epochs.popleft()
            self._seen -= keys
            self.dedup_evicted_keys += len(keys)

    # -------------------------------------------------------- persistence --
    def _open_directory(self) -> None:
        """Restart path: mmap committed segments, replay the WAL.

        Sealed rows never go through ``parse_line`` again — their
        columns map straight back in.  Manifests that fail to load
        (interrupted seals, foreign files) are skipped and counted in
        ``segment_load_errors``; their rows, if any were acknowledged,
        are still in the WAL and get replayed into the buffer.
        """
        from repro.core import segmentio
        seg_dir = self.directory / "segments"
        seg_dir.mkdir(parents=True, exist_ok=True)
        # Pass 1: committed manifests only (a .bin without its .json is
        # an interrupted seal/compaction and is simply invisible).  A
        # compacted manifest's "replaces" list names stems it retired;
        # if a crash hit the window between manifest commit and retired-
        # file deletion, both the merged segment and its inputs exist —
        # the replaced stems must be skipped (and cleaned up) or every
        # merged row would load twice.
        entries: List[Tuple[int, str, Path, Dict]] = []
        replaced: Set[str] = set()
        seq_floor = -1
        for man_path in sorted(seg_dir.glob("seg-*.json")):
            seqs = _stem_seqs(man_path.stem)
            if seqs is None:
                continue
            seq_floor = max(seq_floor, *seqs)
            try:
                with open(man_path, encoding="utf-8") as f:
                    man = json.load(f)
            except (OSError, ValueError):
                self.segment_load_errors += 1
                continue
            if isinstance(man, dict):
                for stem in man.get("replaces", ()):
                    replaced.add(str(stem))
                    rseqs = _stem_seqs(str(stem))
                    if rseqs is not None:
                        seq_floor = max(seq_floor, *rseqs)
            entries.append((seqs[0], man_path.stem, man_path, man))
        # never re-mint a stem some live manifest claims to replace, or
        # a stem whose sort position is already taken
        self._next_seq = max(self._next_seq, seq_floor + 1)
        entries.sort(key=lambda t: (t[0], t[1]))
        loaded: List[Tuple[int, "segmentio.MappedSegment"]] = []
        retired_paths: List[Path] = []
        for seq, stem, man_path, man in entries:
            if stem in replaced:
                retired_paths.append(man_path)
                continue
            if segmentio.segment_crc_ok(
                    man, man_path.with_suffix(".bin")) is False:
                # payload bytes contradict the manifest checksum —
                # quarantine rather than serve silently wrong rows
                # (docs/faults.md); any acknowledged rows also in the
                # WAL replay below exactly as for a load error
                self.quarantined_segments += 1
                if not self.read_only:
                    try:
                        segmentio.quarantine_segment_files(man_path)
                    except OSError:
                        pass
                continue
            try:
                seg = segmentio.load_segment(man_path, manifest=man)
            except (OSError, ValueError, KeyError, TypeError):
                self.segment_load_errors += 1
                continue
            if seg.rollup is not None:
                self._rollups.append(seg)
                self._rollup_stems.append(stem)
            else:
                loaded.append((seq, seg))
                self._sealed_stems.append(stem)
            if seg.ts_max > self._watermark:
                self._watermark = seg.ts_max
        for seq, seg in loaded:
            self._sealed.append(seg)
        if retired_paths and not self.read_only:
            # finish the interrupted swap: manifest first (uncommits the
            # retired segment), then its data file
            for man_path in retired_paths:
                for victim in (man_path, man_path.with_suffix(".bin")):
                    try:
                        victim.unlink()
                    except OSError:
                        pass
            segmentio.fsync_dir(seg_dir)
        cutoff = (-math.inf if self.dedup_horizon_s is None
                  else self._watermark - self.dedup_horizon_s)
        last_seg = loaded[-1][1] if loaded else None
        transient_keys: Set[bytes] = set()
        for _, seg in loaded:
            if seg.ts_max < cutoff:
                if seg is last_seg:
                    # Only the newest seal can sit in the crash window
                    # between segment commit and WAL reset (every
                    # earlier seal's reset completed, or there would be
                    # a newer segment).  If its data is already past
                    # the horizon, its keys must still be visible
                    # *during* replay — the un-reset WAL holds exactly
                    # its rows — and evicted again afterwards.
                    transient_keys = seg.dedup_keys() - self._seen
                    self._seen |= transient_keys
                continue  # keys already past the horizon: stay evicted
            keys = seg.dedup_keys()
            self._seen |= keys
            if self.dedup_horizon_s is not None:
                self._epochs.append((seg.ts_max, keys))
        # replay complete WAL lines into the append buffer (suppressing
        # re-append); a torn trailing write is dropped by the shared
        # reader and removed from disk by the rewrite below, so it can
        # never concatenate with the next accepted line
        lines = segmentio.read_complete_wal_lines(self.directory / "wal.log")
        if lines:
            self._replaying = True
            try:
                for line in lines:
                    rec = parse_line(line)
                    if rec is not None:
                        self.insert(rec)
            finally:
                self._replaying = False
        self._seen -= transient_keys
        if not self.read_only:
            self._rewrite_wal()

    def _rewrite_wal(self) -> None:
        """Atomically reset the WAL to exactly the current buffer."""
        if self._wal is not None:
            self._wal.close()
            self._wal = None
        wal_path = self.directory / "wal.log"
        tmp = wal_path.with_suffix(".tmp")
        from repro.core.segmentio import wal_encode_line
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in self._buffer:
                f.write(wal_encode_line(encode_line(rec)) + "\n")
            f.flush()
            if self.wal_fsync:
                os.fsync(f.fileno())
        os.replace(tmp, wal_path)
        if self.wal_fsync:
            from repro.core import segmentio
            segmentio.fsync_dir(self.directory)
        self._wal = open(wal_path, "a", encoding="utf-8")

    def close(self) -> None:
        """Release the WAL handle (durable stores); safe to call twice."""
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    def adopt_segment(self, manifest_path: os.PathLike) -> int:
        """Adopt a committed segment file pair from *another* store.

        Sealed segments are immutable, self-describing shard units —
        adopting one never re-parses rows: a durable store copies the
        ``.bin``/``.json`` under its own sequence number (same commit
        protocol as a seal), a memory-only store maps the source files
        in place.  The segment's persisted dedup keys merge into the
        live set (horizon rules apply), so transport retransmits of
        adopted rows are still rejected.  Used by shard rebalancing /
        store migration (``repro.core.shards``).  Returns the adopted
        row count.
        """
        from repro.core import segmentio
        with self._lock:
            stem = None
            if self.directory is not None:
                # always fsync, matching migration semantics — adoption
                # has no WAL backstop, the copied files are the only
                # copy here
                stem = segmentio.SEGMENT_STEM_FMT.format(self._next_seq)
                man_path = segmentio.copy_segment_files(
                    manifest_path, self.directory / "segments", stem,
                    fsync=True)
                self._next_seq += 1
                seg = segmentio.load_segment(man_path)
            else:
                seg = segmentio.load_segment(manifest_path)
                man = getattr(seg, "_man", None)
                if man is not None and segmentio.segment_crc_ok(
                        man,
                        Path(manifest_path).with_suffix(".bin")) is False:
                    raise ValueError("segment payload failed checksum: "
                                     f"{manifest_path}")
            if getattr(seg, "rollup", None) is not None:
                # rollup segments route to the rollup tier, exactly as
                # the restart loader does — appending one to _sealed
                # would expose its bucketed partial rows to row-level
                # reads.  Replica catch-up must ship rollups (retention
                # may have dropped the raw segments they cover), so
                # adoption has to route them correctly too.
                self._rollups.append(seg)
                self._rollup_stems.append(stem)
                if self._cache:
                    self._cache.clear()
                if seg.ts_max > self._watermark:
                    self._watermark = seg.ts_max
                return seg.n
            self._sealed.append(seg)
            self._sealed_stems.append(stem)
            if self._cache:
                self._cache.clear()
            if seg.ts_max > self._watermark:
                self._watermark = seg.ts_max
            keys = seg.dedup_keys()
            self._seen |= keys
            if self.dedup_horizon_s is not None:
                self._epochs.append((seg.ts_max, keys))
                self._evict_dedup()
            return seg.n

    def adopt_buffer(self, lines: Iterable[str],
                     next_seq: Optional[int] = None) -> int:
        """Replace the append buffer wholesale with *lines* — the WAL
        tail a replication primary ships during catch-up
        (docs/replication.md).  The current buffer rows are discarded
        and their dedup keys forgotten; the shipped lines land directly
        in the buffer (no threshold seal — the primary decides when to
        seal), and ``next_seq`` fast-forwards the mutation generation,
        so after segment adoption + ``adopt_buffer`` the replica's
        ``(sealed, buffer, seq)`` version equals the primary's exactly.
        Returns the new buffer length."""
        from repro.core.schema import parse_line
        if self.read_only:
            raise RuntimeError("store is read-only")
        with self._lock:
            self._seen -= self._buffer_keys
            self._buffer = []
            self._buffer_keys = set()
            self._transient_base = None
            if self._cache:
                self._cache.clear()
            for line in lines:
                rec = parse_line(line)
                if rec is None:
                    continue
                encoded = encode_line(rec)
                key = hashlib.blake2b(encoded.encode(),
                                      digest_size=12).digest()
                if key in self._seen:
                    continue
                self._seen.add(key)
                self._buffer_keys.add(key)
                self._buffer.append(rec)
                ts = float(rec.ts)
                if ts > self._watermark:
                    self._watermark = ts
            if next_seq is not None:
                self._next_seq = max(self._next_seq, int(next_seq))
            if self.directory is not None:
                self._rewrite_wal()
            return len(self._buffer)

    # -------------------------------------------------------------- reads --
    def segments(self) -> List[Segment]:
        """Sealed segments plus a transient segment over the buffer."""
        return [seg for seg, _uid in self.segment_units()]

    def segment_units(self, include_buffer: bool = True
                      ) -> List[Tuple[Segment, Optional[str]]]:
        """``(segment, uid)`` pairs — the cache-aware scan units.

        Sealed segments carry their stable content uid; the transient
        buffer segment (present only with ``include_buffer``) has uid
        ``None`` and is always recomputed by incremental queries.
        """
        with self._lock:
            units: List[Tuple[Segment, Optional[str]]] = [
                (seg, seg.uid) for seg in self._sealed]
            if include_buffer and self._buffer:
                v = self._version()
                cached = self._cache.get("transient")
                if cached is None or cached[0] != v:
                    cached = (v, self._build_transient())
                    self._cache["transient"] = cached
                units.append((cached[1], None))
            return units

    def rollup_units(self) -> List[Tuple[Segment, str]]:
        """``(segment, uid)`` pairs for downsampled rollup segments.

        Rollups are *not* part of :meth:`segments` /
        :meth:`segment_units` — row-level reads never see them.  Only
        the incremental planner (``splunklite.scatter_partials``)
        consults them, and only when the plan is provably answerable
        from bucketed partial-aggregate columns (docs/storage.md).
        """
        with self._lock:
            return [(seg, seg.uid) for seg in self._rollups]

    def compact(self, **kwargs) -> Dict:
        """Merge runs of small sealed segments into large cold-tier
        (compressed) ones; see :class:`repro.core.compaction.Compactor`.
        Returns the compaction stats dict (also kept as
        ``last_compaction``)."""
        from repro.core.compaction import Compactor
        with self._lock:
            return Compactor(self).compact(**kwargs)

    def apply_retention(self, **kwargs) -> Dict:
        """Build/refresh time-bucketed rollup tiers and (optionally)
        drop raw segments past the retention age; see
        :class:`repro.core.compaction.Compactor`."""
        from repro.core.compaction import Compactor
        with self._lock:
            return Compactor(self).apply_retention(**kwargs)

    def quarantine_segment(self, seg: Segment) -> bool:
        """Remove a corrupt sealed/rollup segment from the live set.

        Called by the scan path when a segment's payload fails to
        decode or checksum at query time (docs/faults.md): the segment
        and its stem leave ``_sealed``/``_rollups``, its files move to
        ``segments/quarantine/`` (durable, writable stores),
        version-scoped memos drop, and the mutation generation bumps so
        remote etags and result caches can never serve rows computed
        against the corrupt payload.  Dedup keys stay registered — the
        rows were accepted once; transport retransmits must still
        dedup.  Returns True if the segment was found (and is gone).
        """
        from repro.core import segmentio
        with self._lock:
            for segs, stems in ((self._sealed, self._sealed_stems),
                                (self._rollups, self._rollup_stems)):
                for i, live in enumerate(segs):
                    if live is seg:
                        segs.pop(i)
                        stem = stems.pop(i)
                        self.quarantined_segments += 1
                        self._next_seq += 1
                        if self._cache:
                            self._cache.clear()
                        if (stem is not None and self.directory is not None
                                and not self.read_only):
                            man_path = (self.directory / "segments"
                                        / (stem + ".json"))
                            try:
                                segmentio.quarantine_segment_files(man_path)
                            except OSError:
                                pass
                        return True
            return False

    def storage_stats(self) -> Dict:
        """Per-tier storage accounting: segment/file counts, stored vs
        raw-equivalent bytes, rows, plus the last compaction's stats.
        Pure bookkeeping — reads manifests already in memory, never the
        ``.bin`` payloads."""
        tiers: Dict[str, Dict] = {}

        def acc(seg: Segment, stem: Optional[str]) -> None:
            t = tiers.setdefault(seg.tier, {
                "segments": 0, "files": 0, "rows": 0,
                "bytes": 0, "raw_bytes": 0})
            t["segments"] += 1
            t["rows"] += seg.n
            if stem is not None:
                t["files"] += 2
            man = getattr(seg, "_man", None)
            if man is not None:
                t["bytes"] += int(man.get("bin_bytes", 0))
                t["raw_bytes"] += int(man.get("raw_bytes",
                                              man.get("bin_bytes", 0)))
            else:
                est = _segment_logical_bytes(seg)
                t["bytes"] += est
                t["raw_bytes"] += est

        with self._lock:
            for seg, stem in zip(self._sealed, self._sealed_stems):
                acc(seg, stem)
            for seg, stem in zip(self._rollups, self._rollup_stems):
                acc(seg, stem)
            total = {k: sum(t[k] for t in tiers.values())
                     for k in ("segments", "files", "rows", "bytes",
                               "raw_bytes")}
            total["tiers"] = tiers
            total["buffer_rows"] = len(self._buffer)
            total["quarantined_segments"] = self.quarantined_segments
            total["last_compaction"] = self.last_compaction
            return total

    # ---------------------------------------------------------- telemetry --
    def telemetry_samples(self) -> Dict[str, float]:
        """Pull-based metric samples for a telemetry ``Registry``:
        the same storage/cache numbers that back :meth:`storage_stats`
        and the partial-aggregate cache counters, under dotted names.
        One source, two views — nothing here is tracked twice."""
        st = self.storage_stats()
        pc = self.partial_cache
        return {
            "storage.segments": float(st["segments"]),
            "storage.rows": float(st["rows"]),
            "storage.bytes": float(st["bytes"]),
            "storage.buffer_rows": float(st["buffer_rows"]),
            "storage.quarantined_segments":
                float(st["quarantined_segments"]),
            "storage.duplicates_dropped": float(self.duplicates_dropped),
            "cache.partial.hits": float(pc.hits),
            "cache.partial.misses": float(pc.misses),
            "cache.partial.evictions": float(pc.evictions),
            "cache.partial.entries": float(len(pc._d)),
        }

    def attach_telemetry(self, telemetry, name: str = "store") -> None:
        """Register this store's :meth:`telemetry_samples` as a pull
        collector under ``name`` and remember the registry handle so
        cooperating components (e.g. :class:`~repro.core.compaction.
        Compactor`) can bump counters on the same registry.  Collector
        names are unique per registry — callers with several stores
        (one per shard) pick distinct names or register a single
        aggregated collector instead, as ``ShardedAggregator`` does."""
        self.telemetry = telemetry
        telemetry.registry.register_collector(name, self.telemetry_samples)

    def _build_transient(self) -> Segment:
        """Transient segment over the append buffer, built
        incrementally: the previous build covers a buffer *prefix*
        (buffers only grow between seals), so per-record column
        construction runs only over records appended since, then the
        prefix and delta merge with vectorized column concatenation
        (:func:`merge_transient_segments`).  Equivalent to — and on a
        streaming store much cheaper than — rebuilding from scratch.
        """
        n = len(self._buffer)
        base = self._transient_base
        if base is not None and 0 < base[0] <= n:
            k, prev = base
            seg = (prev if k == n else merge_transient_segments(
                prev, columns_from_records(self._buffer[k:])))
        else:
            seg = columns_from_records(self._buffer)
        self._transient_base = (n, seg)
        return seg

    @property
    def records(self) -> List[MetricRecord]:
        """Row-materializing compatibility path (segment order)."""
        with self._lock:
            v = self._version()
            cached = self._cache.get("records")
            if cached is None or cached[0] != v:
                recs: List[MetricRecord] = []
                for seg in self.segments():
                    recs.extend(_segment_records(seg, np.arange(seg.n)))
                cached = (v, recs)
                self._cache["records"] = cached
            return cached[1]

    def _segment_mask(self, seg: Segment, job, kind, since, until
                      ) -> Optional[np.ndarray]:
        """None = segment fully pruned; else boolean row mask."""
        if since is not None and seg.ts_max < since:
            return None
        if until is not None and seg.ts_min >= until:
            return None
        mask = np.ones(seg.n, bool)
        for key, want in (("job", job), ("kind", kind)):
            if want is None:
                continue
            col = seg.attrs[key]
            code = col.index.get(want)
            if code is None:
                return None
            mask &= col.codes == code
        ts = seg.attrs["ts"].vals
        if since is not None:
            mask &= ts >= since
        if until is not None:
            mask &= ts < until
        if not mask.any():
            return None
        return mask

    def scan(self, job: Optional[str] = None, kind: Optional[str] = None,
             since: Optional[float] = None, until: Optional[float] = None,
             fields: Iterable[str] = ()) -> ColumnScan:
        """Vectorized filtered read: zone-map/dictionary pruning per
        segment, then a single gather into merged column arrays.

        Results are memoized per store version (dashboards and reports
        issue the same scan repeatedly for different renderings); the
        memo is a bounded LRU so many distinct scans in one version
        evict the oldest instead of disabling memoization.
        """
        fields = tuple(fields)
        memo_key = (job, kind, since, until, fields)
        with self._lock:
            memo = self._cache.get("scans")
            if memo is None or memo[0] != self._version():
                memo = (self._version(), {})
                self._cache["scans"] = memo
            sc = _lru_memo_get(memo[1], memo_key)
            if sc is None:
                sc = self._scan_uncached(job, kind, since, until, fields)
                _lru_memo_put(memo[1], memo_key, sc, SCAN_MEMO_MAX)
            return sc

    def explain(self, q: str) -> Dict:
        """Describe how ``q`` would execute incrementally against this
        store: plan shape, per-segment partial-cache state for the
        plan's fingerprint, and cumulative hit/miss counters.  See
        ``repro.core.splunklite.explain_store``."""
        from repro.core.splunklite import explain_store
        return explain_store(self, q)

    def _scan_uncached(self, job, kind, since, until,
                       fields: Tuple[str, ...]) -> ColumnScan:
        parts: List[Tuple[Segment, np.ndarray]] = []
        for seg in self.segments():
            mask = self._segment_mask(seg, job, kind, since, until)
            if mask is not None:
                parts.append((seg, np.nonzero(mask)[0]))
        if not parts:
            return _empty_scan(fields)
        n = sum(len(idx) for _, idx in parts)
        ts = np.empty(n)
        host_index: Dict[str, int] = {}
        job_index: Dict[str, int] = {}
        host_codes = np.empty(n, np.int32)
        job_codes = np.empty(n, np.int32)
        fvals = {f: np.full(n, np.nan) for f in fields}
        fpres = {f: np.zeros(n, bool) for f in fields}
        pos = 0
        for seg, idx in parts:
            m = len(idx)
            ts[pos:pos + m] = seg.attrs["ts"].vals[idx]
            for key, codes_out, index in (("host", host_codes, host_index),
                                          ("job", job_codes, job_index)):
                col = seg.attrs[key]
                remap = np.array([index.setdefault(v, len(index))
                                  for v in col.vocab], np.int32) \
                    if len(col.vocab) else np.empty(0, np.int32)
                codes_out[pos:pos + m] = remap[col.codes[idx]]
            for f in fields:
                col = seg.cols.get(f)
                if col is None:
                    continue
                if col.kind == "num":
                    fvals[f][pos:pos + m] = col.vals[idx]
                    fpres[f][pos:pos + m] = col.present[idx]
                elif col.kind == "obj":
                    vv = col.vals[idx]
                    pp = col.present[idx]
                    for j in range(m):
                        v = vv[j]
                        if pp[j] and isinstance(v, (int, float)):
                            fvals[f][pos + j] = float(v)
                            fpres[f][pos + j] = True
                # str columns: not numeric -> stays absent
            pos += m
        return ColumnScan(
            n, ts, host_codes, np.array(list(host_index), dtype=object),
            job_codes, np.array(list(job_index), dtype=object),
            {f: (fvals[f], fpres[f]) for f in fields})

    # -------------------------------------------------- compat query API --
    def select(self, job: Optional[str] = None, kind: Optional[str] = None,
               since: Optional[float] = None,
               until: Optional[float] = None) -> Iterator[MetricRecord]:
        for seg in self.segments():
            mask = self._segment_mask(seg, job, kind, since, until)
            if mask is None:
                continue
            yield from _segment_records(seg, np.nonzero(mask)[0])

    def _vocab_union(self, key: str) -> List[str]:
        out: Dict[str, None] = {}
        for seg in self.segments():
            for v in seg.attrs[key].index:
                out.setdefault(v)
        return sorted(out)

    def jobs(self) -> List[str]:
        return self._vocab_union("job")

    def kinds(self) -> List[str]:
        return self._vocab_union("kind")

    def hosts(self, job: Optional[str] = None) -> List[str]:
        if job is None:
            return self._vocab_union("host")
        sc = self.scan(job=job)
        if sc.n == 0:
            return []
        return sorted(sc.host_vocab[np.unique(sc.host_codes)].tolist())
