"""Per-job performance reports — the paper's §4.5 PDF-for-users analog.

Users do not get Splunk access (security/data-protection, per the paper);
they get a static, self-contained report per job.  We render Markdown plus
embedded SVGs, and a single-file HTML (the "PDF" stand-in: printable,
self-contained, no external references).

All store reads go through splunklite queries and the dashboard helpers,
which execute on the columnar engine (``repro.core.columnar``) — report
generation never materializes row objects from the store.
"""

from __future__ import annotations

import html
import math
import os
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.aggregator import MetricStore
from repro.core.daemon import JobManifest
from repro.core.dashboards import (JOB_VIEW_METRICS, JobPoint,
                                   job_metric_series, job_statistical_view,
                                   markdown_table, render_roofline_svg,
                                   render_timeseries_svg, roofline_points)
from repro.core.derived import HardwareSpec, TPU_V5E
from repro.core.detectors import DetectorBank
from repro.core.splunklite import query


def _fmt(v, nd=3):
    if isinstance(v, float):
        if math.isnan(v):
            return "–"
        return f"{v:.{nd}g}"
    return str(v)


def job_summary(store: MetricStore, job: str,
                manifest: Optional[JobManifest] = None,
                hw: HardwareSpec = TPU_V5E) -> Dict[str, object]:
    rows = query(store, f"search kind=perf job={job} gflops>0 "
                        "| stats avg(gflops) max(gflops) avg(gflops_per_chip) "
                        "avg(hbm_gbs) avg(ici_gbs) avg(ai) avg(mfu) "
                        "p50(step_time_s) avg(tokens_per_s) "
                        "min(ts) max(ts) count")
    s = rows[0] if rows else {}
    chips = manifest.num_chips if manifest else 1
    dur = max(float(s.get("max_ts", 0) or 0) - float(s.get("min_ts", 0) or 0),
              0.0)
    out = {
        "job": job,
        "app": manifest.app if manifest else "?",
        "user": manifest.user if manifest else "?",
        "hosts": manifest.num_hosts if manifest else len(store.hosts(job)),
        "chips": chips,
        "duration_s": dur,
        "device_hours": dur * chips / 3600.0,
        "samples": int(s.get("count", 0) or 0),
        "avg_gflops": float(s.get("avg_gflops", 0) or 0),
        "max_gflops": float(s.get("max_gflops", 0) or 0),
        "avg_gflops_per_chip": float(s.get("avg_gflops_per_chip", 0) or 0),
        "avg_hbm_gbs": float(s.get("avg_hbm_gbs", 0) or 0),
        "avg_ici_gbs": float(s.get("avg_ici_gbs", 0) or 0),
        "avg_ai": float(s.get("avg_ai", 0) or 0),
        "avg_mfu": float(s.get("avg_mfu", 0) or 0),
        "p50_step_time_s": float(s.get("p50_step_time_s", 0) or 0),
        "avg_tokens_per_s": float(s.get("avg_tokens_per_s", 0) or 0),
    }
    ai = out["avg_ai"]
    if ai > 0:
        attain = hw.attainable_flops(ai) / 1e9
        out["roofline_attainable_gflops_per_chip"] = attain
        out["roofline_fraction"] = (out["avg_gflops_per_chip"] / attain
                                    if attain else 0.0)
        out["roofline_regime"] = ("memory-bound" if ai < hw.ridge_ai
                                  else "compute-bound")
    return out


def generate_report(store: MetricStore, job: str, out_dir: os.PathLike,
                    manifests: Optional[Dict[str, JobManifest]] = None,
                    hw: HardwareSpec = TPU_V5E) -> Path:
    """Write ``report.md``, ``report.html`` and SVGs; returns the md path."""
    manifests = manifests or {}
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    man = manifests.get(job)
    summ = job_summary(store, job, man, hw)

    svgs: List[str] = []
    md: List[str] = [f"# Job performance report — `{job}`", ""]
    md.append(f"*Application*: **{summ['app']}** — *user*: {summ['user']} — "
              f"*hosts*: {summ['hosts']} — *chips*: {summ['chips']} — "
              f"*duration*: {summ['duration_s']:.1f}s — "
              f"*device-hours*: {summ['device_hours']:.3f}")
    md.append("")
    md.append("## Summary")
    md.append(markdown_table([{k: _fmt(v) for k, v in summ.items()
                               if k not in ("job", "app", "user")}]))

    # roofline placement of THIS job among all jobs in the store
    points = roofline_points(store, manifests)
    if points:
        svg = render_roofline_svg(
            points, hw, title=f"Roofline placement — {job}")
        (out / "roofline.svg").write_text(svg)
        svgs.append(svg)
        md.append("## Roofline placement\n\n![roofline](roofline.svg)\n")

    # temporal views per metric (per host), Fig. 3 analog
    md.append("## Temporal metrics (per host)")
    for metric in JOB_VIEW_METRICS:
        series = job_metric_series(store, job, metric)
        if not series:
            continue
        svg = render_timeseries_svg(series, f"{metric} — {job}", metric)
        name = f"ts_{metric}.svg"
        (out / name).write_text(svg)
        svgs.append(svg)
        md.append(f"![{metric}]({name})\n")

    # statistical min/median/max view (large-job dashboard)
    stat = job_statistical_view(store, job, "gflops")
    if any(stat.values()):
        svg = render_timeseries_svg(
            stat, f"gflops min/median/max across hosts — {job}", "gflops")
        (out / "stat_gflops.svg").write_text(svg)
        svgs.append(svg)
        md.append("## Statistical view (all hosts)\n\n"
                  "![stat](stat_gflops.svg)\n")

    # detector findings for this job
    bank = DetectorBank()
    events = [e for e in bank.scan(store, manifests) if e.job == job]
    md.append("## Automated findings")
    if events:
        md.append(markdown_table([
            {"severity": e.severity, "detector": e.detector,
             "message": e.message} for e in events]))
    else:
        md.append("No issues detected.\n")

    # environment / meta
    meta = query(store, f"search kind=meta job={job} | head 1")
    if meta:
        md.append("## Job environment")
        md.append(markdown_table([{k: _fmt(v) for k, v in meta[0].items()
                                   if k not in ("ts",)}]))

    md_text = "\n".join(md) + "\n"
    md_path = out / "report.md"
    md_path.write_text(md_text)

    # single-file printable HTML ("PDF" stand-in)
    body = []
    for line in md:
        if line.startswith("# "):
            body.append(f"<h1>{html.escape(line[2:])}</h1>")
        elif line.startswith("## "):
            body.append(f"<h2>{html.escape(line[3:])}</h2>")
        elif line.startswith("!["):
            continue  # svgs are embedded below their section instead
        elif line.startswith("|"):
            body.append(f"<pre>{html.escape(line)}</pre>")
        elif line:
            body.append(f"<p>{html.escape(line)}</p>")
    svg_html = "\n".join(svgs)
    (out / "report.html").write_text(
        "<!doctype html><html><head><meta charset='utf-8'>"
        f"<title>{html.escape(job)}</title></head><body>"
        + "\n".join(body) + svg_html + "</body></html>")
    return md_path
