"""repro.core — the paper's contribution: job-specific HPC performance
monitoring (hpcmd middleware + transport + splunklite analysis), adapted
to JAX/TPU jobs.  See DESIGN.md for the full mapping."""

from repro.core.aggregator import Aggregator, MetricStore
from repro.core.columnar import ColumnarMetricStore, ColumnScan, Segment
from repro.core.daemon import DaemonConfig, Hpcmd, JobManifest
from repro.core.derived import (HardwareSpec, RooflineTerms, TPU_V5E, mfu,
                                roofline_terms)
from repro.core.detectors import DetectorBank, DetectorEvent
from repro.core.hooks import TrainMonitor, load_manifests
from repro.core.remote import RemoteShardedAggregator
from repro.core.schema import MetricRecord, encode_line, parse_line
from repro.core.service import QueryResult, QueryService, QuotaExceeded
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import query, query_with_stats
from repro.core.telemetry import SelfMonitor, Telemetry, format_trace

__all__ = [
    "Aggregator", "MetricStore", "ColumnarMetricStore", "ColumnScan",
    "Segment", "DaemonConfig", "Hpcmd", "JobManifest",
    "HardwareSpec", "RooflineTerms", "TPU_V5E", "mfu", "roofline_terms",
    "DetectorBank", "DetectorEvent", "RemoteShardedAggregator",
    "ShardedAggregator", "TrainMonitor",
    "load_manifests", "MetricRecord", "encode_line", "parse_line", "query",
    "query_with_stats", "QueryService", "QueryResult", "QuotaExceeded",
    "SelfMonitor", "Telemetry", "format_trace",
]
