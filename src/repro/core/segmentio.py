"""Self-describing on-disk files for sealed columnar segments.

The paper's Splunk tier keeps the full metric index on disk with
unlimited retention (§4.3).  PR 1 made the in-memory representation
columnar; this module makes sealed segments *durable* so an aggregator
restart loads them back as column arrays instead of re-parsing the
line-oriented archive (PerSyst and the LIKWID Monitoring Stack both
identify restart/replay cost as the practical limit on retention).

Layout per sealed segment (two files, committed atomically):

``seg-XXXXXXXX.bin``
    Raw little-endian column arrays (float64 values, bool presence and
    int-ness masks, int32 dictionary codes) plus the segment's 12-byte
    dedup keys, concatenated with 64-byte alignment.  Never rewritten.

``seg-XXXXXXXX.json``
    Manifest: format tag, row count, ts range, per-column descriptors
    (array byte offsets/lengths into the ``.bin``, string vocabularies,
    JSON-encoded object-column values), numeric zone maps, and the
    dedup-key extent.  Written *last* via ``os.replace`` — the manifest
    is the commit point.  A ``.bin`` without its manifest is an
    interrupted seal and is ignored by the loader (its rows are still
    in the store's write-ahead log).

Readers memory-map the ``.bin`` once (``np.memmap``) and build column
objects lazily: a column's array views are only constructed — and its
pages only faulted in — when a query actually touches it.  Zone maps
and dictionaries live in the manifest, so segment pruning never touches
the ``.bin`` at all.

**Cold tier** (``save_segment(..., compress=True)``, format tag
``repro-colseg-z1``): the same two-file commit protocol, but column
arrays are stored compressed — delta-of-delta timestamps, run-length
string codes, byte-shuffled float64 values, bit-packed boolean masks,
each finished with zlib.  Decoding is *per column on first access*
(the ``MappedSegment`` lazy-column machinery), so a zone-map-pruned
cold segment never pays any decode cost: pruning reads only the
manifest, exactly as in the raw tier.  All codecs are bit-exact
round-trips (delta-of-delta runs on the float64 *bit patterns* in
modular uint64 arithmetic), so cold reads are byte-identical to raw
reads.  See docs/storage.md for the codec table and tier lifecycle.
"""

from __future__ import annotations

import json
import os
import zlib
from collections.abc import Mapping
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core import faults
from repro.core.columnar import (MISSING, NumColumn, ObjColumn, Segment,
                                 StrColumn, segment_uid)

FORMAT = "repro-colseg-v1"
FORMAT_COLD = "repro-colseg-z1"
FORMATS = (FORMAT, FORMAT_COLD)
SHARDSET_FORMAT = "repro-shardset-v1"
SEGMENT_STEM_FMT = "seg-{:08d}"
SHARDSET_MANIFEST = "shards.json"
QUARANTINE_DIRNAME = "quarantine"
_ALIGN = 64


class WalCorruptionError(ValueError):
    """A checksummed WAL has a bad line *before* its final record.

    A torn tail (crash mid-append) can only damage the last line, and
    that line is silently truncated as before.  Corruption anywhere
    earlier means acknowledged records were damaged at rest — replay
    must stop with a typed error instead of silently dropping every
    record from that point (the pre-checksum behavior)."""


# -------------------------------------------------------------------- write --

def fsync_dir(path: os.PathLike) -> None:
    """fsync a directory so renamed-in entries survive power loss
    (``os.replace`` alone does not guarantee directory durability on
    ext4/xfs).  Best-effort: silently skipped where unsupported."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class _BinWriter:
    """Accumulates raw arrays with aligned offsets."""

    def __init__(self) -> None:
        self.chunks: List[bytes] = []
        self.size = 0

    def add(self, arr: np.ndarray) -> List[int]:
        """Append an array; returns its ``[offset, count]`` descriptor."""
        return self.add_bytes(np.ascontiguousarray(arr).tobytes(),
                              count=int(arr.size))

    def add_bytes(self, data: bytes, count: int = None) -> List[int]:
        """Append raw bytes; returns ``[offset, count-or-nbytes]``."""
        pad = (-self.size) % _ALIGN
        if pad:
            self.chunks.append(b"\0" * pad)
            self.size += pad
        off = self.size
        self.chunks.append(data)
        self.size += len(data)
        return [off, len(data) if count is None else count]


# ------------------------------------------------------------- cold codecs --
#
# Every codec is a bit-exact round trip; zlib finishes each payload.
#   bits   bool mask        -> np.packbits
#   shuf8  float64 values   -> byte transpose (all byte-0s, then byte-1s, ...)
#   dod    float64 ts       -> double delta over the uint64 bit patterns
#                              (wrapping arithmetic, exact) + byte transpose
#   rle32  int32 dict codes -> run values ++ run lengths

def _shuffle8(a: np.ndarray) -> bytes:
    return np.ascontiguousarray(
        a.reshape(-1).view(np.uint8).reshape(-1, 8).T).tobytes()


def _unshuffle8(data: bytes, n: int) -> np.ndarray:
    u8 = np.frombuffer(data, np.uint8)
    if u8.size != n * 8:
        raise ValueError("corrupt shuffled column payload")
    return np.ascontiguousarray(u8.reshape(8, n).T).reshape(-1).view("<u8")


def _encode_array(arr: np.ndarray, codec: str) -> bytes:
    if codec == "bits":
        return zlib.compress(np.packbits(arr.view(np.uint8)).tobytes())
    if codec == "shuf8":
        return zlib.compress(
            _shuffle8(np.ascontiguousarray(arr, "<f8").view("<u8")))
    if codec == "dod":
        a = np.ascontiguousarray(arr, "<f8").view("<u8")
        d1 = np.empty_like(a)
        d2 = np.empty_like(a)
        if a.size:
            d1[0] = a[0]
            np.subtract(a[1:], a[:-1], out=d1[1:])
            d2[0] = d1[0]
            np.subtract(d1[1:], d1[:-1], out=d2[1:])
        return zlib.compress(_shuffle8(d2))
    if codec == "rle32":
        codes = np.ascontiguousarray(arr, "<i4")
        if codes.size:
            starts = np.concatenate(
                [[0], np.flatnonzero(codes[1:] != codes[:-1]) + 1])
            runs = np.concatenate(
                [codes[starts],
                 np.diff(np.concatenate([starts, [codes.size]]))])
        else:
            runs = codes
        return zlib.compress(runs.astype("<i4", copy=False).tobytes())
    raise ValueError(f"unknown segment codec {codec!r}")


def _decode_array(data: bytes, codec: str, n: int) -> np.ndarray:
    if codec == "bits":
        out = np.unpackbits(np.frombuffer(data, np.uint8), count=n)
        out = out.view(np.bool_)
    elif codec == "shuf8":
        out = _unshuffle8(data, n).view("<f8")
    elif codec == "dod":
        d2 = _unshuffle8(data, n)
        d1 = np.add.accumulate(d2, dtype=np.uint64)
        out = np.add.accumulate(d1, dtype=np.uint64).view("<f8")
    elif codec == "rle32":
        runs = np.frombuffer(data, "<i4")
        half = runs.size // 2
        out = np.repeat(runs[:half], runs[half:]).astype("<i4", copy=False)
    else:
        raise ValueError(f"unknown segment codec {codec!r}")
    if out.size != n:
        raise ValueError(f"codec {codec!r}: decoded {out.size} of {n} rows")
    out.flags.writeable = False  # immutability parity with mmap views
    return out


def _zref(w: _BinWriter, arr: np.ndarray, codec: str) -> List:
    """Encoded-array descriptor ``[codec, offset, nbytes]``."""
    data = _encode_array(arr, codec)
    off, nbytes = w.add_bytes(data)
    return [codec, off, nbytes]


def _col_logical_bytes(col) -> int:
    """Bytes the raw (hot-tier) ``.bin`` encoding of this column takes —
    the compression denominator reported as ``raw_bytes``."""
    n = len(col.present) if col.kind != "str" else len(col.codes)
    if col.kind == "num":
        return 10 * n          # 8B value + present + is_int per row
    if col.kind == "str":
        return 4 * n           # int32 dictionary code per row
    return n                   # obj: present mask (values live in JSON)


def _col_spec(col, w: _BinWriter, compress: bool = False,
              dod: bool = False) -> Dict:
    if col.kind == "num":
        if compress:
            return {"kind": "num", "n": len(col.vals),
                    "zvals": _zref(w, col.vals, "dod" if dod else "shuf8"),
                    "zpresent": _zref(w, col.present, "bits"),
                    "zis_int": _zref(w, col.is_int, "bits")}
        return {"kind": "num",
                "vals": w.add(col.vals.astype("<f8", copy=False)),
                "present": w.add(col.present),
                "is_int": w.add(col.is_int)}
    if col.kind == "str":
        spec = {"kind": "str",
                "vocab": [str(v) for v in col.vocab.tolist()]}
        if compress:
            spec["n"] = len(col.codes)
            spec["zcodes"] = _zref(w, col.codes, "rle32")
        else:
            spec["codes"] = w.add(col.codes.astype("<i4", copy=False))
        return spec
    # obj fallback: values are wire scalars (insert() canonicalizes every
    # record through encode_line, so nothing non-JSON-able can get here);
    # the explicit present mask disambiguates absent rows.
    values = [v if p else None
              for v, p in zip(col.vals.tolist(), col.present.tolist())]
    spec = {"kind": "obj", "values": values}
    if compress:
        spec["zpresent"] = _zref(w, col.present, "bits")
    else:
        spec["present"] = w.add(col.present)
    return spec


def save_segment(seg_dir: os.PathLike, stem: str, seg: Segment,
                 dedup_keys: Iterable[bytes], compress: bool = False,
                 fsync: bool = True, extra: Dict = None) -> Path:
    """Persist one sealed segment; returns the committed manifest path.

    Commit protocol: ``.bin`` first (fsync + rename), manifest last
    (fsync + rename).  A crash at any point leaves either nothing or an
    orphan ``.bin`` — never a manifest describing missing data.

    ``compress=True`` writes the cold-tier encoding (format tag
    ``repro-colseg-z1``; see module docstring).  ``fsync=False`` skips
    the per-file fsyncs (callers whose durability window is already
    covered by the WAL, e.g. streaming seals under ``wal_fsync=False``).
    ``extra`` merges additional manifest keys — the compaction tier uses
    it for ``tier``/``replaces``/``rollup`` annotations.
    """
    seg_dir = Path(seg_dir)
    seg_dir.mkdir(parents=True, exist_ok=True)
    w = _BinWriter()
    attrs = {k: _col_spec(seg.attrs[k], w, compress=compress,
                          dod=(k == "ts"))
             for k in ("ts", "host", "job", "kind")}
    fields = {k: _col_spec(seg.cols[k], w, compress=compress)
              for k in seg.field_names}
    zones = {name: list(seg.zone(name))
             for name, col in seg.cols.items() if col.kind == "num"}
    raw_bytes = sum(_col_logical_bytes(seg.attrs[k])
                    for k in ("ts", "host", "job", "kind"))
    raw_bytes += sum(_col_logical_bytes(seg.cols[k])
                     for k in seg.field_names)
    keys = sorted(dedup_keys)
    karr = (np.frombuffer(b"".join(keys), dtype=np.uint8)
            if keys else np.zeros(0, np.uint8))
    digest_size = len(keys[0]) if keys else 12
    dedup_spec = {"digest_size": digest_size, "count": len(keys),
                  "keys": w.add(karr)}
    # the checksum covers every payload chunk — dedup keys included —
    # so it must be computed after the final w.add above
    crc = 0
    for chunk in w.chunks:
        crc = faults.crc32c(chunk, crc)
    manifest = {
        "format": FORMAT_COLD if compress else FORMAT,
        "n": seg.n,
        "uid": seg.uid if seg.uid is not None else segment_uid(keys),
        "ts_min": seg.ts_min,
        "ts_max": seg.ts_max,
        "attrs": attrs,
        "fields": fields,
        "zones": zones,
        "dedup": dedup_spec,
        "bin_bytes": w.size,
        "raw_bytes": raw_bytes,
        "crc32c": crc,
        "tier": "cold" if compress else "hot",
    }
    if extra:
        manifest.update(extra)
    bin_path = seg_dir / (stem + ".bin")
    man_path = seg_dir / (stem + ".json")
    # fault injection (tests/bench only; a no-op None check otherwise):
    # simulate the commit protocol's crash windows and a full disk
    fault = faults.storage_fault("seal")
    if fault == "enospc":
        raise faults.enospc(bin_path)
    tmp = Path(str(bin_path) + ".tmp")
    with open(tmp, "wb") as f:
        for chunk in w.chunks:
            f.write(chunk)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if fault == "torn_bin":
        # crash after a partial .bin rename, before the manifest: the
        # loader must treat the stem as invisible (no commit point)
        with open(tmp, "r+b") as f:
            f.truncate(max(0, w.size // 2))
        os.replace(tmp, bin_path)
        raise faults.enospc(man_path)
    os.replace(tmp, bin_path)
    tmp = Path(str(man_path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if fault == "torn_manifest":
        # crash mid-manifest-write: a garbage half-file at the final
        # name — the loader must skip it (counted in
        # segment_load_errors) and recover the rows from the WAL
        blob = json.dumps(manifest)
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(blob[:len(blob) // 2])
        os.replace(tmp, man_path)
        raise faults.enospc(man_path)
    os.replace(tmp, man_path)
    if fsync:
        fsync_dir(seg_dir)
    return man_path


# --------------------------------------------------------------------- read --

class _LazyCols(Mapping):
    """Name -> column mapping that builds columns on first access.

    Membership, iteration and ``len`` never touch the ``.bin`` file, so
    planner-side checks (``name in seg.cols``) stay free.
    """

    __slots__ = ("_build", "_names", "_built")

    def __init__(self, build, names: Iterable[str]) -> None:
        self._build = build
        self._names = dict.fromkeys(names)
        self._built: Dict[str, object] = {}

    def __getitem__(self, name: str):
        col = self._built.get(name)
        if col is None:
            if name not in self._names:
                raise KeyError(name)
            col = self._built[name] = self._build(name)
        return col

    def __contains__(self, name) -> bool:
        return name in self._names

    def __iter__(self):
        return iter(self._names)

    def __len__(self) -> int:
        return len(self._names)


class MappedSegment(Segment):
    """A sealed segment backed by a memory-mapped ``.bin`` file.

    Fully substitutable for an in-memory :class:`Segment`: same
    ``attrs``/``cols``/``field_names``/``zone`` surface, so scans,
    splunklite execution, dashboards and record materialization behave
    identically.  Column objects are built on demand; their arrays are
    read-only views into the map (immutability for free).
    """

    __slots__ = ("_man", "_mm", "_shared")

    def __init__(self, manifest: Dict, mm: np.ndarray) -> None:
        self._man = manifest
        self._mm = mm
        self._shared: Dict[Tuple[str, str], object] = {}
        self.n = int(manifest["n"])
        self.field_names = list(manifest["fields"])
        # content identity: written by save_segment since the manifest
        # grew a "uid" field; recomputed from the persisted dedup keys
        # for manifests from before it existed (same derivation, same
        # value — uid is a pure function of segment content)
        uid = manifest.get("uid")
        self.uid = uid if uid is not None else segment_uid(self.dedup_keys())
        self.ts_min = float(manifest["ts_min"])
        self.ts_max = float(manifest["ts_max"])
        self.tier = manifest.get("tier", "hot")
        self.rollup = manifest.get("rollup")
        self._zones = {k: (float(v[0]), float(v[1]))
                       for k, v in manifest["zones"].items()}
        self.attrs = _LazyCols(self._attr_col, manifest["attrs"])
        names = dict.fromkeys(manifest["attrs"])
        names.update(dict.fromkeys(manifest["fields"]))
        self.cols = _LazyCols(self._view_col, names)

    # ----------------------------------------------------------- builders --
    def _arr(self, ref: List[int], dtype: str) -> np.ndarray:
        off, count = ref
        dt = np.dtype(dtype)
        end = off + count * dt.itemsize
        if end > self._mm.size:
            raise ValueError("column extends past end of .bin")
        return self._mm[off:end].view(dt)

    def _zarr(self, zref: List, n: int) -> np.ndarray:
        """Decode one cold-tier encoded array ``[codec, off, nbytes]``.
        Runs once per column per open (cached via the lazy-column maps),
        and never runs at all for zone-map-pruned segments."""
        codec, off, nbytes = zref[0], int(zref[1]), int(zref[2])
        if off + nbytes > self._mm.size:
            raise ValueError("encoded column extends past end of .bin")
        return _decode_array(zlib.decompress(self._mm[off:off + nbytes]),
                             codec, n)

    def _build(self, spec: Dict):
        kind = spec["kind"]
        if kind == "num":
            if "zvals" in spec:
                n = int(spec["n"])
                return NumColumn(self._zarr(spec["zvals"], n),
                                 self._zarr(spec["zpresent"], n),
                                 self._zarr(spec["zis_int"], n))
            return NumColumn(self._arr(spec["vals"], "<f8"),
                             self._arr(spec["present"], "|b1"),
                             self._arr(spec["is_int"], "|b1"))
        if kind == "str":
            vocab_list = spec["vocab"]
            vocab = np.empty(len(vocab_list), dtype=object)
            vocab[:] = vocab_list
            index = {v: i for i, v in enumerate(vocab_list)}
            codes = (self._zarr(spec["zcodes"], int(spec["n"]))
                     if "zcodes" in spec else self._arr(spec["codes"], "<i4"))
            return StrColumn(codes, vocab, index)
        present = (self._zarr(spec["zpresent"], self.n)
                   if "zpresent" in spec
                   else self._arr(spec["present"], "|b1"))
        vals = np.empty(self.n, dtype=object)
        for i, v in enumerate(spec["values"]):
            vals[i] = v if present[i] else MISSING
        return ObjColumn(vals, present)

    def _attr_col(self, name: str):
        key = ("attr", name)
        col = self._shared.get(key)
        if col is None:
            col = self._shared[key] = self._build(self._man["attrs"][name])
        return col

    def _view_col(self, name: str):
        # query view: metric fields shadow same-named attrs (as_dict
        # semantics), mirroring Segment.cols construction order
        spec = self._man["fields"].get(name)
        if spec is None:
            return self._attr_col(name)
        key = ("field", name)
        col = self._shared.get(key)
        if col is None:
            col = self._shared[key] = self._build(spec)
        return col

    # -------------------------------------------------------------- dedup --
    def dedup_keys(self) -> Set[bytes]:
        d = self._man["dedup"]
        raw = self._arr(d["keys"], "|u1").tobytes()
        size = int(d["digest_size"])
        return {raw[i * size:(i + 1) * size] for i in range(int(d["count"]))}


def segment_crc_ok(manifest: Dict, bin_path: os.PathLike
                   ) -> Optional[bool]:
    """Verify a segment's ``.bin`` payload against the ``crc32c`` its
    manifest recorded at seal.  Returns ``None`` for manifests from
    before the checksum existed (nothing to verify), ``False`` on a
    mismatch or unreadable file, ``True`` when the bytes are intact."""
    want = manifest.get("crc32c")
    if want is None:
        return None
    nbytes = int(manifest.get("bin_bytes", 0))
    try:
        mm = np.memmap(bin_path, dtype=np.uint8, mode="r") \
            if nbytes else np.zeros(0, np.uint8)
    except (OSError, ValueError):
        return False
    if mm.size < nbytes:
        return False
    return faults.crc32c(mm[:nbytes]) == int(want)


def quarantine_segment_files(man_path: os.PathLike) -> Path:
    """Move a corrupt segment's file pair into the sibling
    ``quarantine/`` directory (kept for forensics, invisible to the
    loader).  The ``.bin`` moves first: if quarantining itself is
    interrupted, the survivor state is a manifest without data — an
    interrupted seal, which the loader already skips.  Returns the
    quarantine directory."""
    man_path = Path(man_path)
    qdir = man_path.parent / QUARANTINE_DIRNAME
    qdir.mkdir(parents=True, exist_ok=True)
    for victim in (man_path.with_suffix(".bin"), man_path):
        try:
            os.replace(victim, qdir / victim.name)
        except OSError:
            pass
    fsync_dir(man_path.parent)
    fsync_dir(qdir)
    return qdir


def copy_segment_files(src_manifest: os.PathLike, dest_dir: os.PathLike,
                       stem: str, fsync: bool = True) -> Path:
    """Copy one committed segment's file pair under a new stem (segment
    routing between stores/shards: segments are immutable shippable
    units, so adoption is a byte copy, never a row re-parse).  Follows
    the seal commit protocol — ``.bin`` first, manifest last via
    ``os.replace`` — so an interrupted copy never leaves a manifest
    describing missing data.  Returns the new manifest path."""
    import shutil
    src_manifest = Path(src_manifest)
    with open(src_manifest, encoding="utf-8") as f:
        manifest = json.load(f)
    if not isinstance(manifest, dict) or manifest.get("format") not in FORMATS:
        raise ValueError(f"not a {FORMAT} manifest: {src_manifest}")
    # "replaces" names *source-store* stems retired by a compaction; the
    # stems are meaningless (and possibly colliding) in the destination
    manifest.pop("replaces", None)
    dest_dir = Path(dest_dir)
    dest_dir.mkdir(parents=True, exist_ok=True)
    bin_path = dest_dir / (stem + ".bin")
    man_path = dest_dir / (stem + ".json")
    tmp = Path(str(bin_path) + ".tmp")
    shutil.copyfile(src_manifest.with_suffix(".bin"), tmp)
    # integrity gate: adoption is how corruption would *spread* (shard
    # migration, replica catch-up), so the copied payload is verified
    # against the manifest checksum before it can be committed here
    if segment_crc_ok(manifest, tmp) is False:
        tmp.unlink(missing_ok=True)
        raise ValueError(
            f"segment payload failed checksum during copy: {src_manifest}")
    if fsync:
        with open(tmp, "rb") as f:
            os.fsync(f.fileno())
    os.replace(tmp, bin_path)
    tmp = Path(str(man_path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, man_path)
    if fsync:
        fsync_dir(dest_dir)
    return man_path


def wal_encode_line(payload: str) -> str:
    """One checksummed WAL line: ``<crc32c hex8> <payload>``.  The
    checksum covers the payload bytes only — the newline terminator is
    the framing, not part of the record."""
    return f"{faults.crc32c(payload.encode('utf-8')):08x} {payload}"


def _wal_decode_line(raw: bytes) -> Optional[str]:
    """Payload of one checksummed WAL line, or ``None`` when the line
    fails its checksum / is not checksum-framed."""
    if len(raw) < 10 or raw[8:9] != b" ":
        return None
    head, payload = raw[:8], raw[9:]
    try:
        want = int(head, 16)
    except ValueError:
        return None
    if faults.crc32c(payload) != want:
        return None
    try:
        return payload.decode("utf-8")
    except UnicodeDecodeError:
        return None


def read_complete_wal_lines(path: os.PathLike) -> List[str]:
    """Decoded complete lines of a write-ahead log, dropping a torn
    trailing write (a crash mid-append must never yield a partial
    record, and the torn bytes must not concatenate with the next
    accepted line).  Shared by store restart replay and shard-set
    migration so the WAL framing rules live in one place.

    Lines written since PR 9 carry a per-line crc32c prefix
    (:func:`wal_encode_line`).  For a checksummed WAL the rules are
    strict: only the *final* line may fail verification (that is the
    torn-tail crash window — it is dropped, as before); a bad line with
    valid lines after it is corruption of acknowledged records and
    raises :class:`WalCorruptionError` instead of silently dropping
    data.  A WAL with no verifiable line at all (legacy format, from
    before the checksum) keeps the old lenient behavior: complete lines
    pass through, the unterminated tail is dropped."""
    try:
        data = Path(path).read_bytes()
    except OSError:
        return []
    if not data:
        return []
    raw_lines = [ln for ln in data.split(b"\n") if ln]
    decoded = [_wal_decode_line(raw) for raw in raw_lines]
    if not any(d is not None for d in decoded):
        # legacy WAL (or damaged beyond recognition): pre-checksum rules
        end = data.rfind(b"\n")
        if end < 0:
            return []
        return [raw.decode("utf-8", errors="replace")
                for raw in data[:end + 1].split(b"\n") if raw]
    bad = [i for i, d in enumerate(decoded) if d is None]
    if bad and bad != [len(decoded) - 1]:
        raise WalCorruptionError(
            f"{path}: line {bad[0] + 1} of {len(decoded)} failed its "
            "checksum with intact records after it — mid-file "
            "corruption, not a torn tail")
    if bad:
        decoded.pop()  # torn final append: truncated, as before
    return [d for d in decoded if d is not None]


# ---------------------------------------------------------------- shardset --

def save_shardset_manifest(directory: os.PathLike, meta: Dict) -> Path:
    """Atomically write a shard-set manifest (``shards.json``): the
    routing policy and shard directory names for a sharded aggregator.
    Each named shard directory stays a complete standalone store."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    manifest = {"format": SHARDSET_FORMAT}
    manifest.update(meta)
    path = directory / SHARDSET_MANIFEST
    tmp = Path(str(path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(directory)
    return path


def load_shardset_manifest(directory: os.PathLike) -> Dict:
    """Read a shard-set manifest; ``None`` when the directory has none
    (fresh shard set).  Raises ``ValueError`` on a foreign file."""
    path = Path(directory) / SHARDSET_MANIFEST
    try:
        with open(path, encoding="utf-8") as f:
            manifest = json.load(f)
    except OSError:
        return None
    except ValueError as exc:
        raise ValueError(f"corrupt shard-set manifest: {path}") from exc
    if not isinstance(manifest, dict) or \
            manifest.get("format") != SHARDSET_FORMAT:
        raise ValueError(f"not a {SHARDSET_FORMAT} manifest: {path}")
    return manifest


def update_shardset_manifest(directory: os.PathLike, extra: Dict) -> Dict:
    """Atomically merge informational keys into an existing shard-set
    manifest (read-modify-write through the same tmp+rename commit as
    :func:`save_shardset_manifest`).

    The remote tier records its last-spawned worker topology here
    (host/port/pid per shard) so operators can see which processes
    served a fleet; routing-critical keys are validated on open and
    refuse to change through this side door.  Returns the merged
    manifest."""
    manifest = load_shardset_manifest(directory)
    if manifest is None:
        raise ValueError(f"no shard-set manifest under {directory}")
    for key in ("format", "num_shards", "policy", "time_window_s",
                "shard_dirs"):
        if key in extra and extra[key] != manifest.get(key):
            raise ValueError(
                f"refusing to rewrite routing key {key!r} via update")
    manifest.update(extra)
    manifest.pop("format", None)  # save_shardset_manifest re-stamps it
    save_shardset_manifest(directory, manifest)
    manifest["format"] = SHARDSET_FORMAT
    return manifest


def stamp_replication(directory: os.PathLike, k: int,
                      members: List[Dict]) -> Dict:
    """Record the replica-set topology in the shard-set manifest,
    epoch-stamped (docs/replication.md).

    ``members`` is one entry per ``(shard, replica)`` worker — replica
    0 is the shard's primary (the only member that accepts writes);
    entries carry the served directory name plus whatever liveness info
    the caller has (host/port/pid).  Every call bumps ``epoch``, so
    after failover/restart churn an observer can tell the current
    topology from a stale copy.  Routing keys are still protected by
    :func:`update_shardset_manifest` — replication is an overlay, never
    a rewrite of how records route to shards.  Returns the
    ``replication`` block as written."""
    manifest = load_shardset_manifest(directory)
    prev = manifest.get("replication") if manifest else None
    epoch = (int(prev.get("epoch", 0)) + 1) if isinstance(prev, dict) else 1
    block = {"k": int(k), "epoch": epoch, "members": list(members)}
    update_shardset_manifest(directory, {"replication": block})
    return block


def load_segment(manifest_path: os.PathLike,
                 manifest: Optional[Dict] = None) -> MappedSegment:
    """Map one committed segment.  Raises ``ValueError``/``OSError`` on
    missing, foreign-format, or truncated files (callers skip those —
    an interrupted seal's rows are recovered from the WAL instead).
    ``manifest`` short-circuits the JSON read for callers that already
    parsed it (the store's restart loader)."""
    manifest_path = Path(manifest_path)
    if manifest is None:
        with open(manifest_path, encoding="utf-8") as f:
            manifest = json.load(f)
    if not isinstance(manifest, dict) or manifest.get("format") not in FORMATS:
        raise ValueError(f"not a {FORMAT} manifest: {manifest_path}")
    bin_path = manifest_path.with_suffix(".bin")
    mm = np.memmap(bin_path, dtype=np.uint8, mode="r")
    if mm.size < int(manifest.get("bin_bytes", 0)):
        raise ValueError(f"truncated segment data file: {bin_path}")
    return MappedSegment(manifest, mm)
