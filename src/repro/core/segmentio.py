"""Self-describing on-disk files for sealed columnar segments.

The paper's Splunk tier keeps the full metric index on disk with
unlimited retention (§4.3).  PR 1 made the in-memory representation
columnar; this module makes sealed segments *durable* so an aggregator
restart loads them back as column arrays instead of re-parsing the
line-oriented archive (PerSyst and the LIKWID Monitoring Stack both
identify restart/replay cost as the practical limit on retention).

Layout per sealed segment (two files, committed atomically):

``seg-XXXXXXXX.bin``
    Raw little-endian column arrays (float64 values, bool presence and
    int-ness masks, int32 dictionary codes) plus the segment's 12-byte
    dedup keys, concatenated with 64-byte alignment.  Never rewritten.

``seg-XXXXXXXX.json``
    Manifest: format tag, row count, ts range, per-column descriptors
    (array byte offsets/lengths into the ``.bin``, string vocabularies,
    JSON-encoded object-column values), numeric zone maps, and the
    dedup-key extent.  Written *last* via ``os.replace`` — the manifest
    is the commit point.  A ``.bin`` without its manifest is an
    interrupted seal and is ignored by the loader (its rows are still
    in the store's write-ahead log).

Readers memory-map the ``.bin`` once (``np.memmap``) and build column
objects lazily: a column's array views are only constructed — and its
pages only faulted in — when a query actually touches it.  Zone maps
and dictionaries live in the manifest, so segment pruning never touches
the ``.bin`` at all.
"""

from __future__ import annotations

import json
import os
from collections.abc import Mapping
from pathlib import Path
from typing import Dict, Iterable, List, Set, Tuple

import numpy as np

from repro.core.columnar import (MISSING, NumColumn, ObjColumn, Segment,
                                 StrColumn, segment_uid)

FORMAT = "repro-colseg-v1"
SHARDSET_FORMAT = "repro-shardset-v1"
SEGMENT_STEM_FMT = "seg-{:08d}"
SHARDSET_MANIFEST = "shards.json"
_ALIGN = 64


# -------------------------------------------------------------------- write --

def fsync_dir(path: os.PathLike) -> None:
    """fsync a directory so renamed-in entries survive power loss
    (``os.replace`` alone does not guarantee directory durability on
    ext4/xfs).  Best-effort: silently skipped where unsupported."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class _BinWriter:
    """Accumulates raw arrays with aligned offsets."""

    def __init__(self) -> None:
        self.chunks: List[bytes] = []
        self.size = 0

    def add(self, arr: np.ndarray) -> List[int]:
        """Append an array; returns its ``[offset, count]`` descriptor."""
        pad = (-self.size) % _ALIGN
        if pad:
            self.chunks.append(b"\0" * pad)
            self.size += pad
        off = self.size
        data = np.ascontiguousarray(arr).tobytes()
        self.chunks.append(data)
        self.size += len(data)
        return [off, int(arr.size)]


def _col_spec(col, w: _BinWriter) -> Dict:
    if col.kind == "num":
        return {"kind": "num",
                "vals": w.add(col.vals.astype("<f8", copy=False)),
                "present": w.add(col.present),
                "is_int": w.add(col.is_int)}
    if col.kind == "str":
        return {"kind": "str",
                "codes": w.add(col.codes.astype("<i4", copy=False)),
                "vocab": [str(v) for v in col.vocab.tolist()]}
    # obj fallback: values are wire scalars (insert() canonicalizes every
    # record through encode_line, so nothing non-JSON-able can get here);
    # the explicit present mask disambiguates absent rows.
    values = [v if p else None
              for v, p in zip(col.vals.tolist(), col.present.tolist())]
    return {"kind": "obj", "values": values, "present": w.add(col.present)}


def save_segment(seg_dir: os.PathLike, stem: str, seg: Segment,
                 dedup_keys: Iterable[bytes]) -> Path:
    """Persist one sealed segment; returns the committed manifest path.

    Commit protocol: ``.bin`` first (fsync + rename), manifest last
    (fsync + rename).  A crash at any point leaves either nothing or an
    orphan ``.bin`` — never a manifest describing missing data.
    """
    seg_dir = Path(seg_dir)
    seg_dir.mkdir(parents=True, exist_ok=True)
    w = _BinWriter()
    attrs = {k: _col_spec(seg.attrs[k], w)
             for k in ("ts", "host", "job", "kind")}
    fields = {k: _col_spec(seg.cols[k], w) for k in seg.field_names}
    zones = {name: list(seg.zone(name))
             for name, col in seg.cols.items() if col.kind == "num"}
    keys = sorted(dedup_keys)
    karr = (np.frombuffer(b"".join(keys), dtype=np.uint8)
            if keys else np.zeros(0, np.uint8))
    digest_size = len(keys[0]) if keys else 12
    manifest = {
        "format": FORMAT,
        "n": seg.n,
        "uid": seg.uid if seg.uid is not None else segment_uid(keys),
        "ts_min": seg.ts_min,
        "ts_max": seg.ts_max,
        "attrs": attrs,
        "fields": fields,
        "zones": zones,
        "dedup": {"digest_size": digest_size, "count": len(keys),
                  "keys": w.add(karr)},
        "bin_bytes": w.size,
    }
    bin_path = seg_dir / (stem + ".bin")
    man_path = seg_dir / (stem + ".json")
    tmp = Path(str(bin_path) + ".tmp")
    with open(tmp, "wb") as f:
        for chunk in w.chunks:
            f.write(chunk)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, bin_path)
    tmp = Path(str(man_path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, man_path)
    fsync_dir(seg_dir)
    return man_path


# --------------------------------------------------------------------- read --

class _LazyCols(Mapping):
    """Name -> column mapping that builds columns on first access.

    Membership, iteration and ``len`` never touch the ``.bin`` file, so
    planner-side checks (``name in seg.cols``) stay free.
    """

    __slots__ = ("_build", "_names", "_built")

    def __init__(self, build, names: Iterable[str]) -> None:
        self._build = build
        self._names = dict.fromkeys(names)
        self._built: Dict[str, object] = {}

    def __getitem__(self, name: str):
        col = self._built.get(name)
        if col is None:
            if name not in self._names:
                raise KeyError(name)
            col = self._built[name] = self._build(name)
        return col

    def __contains__(self, name) -> bool:
        return name in self._names

    def __iter__(self):
        return iter(self._names)

    def __len__(self) -> int:
        return len(self._names)


class MappedSegment(Segment):
    """A sealed segment backed by a memory-mapped ``.bin`` file.

    Fully substitutable for an in-memory :class:`Segment`: same
    ``attrs``/``cols``/``field_names``/``zone`` surface, so scans,
    splunklite execution, dashboards and record materialization behave
    identically.  Column objects are built on demand; their arrays are
    read-only views into the map (immutability for free).
    """

    __slots__ = ("_man", "_mm", "_shared")

    def __init__(self, manifest: Dict, mm: np.ndarray) -> None:
        self._man = manifest
        self._mm = mm
        self._shared: Dict[Tuple[str, str], object] = {}
        self.n = int(manifest["n"])
        self.field_names = list(manifest["fields"])
        # content identity: written by save_segment since the manifest
        # grew a "uid" field; recomputed from the persisted dedup keys
        # for manifests from before it existed (same derivation, same
        # value — uid is a pure function of segment content)
        uid = manifest.get("uid")
        self.uid = uid if uid is not None else segment_uid(self.dedup_keys())
        self.ts_min = float(manifest["ts_min"])
        self.ts_max = float(manifest["ts_max"])
        self._zones = {k: (float(v[0]), float(v[1]))
                       for k, v in manifest["zones"].items()}
        self.attrs = _LazyCols(self._attr_col, manifest["attrs"])
        names = dict.fromkeys(manifest["attrs"])
        names.update(dict.fromkeys(manifest["fields"]))
        self.cols = _LazyCols(self._view_col, names)

    # ----------------------------------------------------------- builders --
    def _arr(self, ref: List[int], dtype: str) -> np.ndarray:
        off, count = ref
        dt = np.dtype(dtype)
        end = off + count * dt.itemsize
        if end > self._mm.size:
            raise ValueError("column extends past end of .bin")
        return self._mm[off:end].view(dt)

    def _build(self, spec: Dict):
        kind = spec["kind"]
        if kind == "num":
            return NumColumn(self._arr(spec["vals"], "<f8"),
                             self._arr(spec["present"], "|b1"),
                             self._arr(spec["is_int"], "|b1"))
        if kind == "str":
            vocab_list = spec["vocab"]
            vocab = np.empty(len(vocab_list), dtype=object)
            vocab[:] = vocab_list
            index = {v: i for i, v in enumerate(vocab_list)}
            return StrColumn(self._arr(spec["codes"], "<i4"), vocab, index)
        present = self._arr(spec["present"], "|b1")
        vals = np.empty(self.n, dtype=object)
        for i, v in enumerate(spec["values"]):
            vals[i] = v if present[i] else MISSING
        return ObjColumn(vals, present)

    def _attr_col(self, name: str):
        key = ("attr", name)
        col = self._shared.get(key)
        if col is None:
            col = self._shared[key] = self._build(self._man["attrs"][name])
        return col

    def _view_col(self, name: str):
        # query view: metric fields shadow same-named attrs (as_dict
        # semantics), mirroring Segment.cols construction order
        spec = self._man["fields"].get(name)
        if spec is None:
            return self._attr_col(name)
        key = ("field", name)
        col = self._shared.get(key)
        if col is None:
            col = self._shared[key] = self._build(spec)
        return col

    # -------------------------------------------------------------- dedup --
    def dedup_keys(self) -> Set[bytes]:
        d = self._man["dedup"]
        raw = self._arr(d["keys"], "|u1").tobytes()
        size = int(d["digest_size"])
        return {raw[i * size:(i + 1) * size] for i in range(int(d["count"]))}


def copy_segment_files(src_manifest: os.PathLike, dest_dir: os.PathLike,
                       stem: str, fsync: bool = True) -> Path:
    """Copy one committed segment's file pair under a new stem (segment
    routing between stores/shards: segments are immutable shippable
    units, so adoption is a byte copy, never a row re-parse).  Follows
    the seal commit protocol — ``.bin`` first, manifest last via
    ``os.replace`` — so an interrupted copy never leaves a manifest
    describing missing data.  Returns the new manifest path."""
    import shutil
    src_manifest = Path(src_manifest)
    with open(src_manifest, encoding="utf-8") as f:
        manifest = json.load(f)
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} manifest: {src_manifest}")
    dest_dir = Path(dest_dir)
    dest_dir.mkdir(parents=True, exist_ok=True)
    bin_path = dest_dir / (stem + ".bin")
    man_path = dest_dir / (stem + ".json")
    tmp = Path(str(bin_path) + ".tmp")
    shutil.copyfile(src_manifest.with_suffix(".bin"), tmp)
    if fsync:
        with open(tmp, "rb") as f:
            os.fsync(f.fileno())
    os.replace(tmp, bin_path)
    tmp = Path(str(man_path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, man_path)
    if fsync:
        fsync_dir(dest_dir)
    return man_path


def read_complete_wal_lines(path: os.PathLike) -> List[str]:
    """Decoded complete lines of a write-ahead log, dropping a torn
    trailing write (a crash mid-append must never yield a partial
    record, and the torn bytes must not concatenate with the next
    accepted line).  Shared by store restart replay and shard-set
    migration so the WAL framing rules live in one place."""
    try:
        data = Path(path).read_bytes()
    except OSError:
        return []
    end = data.rfind(b"\n")
    if end < 0:
        return []
    return [raw.decode("utf-8", errors="replace")
            for raw in data[:end + 1].split(b"\n") if raw]


# ---------------------------------------------------------------- shardset --

def save_shardset_manifest(directory: os.PathLike, meta: Dict) -> Path:
    """Atomically write a shard-set manifest (``shards.json``): the
    routing policy and shard directory names for a sharded aggregator.
    Each named shard directory stays a complete standalone store."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    manifest = {"format": SHARDSET_FORMAT}
    manifest.update(meta)
    path = directory / SHARDSET_MANIFEST
    tmp = Path(str(path) + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(directory)
    return path


def load_shardset_manifest(directory: os.PathLike) -> Dict:
    """Read a shard-set manifest; ``None`` when the directory has none
    (fresh shard set).  Raises ``ValueError`` on a foreign file."""
    path = Path(directory) / SHARDSET_MANIFEST
    try:
        with open(path, encoding="utf-8") as f:
            manifest = json.load(f)
    except OSError:
        return None
    except ValueError as exc:
        raise ValueError(f"corrupt shard-set manifest: {path}") from exc
    if not isinstance(manifest, dict) or \
            manifest.get("format") != SHARDSET_FORMAT:
        raise ValueError(f"not a {SHARDSET_FORMAT} manifest: {path}")
    return manifest


def update_shardset_manifest(directory: os.PathLike, extra: Dict) -> Dict:
    """Atomically merge informational keys into an existing shard-set
    manifest (read-modify-write through the same tmp+rename commit as
    :func:`save_shardset_manifest`).

    The remote tier records its last-spawned worker topology here
    (host/port/pid per shard) so operators can see which processes
    served a fleet; routing-critical keys are validated on open and
    refuse to change through this side door.  Returns the merged
    manifest."""
    manifest = load_shardset_manifest(directory)
    if manifest is None:
        raise ValueError(f"no shard-set manifest under {directory}")
    for key in ("format", "num_shards", "policy", "time_window_s",
                "shard_dirs"):
        if key in extra and extra[key] != manifest.get(key):
            raise ValueError(
                f"refusing to rewrite routing key {key!r} via update")
    manifest.update(extra)
    manifest.pop("format", None)  # save_shardset_manifest re-stamps it
    save_shardset_manifest(directory, manifest)
    manifest["format"] = SHARDSET_FORMAT
    return manifest


def load_segment(manifest_path: os.PathLike) -> MappedSegment:
    """Map one committed segment.  Raises ``ValueError``/``OSError`` on
    missing, foreign-format, or truncated files (callers skip those —
    an interrupted seal's rows are recovered from the WAL instead)."""
    manifest_path = Path(manifest_path)
    with open(manifest_path, encoding="utf-8") as f:
        manifest = json.load(f)
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} manifest: {manifest_path}")
    bin_path = manifest_path.with_suffix(".bin")
    mm = np.memmap(bin_path, dtype=np.uint8, mode="r")
    if mm.size < int(manifest.get("bin_bytes", 0)):
        raise ValueError(f"truncated segment data file: {bin_path}")
    return MappedSegment(manifest, mm)
