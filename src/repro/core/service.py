"""Multi-tenant query service: admission control, dedup, result cache.

The direct paths (``splunklite.query_with_stats``, the sharded
aggregators) execute whatever they are handed, immediately, on the
caller's thread.  That is the right contract for a library — and the
wrong one for a monitoring frontend, where hundreds of dashboard
refreshes, ad-hoc analyst queries and fleet-wide admin scans hit the
same store concurrently.  :class:`QueryService` is the thin scheduling
layer in between:

* **Admission control** — per-tenant quotas on *outstanding* work (a
  tenant with a stuck dashboard cannot monopolise the pool) and a bound
  on total queued flights.  Over the queue bound, a submission either
  blocks until the backlog drains (*delay*) or, if the caller marked it
  ``shed_ok``, resolves instantly as *shed* — the caller keeps showing
  its previous answer.  Ingest-driven watch refreshes are the intended
  shed customers: stale-but-recent beats a refresh convoy at
  saturation.
* **In-flight dedup** — identical concurrent plans coalesce onto one
  execution whose result fans out to every waiter.  "Identical" is
  decided by :meth:`_plan_key`, which extends
  ``ScatterPlan.fingerprint`` (deliberately tail-agnostic, see
  docs/incremental.md) with the tail stages, engine and tolerance so
  deduped answers are byte-identical to a private execution.
* **Shared result cache** — a bounded LRU keyed ``(plan_key, store
  version)`` layered *above* the per-segment partial caches.  Partial
  caches make re-execution cheap; the result cache makes repetition
  free.  An entry is stored only when the store version is unchanged
  across the execution, so a result computed while ingest was racing is
  never served for the new version; version-keying makes invalidation
  implicit.
* **Fairness** — two admission classes.  ``interactive`` flights
  (watch/dashboard refreshes, cheap incremental re-aggregations) are
  scheduled first; ``batch`` flights (cold scans, fleet sweeps) are
  capped to half the worker pool so a burst of expensive scans can
  never starve the dashboards.

Results are byte-identical to the direct path: the service runs the
same :func:`repro.core.splunklite.query_with_stats` everybody else
does, just fewer times.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Tuple

from .splunklite import _split_pipeline, compile_scatter_plan, \
    query_with_stats as _direct_query_with_stats
from .telemetry import Telemetry

__all__ = ["QueryService", "QueryResult", "Ticket", "QuotaExceeded"]

Row = Dict[str, Any]

#: Admission classes, in scheduling-priority order.
INTERACTIVE = "interactive"
BATCH = "batch"


class QuotaExceeded(RuntimeError):
    """A tenant is at its outstanding-query quota."""


class QueryResult:
    """Outcome of one submission.

    ``rows``/``stats`` carry the executor's answer (``rows is None``
    only for shed submissions, whose ``stats`` is ``{"shed": True}``).
    ``source`` says how the service satisfied it: ``"executed"`` (this
    submission ran the query), ``"deduped"`` (attached to another
    submission's in-flight execution), ``"cached"`` (shared result
    cache), or ``"shed"`` (dropped under backpressure).
    """

    __slots__ = ("rows", "stats", "source")

    def __init__(self, rows: Optional[List[Row]], stats: Dict,
                 source: str) -> None:
        self.rows = rows
        self.stats = stats
        self.source = source

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        n = "None" if self.rows is None else len(self.rows)
        return f"QueryResult(rows={n}, source={self.source!r})"


class _Flight:
    """One scheduled execution; every coalesced ticket points here."""

    __slots__ = ("key", "q", "engine", "tolerance", "priority", "tickets",
                 "done", "rows", "stats", "error", "span")

    def __init__(self, key: tuple, q: str, engine: Optional[str],
                 tolerance: Optional[float], priority: str,
                 span=None) -> None:
        self.key = key
        self.q = q
        self.engine = engine
        self.tolerance = tolerance
        self.priority = priority
        self.tickets: List["Ticket"] = []
        self.done = threading.Event()
        self.rows: Optional[List[Row]] = None
        self.stats: Optional[Dict] = None
        self.error: Optional[BaseException] = None
        # the submitting request's root span; the worker thread parents
        # its execute span here and finishes it when the flight lands
        self.span = span


class Ticket:
    """A caller's claim on one submission.

    :meth:`result` blocks until the backing flight lands (or returns
    immediately for cached/shed tickets) and returns a
    :class:`QueryResult`; an execution error re-raises in every waiter.
    """

    __slots__ = ("tenant", "source", "_flight", "_result")

    def __init__(self, tenant: str, source: str,
                 flight: Optional[_Flight] = None,
                 result: Optional[QueryResult] = None) -> None:
        self.tenant = tenant
        self.source = source
        self._flight = flight
        self._result = result

    @property
    def done(self) -> bool:
        return self._result is not None or self._flight.done.is_set()

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        if self._result is not None:
            return self._result
        fl = self._flight
        if not fl.done.wait(timeout):
            raise TimeoutError(f"query not done after {timeout}s: {fl.q!r}")
        if fl.error is not None:
            raise fl.error
        self._result = QueryResult(fl.rows, fl.stats, self.source)
        return self._result


class QueryService:
    """Concurrent scheduler over one store (single, sharded or remote).

    See the module docstring for semantics.  ``max_concurrency`` bounds
    worker threads (spawned lazily, daemonic); ``queue_limit`` bounds
    *queued* flights before backpressure kicks in; ``tenant_quota``
    bounds one tenant's outstanding submissions (``0``/``None``
    disables the quota); ``result_cache_size`` bounds the shared LRU
    (``0`` disables it).  Use as a context manager or call
    :meth:`close`.
    """

    def __init__(self, store, max_concurrency: int = 4,
                 queue_limit: int = 32,
                 tenant_quota: Optional[int] = 16,
                 result_cache_size: int = 128,
                 telemetry: Optional[Telemetry] = None) -> None:
        if max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        if queue_limit < 1:
            # 0 would block every non-shed submission forever
            raise ValueError("queue_limit must be >= 1")
        self.store = store
        self.max_concurrency = int(max_concurrency)
        self.queue_limit = int(queue_limit)
        self.tenant_quota = int(tenant_quota or 0)
        self.result_cache_size = int(result_cache_size)
        # batch flights may hold at most half the lanes (min 1), so a
        # convoy of cold scans leaves room for interactive refreshes
        self.batch_slots = max(1, self.max_concurrency // 2)

        self._cond = threading.Condition()
        self._queues: Dict[str, deque] = {INTERACTIVE: deque(),
                                          BATCH: deque()}
        self._inflight: Dict[tuple, _Flight] = {}
        self._result_cache: "OrderedDict[tuple, Tuple[List[Row], Dict]]" = \
            OrderedDict()
        self._outstanding: Dict[str, int] = {}
        self._threads: List[threading.Thread] = []
        self._idle = 0
        self._active = 0
        self._active_batch = 0
        self._closed = False
        self.counters: Dict[str, int] = {
            "submitted": 0, "executed": 0, "deduped": 0,
            "result_cache_hits": 0, "shed": 0, "quota_rejections": 0,
        }
        # share the store's telemetry so service and executor spans
        # land in one trace; plain stores get a private instance
        self.telemetry = telemetry if telemetry is not None else (
            getattr(store, "telemetry", None) or Telemetry(tracing=False))
        self.telemetry.registry.register_collector(
            "service", self._telemetry_samples)

    # ------------------------------------------------------------ admission --
    def _plan_key(self, q: str, engine: Optional[str],
                  tolerance: Optional[float]) -> tuple:
        """Dedup/cache identity of a submission.

        ``ScatterPlan.fingerprint`` is shared by plans that differ only
        in tail stages (that is what lets the partial caches serve
        them), so byte-identical coalescing must add the tail back —
        plus engine and tolerance, which both change the answer.
        """
        stages = _split_pipeline(q)
        plan = compile_scatter_plan(stages, tolerance=tolerance)
        if plan is not None:
            return (plan.fingerprint, repr(plan.tail), engine, tolerance)
        return ("nonmergeable", repr(stages), engine, tolerance)

    def _store_version(self) -> Optional[tuple]:
        ver = getattr(self.store, "_version", None)
        return ver() if callable(ver) else None

    def submit(self, q: str, tenant: str = "default",
               engine: Optional[str] = None,
               tolerance: Optional[float] = None,
               priority: str = INTERACTIVE,
               shed_ok: bool = False) -> Ticket:
        """Admit a query; returns a :class:`Ticket` immediately.

        Raises :class:`QuotaExceeded` when ``tenant`` is at its quota.
        Over ``queue_limit`` queued flights the call blocks until the
        backlog drains — unless ``shed_ok``, which instead returns an
        already-resolved shed ticket (``rows=None``,
        ``stats={"shed": True}``).
        """
        if priority not in self._queues:
            raise ValueError(f"unknown priority {priority!r}")
        tenant = str(tenant)
        root = self.telemetry.tracer.start_span(
            "query.request", attrs={"tenant": tenant,
                                    "priority": priority, "q": q})
        handed_off = failed = False
        try:
            with root.child("plan.compile"):
                key = self._plan_key(q, engine, tolerance)
            adm = root.child("admission")
            try:
                ticket, handed_off = self._admit(
                    q, tenant, engine, tolerance, priority, shed_ok,
                    key, root, adm)
            finally:
                adm.finish()
            return ticket
        except BaseException:
            failed = True
            raise
        finally:
            # executed submissions hand the root span to the flight —
            # the worker finishes it when the query lands, so the span
            # covers the full request latency (queue wait included)
            if not handed_off:
                root.finish("error" if failed else None)

    def _admit(self, q: str, tenant: str, engine: Optional[str],
               tolerance: Optional[float], priority: str, shed_ok: bool,
               key: tuple, root, adm) -> Tuple[Ticket, bool]:
        with self._cond:
            while True:
                if self._closed:
                    raise RuntimeError("QueryService is closed")
                self.counters["submitted"] += 1
                if (self.tenant_quota
                        and self._outstanding.get(tenant, 0)
                        >= self.tenant_quota):
                    self.counters["quota_rejections"] += 1
                    adm.set(outcome="quota_rejected")
                    raise QuotaExceeded(
                        f"tenant {tenant!r} has "
                        f"{self._outstanding[tenant]} outstanding queries "
                        f"(quota {self.tenant_quota})")
                version = self._store_version()
                if version is not None and self.result_cache_size:
                    hit = self._result_cache.get((key, version))
                    if hit is not None:
                        self._result_cache.move_to_end((key, version))
                        self.counters["result_cache_hits"] += 1
                        adm.set(outcome="cached")
                        rows, stats = hit
                        return Ticket(tenant, "cached",
                                      result=QueryResult(
                                          rows, stats, "cached")), False
                fl = self._inflight.get(key)
                if fl is not None:
                    self.counters["deduped"] += 1
                    adm.set(outcome="deduped")
                    if fl.span is not None and fl.span.recording:
                        adm.set(joined_trace=fl.span.trace_id)
                    t = Ticket(tenant, "deduped", flight=fl)
                    fl.tickets.append(t)
                    self._outstanding[tenant] = \
                        self._outstanding.get(tenant, 0) + 1
                    return t, False
                queued = sum(len(dq) for dq in self._queues.values())
                if queued >= self.queue_limit:
                    if shed_ok:
                        self.counters["shed"] += 1
                        adm.set(outcome="shed")
                        return Ticket(tenant, "shed",
                                      result=QueryResult(
                                          None, {"shed": True},
                                          "shed")), False
                    # delay: wait for a worker to drain the backlog,
                    # then re-run admission from scratch (the flight we
                    # want may be in flight or cached by then)
                    self.counters["submitted"] -= 1
                    self._cond.wait()
                    continue
                adm.set(outcome="executed")
                fl = _Flight(key, q, engine, tolerance, priority,
                             span=root)
                t = Ticket(tenant, "executed", flight=fl)
                fl.tickets.append(t)
                self._outstanding[tenant] = \
                    self._outstanding.get(tenant, 0) + 1
                self._inflight[key] = fl
                self._queues[priority].append(fl)
                if self._idle == 0 \
                        and len(self._threads) < self.max_concurrency:
                    th = threading.Thread(
                        target=self._worker_main, daemon=True,
                        name=f"query-service-{len(self._threads)}")
                    self._threads.append(th)
                    th.start()
                self._cond.notify()
                return t, True

    # ---------------------------------------------------------- convenience --
    def query_with_stats(self, q: str, tenant: str = "default",
                         engine: Optional[str] = None,
                         tolerance: Optional[float] = None,
                         priority: str = INTERACTIVE,
                         shed_ok: bool = False,
                         timeout: Optional[float] = None
                         ) -> Tuple[Optional[List[Row]], Dict]:
        """Blocking submit; returns ``(rows, stats)`` like the direct
        path (``(None, {"shed": True})`` for shed submissions)."""
        res = self.submit(q, tenant=tenant, engine=engine,
                          tolerance=tolerance, priority=priority,
                          shed_ok=shed_ok).result(timeout)
        return res.rows, res.stats

    def query(self, q: str, tenant: str = "default",
              engine: Optional[str] = None,
              tolerance: Optional[float] = None,
              priority: str = INTERACTIVE,
              timeout: Optional[float] = None) -> List[Row]:
        rows, _stats = self.query_with_stats(
            q, tenant=tenant, engine=engine, tolerance=tolerance,
            priority=priority, timeout=timeout)
        return rows

    def _local_snapshot(self) -> Dict[str, Any]:
        """Every service-local stat, read in ONE critical section so
        the numbers are mutually consistent (a concurrent submit can
        never show e.g. ``submitted`` ahead of the queue it joined)."""
        with self._cond:
            out: Dict[str, Any] = dict(self.counters)
            out["inflight"] = len(self._inflight)
            out["queued"] = sum(len(dq) for dq in self._queues.values())
            out["threads"] = len(self._threads)
            out["result_cache_entries"] = len(self._result_cache)
            out["outstanding"] = {t: n for t, n in
                                  self._outstanding.items() if n}
            return out

    def stats(self) -> Dict[str, Any]:
        """Consistent snapshot of counters plus live queue/pool state.

        Service-local fields come from a single locked snapshot
        (:meth:`_local_snapshot` — also the telemetry registry's
        ``service`` collector, so the two views share one source).
        Store-side ``replication``/``robustness`` blocks are collected
        afterwards, outside the service lock: they take the store's own
        locks and must not nest inside ours."""
        out = self._local_snapshot()
        rep = getattr(self.store, "replication_stats", None)
        if callable(rep):
            r = rep()
            if r:
                out["replication"] = r
        rob = getattr(self.store, "robustness_stats", None)
        if callable(rob):
            r = rob()
            if r:
                out["robustness"] = r
        return out

    def _telemetry_samples(self) -> Dict[str, float]:
        """Registry collector: the numeric slice of
        :meth:`_local_snapshot` under ``service.*`` names."""
        snap = self._local_snapshot()
        out = {"service." + k: float(v) for k, v in snap.items()
               if isinstance(v, (int, float))}
        out["service.outstanding_tenants"] = float(
            len(snap.get("outstanding") or ()))
        return out

    # ------------------------------------------------------------- scheduler --
    def _next_flight(self) -> Optional[_Flight]:
        """Pick under the lock: interactive first, batch only while
        under ``batch_slots``."""
        if self._queues[INTERACTIVE]:
            return self._queues[INTERACTIVE].popleft()
        if self._queues[BATCH] and self._active_batch < self.batch_slots:
            return self._queues[BATCH].popleft()
        return None

    def _worker_main(self) -> None:
        while True:
            with self._cond:
                fl = self._next_flight()
                while fl is None:
                    if self._closed:
                        return
                    self._idle += 1
                    try:
                        self._cond.wait()
                    finally:
                        self._idle -= 1
                    fl = self._next_flight()
                self._active += 1
                if fl.priority == BATCH:
                    self._active_batch += 1
                # backlog shrank: wake any submitter delayed on it
                self._cond.notify_all()

            error: Optional[BaseException] = None
            rows: Optional[List[Row]] = None
            stats: Optional[Dict] = None
            v0 = self._store_version()
            exe = (fl.span if fl.span is not None
                   else self.telemetry.tracer.current()).child("execute")
            try:
                # activate the execute span so the store's own query
                # span (see ShardedAggregator.query_with_stats) parents
                # under it — one stitched trace per request
                with exe, self.telemetry.tracer.activate(exe):
                    rows, stats = _direct_query_with_stats(
                        self.store, fl.q, engine=fl.engine,
                        tolerance=fl.tolerance)
            except BaseException as exc:  # fan the error out to waiters
                error = exc
            v1 = self._store_version()

            with self._cond:
                self.counters["executed"] += 1
                if (error is None and self.result_cache_size
                        and v0 is not None and v0 == v1):
                    # stable version across the run: safe to share
                    self._result_cache[(fl.key, v0)] = (rows, stats)
                    self._result_cache.move_to_end((fl.key, v0))
                    while len(self._result_cache) > self.result_cache_size:
                        self._result_cache.popitem(last=False)
                fl.rows, fl.stats, fl.error = rows, stats, error
                # unpublish before waking waiters so a submitter that
                # races the completion either joins this flight (and is
                # woken now) or starts a fresh one — never attaches to
                # a completed-and-forgotten flight
                if self._inflight.get(fl.key) is fl:
                    del self._inflight[fl.key]
                for t in fl.tickets:
                    n = self._outstanding.get(t.tenant, 0) - 1
                    if n > 0:
                        self._outstanding[t.tenant] = n
                    else:
                        self._outstanding.pop(t.tenant, None)
                fl.done.set()
                self._active -= 1
                if fl.priority == BATCH:
                    self._active_batch -= 1
                self._cond.notify_all()
            if fl.span is not None:
                fl.span.set(waiters=len(fl.tickets))
                fl.span.finish("error" if error is not None else None)

    # --------------------------------------------------------------- closing --
    def close(self, timeout: float = 5.0) -> None:
        """Drain queued flights, then stop the workers.

        New submissions are refused immediately; flights already
        admitted still complete so no ticket-holder hangs.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        deadline = timeout
        for th in self._threads:
            th.join(timeout=deadline)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
