"""Automatic analytics — the paper's §4.6 ("under development"), built out.

Detectors encode the paper's specialized views (§4.4) and case studies
(§5) as executable rules:

* :class:`HangDetector`        — "hanging jobs": progress stalls, GFLOP/s≈0
                                  (paper §5, the livelock/deadlock case)
* :class:`IdleAcceleratorDetector` — reserved accelerators never used
                                  (paper: GPU nodes without GPU usage)
* :class:`MemoryUnderuseDetector` — large-memory allocation, tiny footprint
* :class:`LowParticipationDetector` — fewer than half the allocated hosts
                                  ever report work (paper: "<half the cores")
* :class:`LowMfuDetector`      — running but far from the roofline
* :class:`StragglerDetector`   — (beyond paper) slow-host step-time outlier;
                                  events feed the elastic supervisor

Batch ``scan`` methods run vectorized over the columnar store's scan API
(one NumPy pass per detector instead of per-record Python loops); the
hang detector additionally supports streaming ``feed`` for ingest-time
alerting.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.aggregator import MetricStore
from repro.core.columnar import ColumnScan
from repro.core.daemon import JobManifest
from repro.core.schema import MetricRecord


@dataclass
class DetectorEvent:
    ts: float
    job: str
    detector: str
    severity: str  # info | warning | critical
    message: str
    fields: Dict[str, object] = field(default_factory=dict)

    def as_record(self) -> MetricRecord:
        f = {"detector": self.detector, "severity": self.severity,
             "message": self.message}
        f.update({k: v for k, v in self.fields.items()})
        return MetricRecord(ts=self.ts, host="aggregator", job=self.job,
                            kind="event", fields=f)


Manifests = Dict[str, JobManifest]


def _jobs_sorted(sc: ColumnScan) -> Iterator[Tuple[str, np.ndarray]]:
    """Yield (job, row-index array in store order), jobs sorted by name."""
    if sc.n == 0:
        return
    order = np.argsort(sc.job_codes, kind="stable")
    codes_sorted = sc.job_codes[order]
    bounds = np.searchsorted(codes_sorted, np.arange(len(sc.job_vocab) + 1))
    for code in sorted(range(len(sc.job_vocab)),
                       key=lambda c: sc.job_vocab[c]):
        idx = order[bounds[code]:bounds[code + 1]]
        if idx.size:
            yield str(sc.job_vocab[code]), idx


class Detector:
    name = "base"

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        raise NotImplementedError


class HangDetector(Detector):
    """Job "runs" but makes no forward progress for >= patience samples."""

    name = "hang"

    def __init__(self, patience: int = 3, min_gflops: float = 1e-3) -> None:
        self.patience = patience
        self.min_gflops = min_gflops
        self._streak: Dict[str, int] = defaultdict(int)
        self._fired: set = set()

    def _is_stalled(self, rec: MetricRecord) -> bool:
        return (float(rec.get("steps_per_s", 0.0) or 0.0) <= 0.0
                and float(rec.get("gflops", 0.0) or 0.0) < self.min_gflops)

    def feed(self, rec: MetricRecord) -> List[DetectorEvent]:
        """Streaming evaluation at ingest time.  Fires once per
        (job, host) episode — on multi-host jobs every stalled host is
        reported (the statistical job view shows whether it is global)."""
        if rec.kind != "perf":
            return []
        key = f"{rec.job}/{rec.host}"
        if self._is_stalled(rec):
            self._streak[key] += 1
            if self._streak[key] == self.patience and key not in self._fired:
                self._fired.add(key)
                return [DetectorEvent(
                    ts=rec.ts, job=rec.job, detector=self.name,
                    severity="critical",
                    message=(f"no forward progress on {rec.host} for "
                             f"{self.patience} consecutive samples "
                             f"(steps_per_s=0, GFLOP/s<{self.min_gflops})"),
                    fields={"host": rec.host, "streak": self.patience,
                            "step": rec.get("step", -1)})]
        else:
            self._streak[key] = 0
            self._fired.discard(key)
        return []

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        """Vectorized stall-run detection: one pass over the perf scan.

        A "run" of consecutive stalled samples per (job, host) fires one
        event the moment it reaches ``patience`` — identical to feeding
        every record through a fresh streaming detector.
        """
        sc = store.scan(kind="perf", fields=("steps_per_s", "gflops",
                                             "step"))
        if sc.n == 0:
            return []
        sps, sps_p = sc.field("steps_per_s")
        g, g_p = sc.field("gflops")
        step, step_p = sc.field("step")
        with np.errstate(invalid="ignore"):
            stalled = (np.where(sps_p, sps, 0.0) <= 0.0) \
                & (np.where(g_p, g, 0.0) < self.min_gflops)
        key = sc.job_codes.astype(np.int64) * max(len(sc.host_vocab), 1) \
            + sc.host_codes
        order = np.argsort(key, kind="stable")
        k_o = key[order]
        s_o = stalled[order]
        n = sc.n
        pos = np.arange(n)
        boundary = np.empty(n, bool)
        boundary[0] = True
        boundary[1:] = k_o[1:] != k_o[:-1]
        # last reset = previous non-stalled sample or the slot before the
        # (job, host) group starts; streak = distance from it
        anchor_seed = np.where(~s_o, pos,
                               np.where(boundary, pos - 1, -(n + 1)))
        anchor = np.maximum.accumulate(anchor_seed)
        fire = s_o & ((pos - anchor) == self.patience)
        events: List[DetectorEvent] = []
        for orig in sorted(int(i) for i in order[fire]):
            host = str(sc.host_vocab[sc.host_codes[orig]])
            step_val = int(step[orig]) if step_p[orig] and not np.isnan(
                step[orig]) else -1
            events.append(DetectorEvent(
                ts=float(sc.ts[orig]),
                job=str(sc.job_vocab[sc.job_codes[orig]]),
                detector=self.name, severity="critical",
                message=(f"no forward progress on {host} for "
                         f"{self.patience} consecutive samples "
                         f"(steps_per_s=0, GFLOP/s<{self.min_gflops})"),
                fields={"host": host, "streak": self.patience,
                        "step": step_val}))
        return events


class IdleAcceleratorDetector(Detector):
    """Accelerators allocated but (nearly) never used."""

    name = "idle_accelerator"

    def __init__(self, max_frac: float = 0.05, min_samples: int = 2) -> None:
        self.max_frac = max_frac
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        sc = store.scan(kind="device", fields=("hbm_frac_used",))
        v, p = sc.field("hbm_frac_used")
        valid = p & ~np.isnan(v)
        events = []
        for job, idx in _jobs_sorted(sc):
            vi = idx[valid[idx]]
            if vi.size < self.min_samples:
                continue
            peak = float(v[vi].max())
            if peak < self.max_frac:
                events.append(DetectorEvent(
                    ts=float(sc.ts[vi[-1]]), job=job, detector=self.name,
                    severity="warning",
                    message=(f"accelerators allocated but peak HBM use is "
                             f"{peak:.1%} (<{self.max_frac:.0%})"),
                    fields={"peak_hbm_frac": peak,
                            "samples": int(vi.size)}))
        return events


class MemoryUnderuseDetector(Detector):
    """Large-memory allocation whose footprint never grows."""

    name = "memory_underuse"

    def __init__(self, max_frac: float = 0.25) -> None:
        self.max_frac = max_frac

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        manifests = manifests or {}
        sc = store.scan(kind="device", fields=("hbm_frac_used",))
        v, p = sc.field("hbm_frac_used")
        valid = p & ~np.isnan(v)
        events = []
        for job, idx in _jobs_sorted(sc):
            man = manifests.get(job)
            if man is None or man.extra.get("large_memory") not in ("1", 1,
                                                                   True):
                continue
            vi = idx[valid[idx]]
            if vi.size == 0:
                continue
            peak = float(v[vi].max())
            if peak < self.max_frac:
                events.append(DetectorEvent(
                    ts=float(sc.ts[vi[-1]]), job=job, detector=self.name,
                    severity="warning",
                    message=(f"large-memory allocation but peak memory use "
                             f"is {peak:.1%} (<{self.max_frac:.0%})"),
                    fields={"peak_frac": peak}))
        return events


class LowParticipationDetector(Detector):
    """Fewer than half of the allocated hosts ever report perf samples."""

    name = "low_participation"

    def __init__(self, min_frac: float = 0.5) -> None:
        self.min_frac = min_frac

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        manifests = manifests or {}
        sc = store.scan(kind="perf", fields=("gflops",))
        g, g_p = sc.field("gflops")
        with np.errstate(invalid="ignore"):
            working = np.where(g_p, g, 0.0) > 0
        sc_all = store.scan()
        last_ts = {job: float(sc_all.ts[idx].max())
                   for job, idx in _jobs_sorted(sc_all)}
        events = []
        for job, idx in _jobs_sorted(sc):
            man = manifests.get(job)
            if man is None or man.num_hosts <= 1:
                continue
            active = int(np.unique(sc.host_codes[idx[working[idx]]]).size)
            frac = active / man.num_hosts
            if active and frac < self.min_frac:
                events.append(DetectorEvent(
                    ts=last_ts.get(job, 0.0), job=job, detector=self.name,
                    severity="warning",
                    message=(f"only {active}/{man.num_hosts} allocated "
                             f"hosts report useful work"),
                    fields={"active_hosts": active,
                            "allocated_hosts": man.num_hosts}))
        return events


class LowMfuDetector(Detector):
    """Job runs but far below roofline — the support-staff outreach case."""

    name = "low_mfu"

    def __init__(self, min_mfu: float = 0.10, min_samples: int = 3) -> None:
        self.min_mfu = min_mfu
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        sc = store.scan(kind="perf", fields=("mfu", "gflops"))
        mfu, mfu_p = sc.field("mfu")
        g, g_p = sc.field("gflops")
        with np.errstate(invalid="ignore"):
            valid = mfu_p & (np.where(g_p, g, 0.0) > 0)
        events = []
        for job, idx in _jobs_sorted(sc):
            vi = idx[valid[idx]]
            if vi.size < self.min_samples:
                continue
            avg = float(mfu[vi].mean())
            if avg < self.min_mfu:
                events.append(DetectorEvent(
                    ts=float(sc.ts[vi[-1]]), job=job, detector=self.name,
                    severity="info",
                    message=(f"average MFU {avg:.1%} < {self.min_mfu:.0%}"
                             " — candidate for application support"),
                    fields={"avg_mfu": avg, "samples": int(vi.size)}))
        return events


class StragglerDetector(Detector):
    """(Beyond paper) per-host step-time outliers on multi-host jobs."""

    name = "straggler"

    def __init__(self, ratio: float = 1.5, min_samples: int = 3) -> None:
        self.ratio = ratio
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        sc = store.scan(kind="perf", fields=("step_time_s",))
        v, p = sc.field("step_time_s")
        with np.errstate(invalid="ignore"):
            valid = p & (v > 0)
        events = []
        for job, idx in _jobs_sorted(sc):
            vi = idx[valid[idx]]
            hosts = sc.host_codes[vi]
            if np.unique(hosts).size < 2:
                continue
            order = np.argsort(hosts, kind="stable")
            hs = hosts[order]
            vs = v[vi][order]
            cuts = np.nonzero(hs[1:] != hs[:-1])[0] + 1
            medians: Dict[str, float] = {}
            for chunk, hc in zip(np.split(vs, cuts), np.split(hs, cuts)):
                if chunk.size >= self.min_samples:
                    medians[str(sc.host_vocab[hc[0]])] = float(
                        np.quantile(chunk, 0.5))
            if len(medians) < 2:
                continue
            overall = float(np.quantile(np.array(list(medians.values())),
                                        0.5))
            ts = float(sc.ts[vi[-1]])
            for host, med in sorted(medians.items()):
                if med > self.ratio * overall:
                    events.append(DetectorEvent(
                        ts=ts, job=job, detector=self.name,
                        severity="warning",
                        message=(f"host {host} median step time {med:.3f}s is "
                                 f"{med / overall:.2f}x the job median "
                                 f"{overall:.3f}s — straggler"),
                        fields={"host": host, "host_median_s": med,
                                "job_median_s": overall}))
        return events


class BreakerOpenDetector(Detector):
    """Fleet self-monitoring (docs/observability.md): a circuit breaker
    stuck open in the latest ``kind=fleet`` snapshot means a worker is
    being fast-failed right now — the monitor catches its own outage."""

    name = "breaker_open"

    def __init__(self, min_open: int = 1) -> None:
        self.min_open = min_open

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        sc = store.scan(kind="fleet", fields=("breaker.open",
                                              "breaker.opens"))
        if sc.n == 0:
            return []
        v, p = sc.field("breaker.open")
        opens, opens_p = sc.field("breaker.opens")
        events = []
        for job, idx in _jobs_sorted(sc):
            vi = idx[p[idx] & ~np.isnan(v[idx])]
            if vi.size == 0:
                continue
            last = vi[np.argmax(sc.ts[vi])]
            n_open = int(v[last])
            if n_open >= self.min_open:
                total_opens = (int(opens[last])
                               if opens_p[last] and not np.isnan(opens[last])
                               else -1)
                events.append(DetectorEvent(
                    ts=float(sc.ts[last]), job=job, detector=self.name,
                    severity="critical",
                    message=(f"{n_open} circuit breaker(s) open — worker(s) "
                             f"fast-failing ({total_opens} opens so far)"),
                    fields={"open": n_open, "opens": total_opens}))
        return events


class QuarantineGrowthDetector(Detector):
    """Fleet self-monitoring: quarantined-segment count growing across
    ``kind=fleet`` snapshots means payloads keep failing checksums at
    read time (docs/faults.md) — silent data loss in progress."""

    name = "quarantine_growth"

    def __init__(self, min_growth: int = 1) -> None:
        self.min_growth = min_growth

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        sc = store.scan(kind="fleet",
                        fields=("storage.quarantined_segments",))
        if sc.n == 0:
            return []
        v, p = sc.field("storage.quarantined_segments")
        events = []
        for job, idx in _jobs_sorted(sc):
            vi = idx[p[idx] & ~np.isnan(v[idx])]
            if vi.size < 2:
                continue
            order = vi[np.argsort(sc.ts[vi], kind="stable")]
            first, last = int(v[order[0]]), int(v[order[-1]])
            growth = last - first
            if growth >= self.min_growth:
                events.append(DetectorEvent(
                    ts=float(sc.ts[order[-1]]), job=job, detector=self.name,
                    severity="warning",
                    message=(f"quarantined segments grew {first} -> {last} "
                             f"over the snapshot window — payload corruption "
                             f"is ongoing"),
                    fields={"first": first, "last": last,
                            "growth": growth}))
        return events


DEFAULT_DETECTORS = (HangDetector, IdleAcceleratorDetector,
                     MemoryUnderuseDetector, LowParticipationDetector,
                     LowMfuDetector, StragglerDetector)

# Fleet self-monitoring detectors run over the dedicated ``_telemetry``
# store (kind=fleet snapshots pumped by ``telemetry.SelfMonitor``), not
# the job-metric store — kept out of DEFAULT_DETECTORS so job-facing
# banks stay unchanged.  See docs/observability.md.
TELEMETRY_DETECTORS = (BreakerOpenDetector, QuarantineGrowthDetector)


class DetectorBank:
    """All detectors together; batch scan plus streaming hang alerts."""

    def __init__(self, detectors: Optional[List[Detector]] = None) -> None:
        self.detectors = detectors or [cls() for cls in DEFAULT_DETECTORS]
        self._stream_hang = HangDetector()
        self.events: List[DetectorEvent] = []

    def feed(self, rec: MetricRecord) -> List[DetectorEvent]:
        evs = self._stream_hang.feed(rec)
        self.events.extend(evs)
        return evs

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        out: List[DetectorEvent] = []
        for det in self.detectors:
            out.extend(det.scan(store, manifests))
        out.sort(key=lambda e: e.ts)
        return out

    @staticmethod
    def write_back(store: MetricStore, events: List[DetectorEvent]) -> None:
        """Persist events as kind=event records so they are queryable."""
        for ev in events:
            store.insert(ev.as_record())
