"""Automatic analytics — the paper's §4.6 ("under development"), built out.

Detectors encode the paper's specialized views (§4.4) and case studies
(§5) as executable rules:

* :class:`HangDetector`        — "hanging jobs": progress stalls, GFLOP/s≈0
                                  (paper §5, the livelock/deadlock case)
* :class:`IdleAcceleratorDetector` — reserved accelerators never used
                                  (paper: GPU nodes without GPU usage)
* :class:`MemoryUnderuseDetector` — large-memory allocation, tiny footprint
* :class:`LowParticipationDetector` — fewer than half the allocated hosts
                                  ever report work (paper: "<half the cores")
* :class:`LowMfuDetector`      — running but far from the roofline
* :class:`StragglerDetector`   — (beyond paper) slow-host step-time outlier;
                                  events feed the elastic supervisor

All detectors are pure functions of the store (batch ``scan``); the hang
detector additionally supports streaming ``feed`` for ingest-time alerting.
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.aggregator import MetricStore
from repro.core.daemon import JobManifest
from repro.core.schema import MetricRecord
from repro.core.sketches import exact_quantile


@dataclass
class DetectorEvent:
    ts: float
    job: str
    detector: str
    severity: str  # info | warning | critical
    message: str
    fields: Dict[str, object] = field(default_factory=dict)

    def as_record(self) -> MetricRecord:
        f = {"detector": self.detector, "severity": self.severity,
             "message": self.message}
        f.update({k: v for k, v in self.fields.items()})
        return MetricRecord(ts=self.ts, host="aggregator", job=self.job,
                            kind="event", fields=f)


Manifests = Dict[str, JobManifest]


class Detector:
    name = "base"

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        raise NotImplementedError


class HangDetector(Detector):
    """Job "runs" but makes no forward progress for >= patience samples."""

    name = "hang"

    def __init__(self, patience: int = 3, min_gflops: float = 1e-3) -> None:
        self.patience = patience
        self.min_gflops = min_gflops
        self._streak: Dict[str, int] = defaultdict(int)
        self._fired: set = set()

    def _is_stalled(self, rec: MetricRecord) -> bool:
        return (float(rec.get("steps_per_s", 0.0) or 0.0) <= 0.0
                and float(rec.get("gflops", 0.0) or 0.0) < self.min_gflops)

    def feed(self, rec: MetricRecord) -> List[DetectorEvent]:
        """Streaming evaluation at ingest time.  Fires once per
        (job, host) episode — on multi-host jobs every stalled host is
        reported (the statistical job view shows whether it is global)."""
        if rec.kind != "perf":
            return []
        key = f"{rec.job}/{rec.host}"
        if self._is_stalled(rec):
            self._streak[key] += 1
            if self._streak[key] == self.patience and key not in self._fired:
                self._fired.add(key)
                return [DetectorEvent(
                    ts=rec.ts, job=rec.job, detector=self.name,
                    severity="critical",
                    message=(f"no forward progress on {rec.host} for "
                             f"{self.patience} consecutive samples "
                             f"(steps_per_s=0, GFLOP/s<{self.min_gflops})"),
                    fields={"host": rec.host, "streak": self.patience,
                            "step": rec.get("step", -1)})]
        else:
            self._streak[key] = 0
            self._fired.discard(key)
        return []

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        fresh = HangDetector(self.patience, self.min_gflops)
        events: List[DetectorEvent] = []
        for rec in store.select(kind="perf"):
            events.extend(fresh.feed(rec))
        return events


class IdleAcceleratorDetector(Detector):
    """Accelerators allocated but (nearly) never used."""

    name = "idle_accelerator"

    def __init__(self, max_frac: float = 0.05, min_samples: int = 2) -> None:
        self.max_frac = max_frac
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        events = []
        for job in store.jobs():
            fracs, ts = [], 0.0
            for rec in store.select(job=job, kind="device"):
                v = rec.get("hbm_frac_used")
                if isinstance(v, (int, float)):
                    fracs.append(float(v))
                    ts = rec.ts
            if len(fracs) >= self.min_samples and max(fracs) < self.max_frac:
                events.append(DetectorEvent(
                    ts=ts, job=job, detector=self.name, severity="warning",
                    message=(f"accelerators allocated but peak HBM use is "
                             f"{max(fracs):.1%} (<{self.max_frac:.0%})"),
                    fields={"peak_hbm_frac": max(fracs),
                            "samples": len(fracs)}))
        return events


class MemoryUnderuseDetector(Detector):
    """Large-memory allocation whose footprint never grows."""

    name = "memory_underuse"

    def __init__(self, max_frac: float = 0.25) -> None:
        self.max_frac = max_frac

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        manifests = manifests or {}
        events = []
        for job in store.jobs():
            man = manifests.get(job)
            if man is None or man.extra.get("large_memory") not in ("1", 1, True):
                continue
            fracs, ts = [], 0.0
            for rec in store.select(job=job, kind="device"):
                v = rec.get("hbm_frac_used")
                if isinstance(v, (int, float)):
                    fracs.append(float(v))
                    ts = rec.ts
            if fracs and max(fracs) < self.max_frac:
                events.append(DetectorEvent(
                    ts=ts, job=job, detector=self.name, severity="warning",
                    message=(f"large-memory allocation but peak memory use "
                             f"is {max(fracs):.1%} (<{self.max_frac:.0%})"),
                    fields={"peak_frac": max(fracs)}))
        return events


class LowParticipationDetector(Detector):
    """Fewer than half of the allocated hosts ever report perf samples."""

    name = "low_participation"

    def __init__(self, min_frac: float = 0.5) -> None:
        self.min_frac = min_frac

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        manifests = manifests or {}
        events = []
        for job in store.jobs():
            man = manifests.get(job)
            if man is None or man.num_hosts <= 1:
                continue
            hosts = {r.host for r in store.select(job=job, kind="perf")
                     if float(r.get("gflops", 0.0) or 0.0) > 0}
            ts = max((r.ts for r in store.select(job=job)), default=0.0)
            frac = len(hosts) / man.num_hosts
            if hosts and frac < self.min_frac:
                events.append(DetectorEvent(
                    ts=ts, job=job, detector=self.name, severity="warning",
                    message=(f"only {len(hosts)}/{man.num_hosts} allocated "
                             f"hosts report useful work"),
                    fields={"active_hosts": len(hosts),
                            "allocated_hosts": man.num_hosts}))
        return events


class LowMfuDetector(Detector):
    """Job runs but far below roofline — the support-staff outreach case."""

    name = "low_mfu"

    def __init__(self, min_mfu: float = 0.10, min_samples: int = 3) -> None:
        self.min_mfu = min_mfu
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        events = []
        for job in store.jobs():
            mfus, ts = [], 0.0
            for rec in store.select(job=job, kind="perf"):
                v = rec.get("mfu")
                g = rec.get("gflops", 0.0)
                if isinstance(v, (int, float)) and float(g or 0.0) > 0:
                    mfus.append(float(v))
                    ts = rec.ts
            if len(mfus) >= self.min_samples:
                avg = sum(mfus) / len(mfus)
                if avg < self.min_mfu:
                    events.append(DetectorEvent(
                        ts=ts, job=job, detector=self.name, severity="info",
                        message=(f"average MFU {avg:.1%} < {self.min_mfu:.0%}"
                                 " — candidate for application support"),
                        fields={"avg_mfu": avg, "samples": len(mfus)}))
        return events


class StragglerDetector(Detector):
    """(Beyond paper) per-host step-time outliers on multi-host jobs."""

    name = "straggler"

    def __init__(self, ratio: float = 1.5, min_samples: int = 3) -> None:
        self.ratio = ratio
        self.min_samples = min_samples

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        events = []
        for job in store.jobs():
            per_host: Dict[str, List[float]] = defaultdict(list)
            ts = 0.0
            for rec in store.select(job=job, kind="perf"):
                v = rec.get("step_time_s")
                if isinstance(v, (int, float)) and float(v) > 0:
                    per_host[rec.host].append(float(v))
                    ts = rec.ts
            if len(per_host) < 2:
                continue
            medians = {h: exact_quantile(v, 0.5) for h, v in per_host.items()
                       if len(v) >= self.min_samples}
            if len(medians) < 2:
                continue
            overall = exact_quantile(list(medians.values()), 0.5)
            for host, med in sorted(medians.items()):
                if med > self.ratio * overall:
                    events.append(DetectorEvent(
                        ts=ts, job=job, detector=self.name,
                        severity="warning",
                        message=(f"host {host} median step time {med:.3f}s is "
                                 f"{med / overall:.2f}x the job median "
                                 f"{overall:.3f}s — straggler"),
                        fields={"host": host, "host_median_s": med,
                                "job_median_s": overall}))
        return events


DEFAULT_DETECTORS = (HangDetector, IdleAcceleratorDetector,
                     MemoryUnderuseDetector, LowParticipationDetector,
                     LowMfuDetector, StragglerDetector)


class DetectorBank:
    """All detectors together; batch scan plus streaming hang alerts."""

    def __init__(self, detectors: Optional[List[Detector]] = None) -> None:
        self.detectors = detectors or [cls() for cls in DEFAULT_DETECTORS]
        self._stream_hang = HangDetector()
        self.events: List[DetectorEvent] = []

    def feed(self, rec: MetricRecord) -> List[DetectorEvent]:
        evs = self._stream_hang.feed(rec)
        self.events.extend(evs)
        return evs

    def scan(self, store: MetricStore,
             manifests: Optional[Manifests] = None) -> List[DetectorEvent]:
        out: List[DetectorEvent] = []
        for det in self.detectors:
            out.extend(det.scan(store, manifests))
        out.sort(key=lambda e: e.ts)
        return out

    @staticmethod
    def write_back(store: MetricStore, events: List[DetectorEvent]) -> None:
        """Persist events as kind=event records so they are queryable."""
        for ev in events:
            store.insert(ev.as_record())
