"""Post-SPMD HLO text analysis: collective traffic extraction.

XLA's ``cost_analysis()`` does not report collective bytes, so (per the
task spec) we parse the compiled module text and sum the operand sizes of
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all``
/ ``collective-permute`` op.  This is the "network counter" data source of
the monitoring system: the per-step ICI traffic is a static property of the
compiled executable, exactly like the FLOP count.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1, "f8e5m2": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# dtype[d0,d1,...] possibly followed by layout {..}
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# op line:  %name = <type> <opcode>(...), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")


def shape_bytes(dtype: str, dims: str) -> float:
    width = _DTYPE_BYTES.get(dtype)
    if width is None:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * width


def _sum_shapes(text: str) -> float:
    return sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(text))


def _balanced_paren_span(line: str, start: int) -> Tuple[int, int]:
    """Return (open_idx, close_idx) of the operand list starting at
    ``start`` (index of the opening paren)."""
    depth = 0
    for i in range(start, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return start, i
    return start, len(line) - 1


@dataclass
class CollectiveStats:
    count: int = 0
    operand_bytes: float = 0.0
    result_bytes: float = 0.0


@dataclass
class CollectiveSummary:
    per_kind: Dict[str, CollectiveStats] = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> float:
        return sum(s.operand_bytes for s in self.per_kind.values())

    @property
    def total_result_bytes(self) -> float:
        return sum(s.result_bytes for s in self.per_kind.values())

    @property
    def total_count(self) -> int:
        return sum(s.count for s in self.per_kind.values())

    def as_fields(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "coll_bytes": self.total_operand_bytes,
            "coll_result_bytes": self.total_result_bytes,
            "coll_count": float(self.total_count),
        }
        for kind, s in sorted(self.per_kind.items()):
            key = kind.replace("-", "_")
            out[f"coll_{key}_bytes"] = s.operand_bytes
            out[f"coll_{key}_count"] = float(s.count)
        return out


def _normalize_opcode(opcode: str) -> str:
    for suffix in ("-start", "-done"):
        if opcode.endswith(suffix):
            return opcode[: -len(suffix)]
    return opcode


def collective_summary(hlo_text: str) -> CollectiveSummary:
    """Scan compiled (post-partitioning) HLO text for collective ops.

    Operand types appear inline in HLO long form
    (``all-reduce(f32[8,128]{1,0} %add.3)``), so operand bytes are read
    directly off the op line.  ``*-done`` ops are skipped to avoid double
    counting async pairs.
    """
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_type, opcode = m.group(1), m.group(2)
        if opcode.endswith("-done"):
            continue
        kind = _normalize_opcode(opcode)
        if kind not in COLLECTIVE_KINDS:
            continue
        open_idx = line.find("(", m.end() - 1)
        _, close_idx = _balanced_paren_span(line, open_idx)
        operand_text = line[open_idx + 1: close_idx]
        st = summary.per_kind.setdefault(kind, CollectiveStats())
        st.count += 1
        rb = _sum_shapes(result_type)
        ob = _sum_shapes(operand_text)
        # short-form HLO omits operand types; result size is the correct
        # operand size for all-reduce/permute and an upper bound otherwise
        st.operand_bytes += ob if ob else rb
        st.result_bytes += rb
    return summary


def collective_bytes(hlo_text: str) -> float:
    """Total operand bytes across all collective ops (task-spec metric)."""
    return collective_summary(hlo_text).total_operand_bytes


# ----------------------------------------------------------- cost extraction

def cost_figures(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` into {flops, bytes}.

    XLA:CPU/TPU report per-partition figures on the partitioned module.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returned [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": max(flops, 0.0), "bytes": max(byts, 0.0)}


def memory_figures(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    out["total_bytes_per_device"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out.get("alias_size_in_bytes", 0.0))
    return out
