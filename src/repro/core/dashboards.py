"""Dashboards — the Splunk-dashboard analog (paper §4.4), rendered to SVG.

Three views, exactly as in the paper:

* **Roofline view** (Fig. 2): every finished job in a time window as a
  circle on log-log (arithmetic intensity, GFLOP/s-per-chip) axes, sized
  by device-hours, under the machine roofline.
* **Detailed job view** (Fig. 3): temporal plots per metric per host,
  plus a min/median/max statistical aggregation for large jobs.
* **Specialized views**: top apps by device-hours; accelerators reserved
  but idle; large-memory underuse; low host participation — implemented
  as splunklite queries (staff "custom queries" in the paper).

Every view takes a single :class:`MetricStore` *or* a sharded store
(:class:`~repro.core.shards.ShardedAggregator`, including its
worker-process subclass
:class:`~repro.core.remote.RemoteShardedAggregator`) — ``query``
dispatches fleet queries through the scatter/gather planner and
``scan`` merges per-shard column scans, so dashboards render
identically either way: in-process, sharded, or against a remote
worker fleet (the shard- and remote-parity suites assert it).

For the paper's continuous dashboards, :class:`StreamingView` (and
:func:`streaming_specialized_views`) wrap the query-backed views in
:class:`~repro.core.splunklite.QueryHandle` refresh loops: re-rendering
after each aggregator pump recomputes only the unsealed append buffer —
sealed segments come from the segment-keyed partial-aggregate cache
(docs/incremental.md).

Rendering is dependency-free SVG string building.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.aggregator import MetricStore
from repro.core.daemon import JobManifest
from repro.core.derived import HardwareSpec, TPU_V5E
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import QueryHandle, query

# RemoteShardedAggregator subclasses ShardedAggregator, so the union
# covers the worker-process fleet too
StoreLike = Union[MetricStore, ShardedAggregator]

# ------------------------------------------------------------ svg helpers ---

_SVG_HEADER = ('<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
               'height="{h}" viewBox="0 0 {w} {h}" '
               'font-family="Helvetica,Arial,sans-serif">')


def _esc(s: str) -> str:
    return (str(s).replace("&", "&amp;").replace("<", "&lt;")
            .replace(">", "&gt;").replace('"', "&quot;"))


class SvgCanvas:
    def __init__(self, w: int, h: int) -> None:
        self.w, self.h = w, h
        self.parts: List[str] = [_SVG_HEADER.format(w=w, h=h),
                                 f'<rect width="{w}" height="{h}" fill="white"/>']

    def line(self, x1, y1, x2, y2, stroke="#444", width=1.0, dash=""):
        d = f' stroke-dasharray="{dash}"' if dash else ""
        self.parts.append(
            f'<line x1="{x1:.1f}" y1="{y1:.1f}" x2="{x2:.1f}" y2="{y2:.1f}" '
            f'stroke="{stroke}" stroke-width="{width}"{d}/>')

    def circle(self, cx, cy, r, fill="#1f77b4", opacity=0.6, title=""):
        t = f"<title>{_esc(title)}</title>" if title else ""
        self.parts.append(
            f'<circle cx="{cx:.1f}" cy="{cy:.1f}" r="{r:.1f}" fill="{fill}" '
            f'fill-opacity="{opacity}" stroke="#333" stroke-width="0.5">{t}'
            '</circle>')

    def text(self, x, y, s, size=11, anchor="start", fill="#222", rotate=None):
        rot = (f' transform="rotate({rotate} {x:.1f} {y:.1f})"'
               if rotate is not None else "")
        self.parts.append(
            f'<text x="{x:.1f}" y="{y:.1f}" font-size="{size}" fill="{fill}" '
            f'text-anchor="{anchor}"{rot}>{_esc(s)}</text>')

    def polyline(self, pts: Sequence[Tuple[float, float]], stroke="#1f77b4",
                 width=1.5):
        if len(pts) < 2:
            return
        path = " ".join(f"{x:.1f},{y:.1f}" for x, y in pts)
        self.parts.append(
            f'<polyline points="{path}" fill="none" stroke="{stroke}" '
            f'stroke-width="{width}"/>')

    def polyline_xy(self, xs, ys, stroke="#1f77b4", width=1.5):
        """Vectorized variant: pre-scaled coordinate arrays."""
        if len(xs) < 2:
            return
        path = " ".join(map("%.1f,%.1f".__mod__,
                            zip(xs.tolist(), ys.tolist())))
        self.parts.append(
            f'<polyline points="{path}" fill="none" stroke="{stroke}" '
            f'stroke-width="{width}"/>')

    def render(self) -> str:
        return "\n".join(self.parts + ["</svg>"])


_PALETTE = ["#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd",
            "#8c564b", "#e377c2", "#7f7f7f", "#bcbd22", "#17becf"]


# ------------------------------------------------------------ roofline ------

@dataclass
class JobPoint:
    job: str
    app: str
    ai: float                 # FLOP/byte
    gflops_per_chip: float
    device_hours: float
    mfu: float = 0.0


def roofline_points(store: StoreLike,
                    manifests: Optional[Dict[str, JobManifest]] = None
                    ) -> List[JobPoint]:
    """Condense each job into (AI, GFLOP/s-per-chip, device-hours)."""
    manifests = manifests or {}
    rows = query(store, "search kind=perf gflops>0 "
                        "| stats avg(ai) avg(gflops_per_chip) avg(mfu) "
                        "min(ts) max(ts) by job")
    app_by_job = {r["job"]: str(r.get("app", "?")) for r in query(
        store, "search kind=meta | dedup job | fields job app")}
    points = []
    for r in rows:
        job = r["job"]
        man = manifests.get(job)
        chips = man.num_chips if man else 1
        dur_h = max(float(r["max_ts"]) - float(r["min_ts"]), 0.0) / 3600.0
        points.append(JobPoint(
            job=job,
            app=(man.app if man else app_by_job.get(job, "?")),
            ai=float(r["avg_ai"]),
            gflops_per_chip=float(r["avg_gflops_per_chip"]),
            device_hours=max(dur_h * chips, 1e-6),
            mfu=float(r.get("avg_mfu") or 0.0)))
    return points


def render_roofline_svg(points: Sequence[JobPoint],
                        hw: HardwareSpec = TPU_V5E,
                        width: int = 860, height: int = 560,
                        title: str = "Job roofline overview") -> str:
    """Fig. 2 analog: log-log roofline with one circle per job."""
    c = SvgCanvas(width, height)
    ml, mr, mt, mb = 70, 30, 46, 56
    pw, ph = width - ml - mr, height - mt - mb
    # axis ranges (log10)
    ai_lo, ai_hi = -2.0, 4.0
    peak_g = hw.peak_flops / 1e9
    pf_lo, pf_hi = math.log10(peak_g) - 5.0, math.log10(peak_g) + 0.4

    def X(ai: float) -> float:
        ai = min(max(ai, 10 ** ai_lo), 10 ** ai_hi)
        return ml + (math.log10(ai) - ai_lo) / (ai_hi - ai_lo) * pw

    def Y(gf: float) -> float:
        gf = min(max(gf, 10 ** pf_lo), 10 ** pf_hi)
        return mt + ph - (math.log10(gf) - pf_lo) / (pf_hi - pf_lo) * ph

    c.text(width / 2, 22, title, size=15, anchor="middle")
    # gridlines + ticks
    for e in range(int(ai_lo), int(ai_hi) + 1):
        x = X(10 ** e)
        c.line(x, mt, x, mt + ph, stroke="#eee")
        c.text(x, mt + ph + 16, f"1e{e}", size=10, anchor="middle")
    for e in range(math.ceil(pf_lo), math.floor(pf_hi) + 1):
        y = Y(10 ** e)
        c.line(ml, y, ml + pw, y, stroke="#eee")
        c.text(ml - 6, y + 3, f"1e{e}", size=10, anchor="end")
    c.line(ml, mt + ph, ml + pw, mt + ph)
    c.line(ml, mt, ml, mt + ph)
    c.text(width / 2, height - 14,
           "arithmetic intensity [FLOP/byte]", size=12, anchor="middle")
    c.text(16, mt + ph / 2, "GFLOP/s per chip", size=12, anchor="middle",
           rotate=-90)
    # roofline: bandwidth slope then flat compute roof
    ridge = hw.ridge_ai
    bw_g = hw.hbm_bw / 1e9
    pts = [(X(10 ** ai_lo), Y(bw_g * 10 ** ai_lo)),
           (X(ridge), Y(peak_g)), (X(10 ** ai_hi), Y(peak_g))]
    c.polyline(pts, stroke="#d62728", width=2.0)
    c.text(X(ridge), Y(peak_g) - 8,
           f"{hw.name}: {peak_g / 1e3:.0f} TFLOP/s, "
           f"{bw_g:.0f} GB/s, ridge {ridge:.0f}",
           size=10, anchor="middle", fill="#d62728")
    # jobs
    if points:
        max_h = max(p.device_hours for p in points)
        apps = sorted({p.app for p in points})
        color = {a: _PALETTE[i % len(_PALETTE)] for i, a in enumerate(apps)}
        for p in points:
            r = 4 + 14 * math.sqrt(p.device_hours / max_h)
            c.circle(X(p.ai), Y(max(p.gflops_per_chip, 10 ** pf_lo)), r,
                     fill=color[p.app],
                     title=(f"{p.job} ({p.app}) AI={p.ai:.2f} "
                            f"{p.gflops_per_chip:.1f} GFLOP/s/chip "
                            f"MFU={p.mfu:.1%} {p.device_hours:.2f} dev-h"))
        for i, a in enumerate(apps[:12]):
            c.circle(ml + 10, mt + 12 + 16 * i, 5, fill=color[a])
            c.text(ml + 20, mt + 16 + 16 * i, a, size=10)
    return c.render()


# ------------------------------------------------------- detailed job view --

def render_timeseries_svg(series: Dict[str, List[Tuple[float, float]]],
                          title: str, ylabel: str,
                          width: int = 860, height: int = 300) -> str:
    """Multi-line temporal plot (one line per host/socket), Fig. 3 style."""
    c = SvgCanvas(width, height)
    ml, mr, mt, mb = 64, 120, 34, 40
    pw, ph = width - ml - mr, height - mt - mb
    raw = {name: np.asarray(pts, dtype=np.float64)
           for name, pts in series.items() if pts}
    arrays = {name: a[~np.isnan(a[:, 1])] for name, a in raw.items()}
    c.text(width / 2, 20, title, size=13, anchor="middle")
    if not arrays or not any(a.size for a in arrays.values()):
        c.text(width / 2, height / 2, "(no data)", anchor="middle")
        return c.render()
    x0 = min(float(a[:, 0].min()) for a in raw.values())
    x1 = max(float(a[:, 0].max()) for a in raw.values())
    valid = [a for a in arrays.values() if a.size]
    y0 = min(0.0, min(float(a[:, 1].min()) for a in valid))
    y1 = max(float(a[:, 1].max()) for a in valid)
    if y1 <= y0:
        y1 = y0 + 1.0
    if x1 <= x0:
        x1 = x0 + 1.0
    sx = pw / (x1 - x0)
    sy = ph / (y1 - y0)

    def X(t): return ml + (t - x0) * sx
    def Y(v): return mt + ph - (v - y0) * sy

    for i in range(5):
        yv = y0 + (y1 - y0) * i / 4
        c.line(ml, Y(yv), ml + pw, Y(yv), stroke="#eee")
        c.text(ml - 6, Y(yv) + 3, f"{yv:.3g}", size=9, anchor="end")
    for i in range(5):
        tv = x0 + (x1 - x0) * i / 4
        c.text(X(tv), mt + ph + 14, f"+{tv - x0:.0f}s", size=9,
               anchor="middle")
    c.line(ml, mt + ph, ml + pw, mt + ph)
    c.line(ml, mt, ml, mt + ph)
    c.text(14, mt + ph / 2, ylabel, size=11, anchor="middle", rotate=-90)
    for i, name in enumerate(sorted(series)):
        col = _PALETTE[i % len(_PALETTE)]
        arr = arrays.get(name)
        if arr is not None and arr.size:
            c.polyline_xy(ml + (arr[:, 0] - x0) * sx,
                          mt + ph - (arr[:, 1] - y0) * sy, stroke=col)
        if i < 14:
            c.line(ml + pw + 8, mt + 10 + 14 * i, ml + pw + 24,
                   mt + 10 + 14 * i, stroke=col, width=2)
            c.text(ml + pw + 28, mt + 14 + 14 * i, name[:14], size=9)
    return c.render()


JOB_VIEW_METRICS = ("gflops", "hbm_gbs", "ai", "mfu", "step_time_s",
                    "tokens_per_s", "loss")


def job_metric_series(store: StoreLike, job: str, metric: str,
                      kind: str = "perf"
                      ) -> Dict[str, List[Tuple[float, float]]]:
    """Per-host (ts, value) series straight off the column arrays."""
    sc = store.scan(job=job, kind=kind, fields=(metric,))
    vals, present = sc.field(metric)
    idx = np.nonzero(present)[0]
    series: Dict[str, List[Tuple[float, float]]] = {}
    if idx.size == 0:
        return series
    hc = sc.host_codes[idx]
    ts = sc.ts[idx]
    vs = vals[idx]
    order = np.lexsort((vs, ts, hc))
    hc, ts, vs = hc[order], ts[order], vs[order]
    cuts = np.nonzero(hc[1:] != hc[:-1])[0] + 1
    starts = np.concatenate([[0], cuts])
    stops = np.concatenate([cuts, [len(hc)]])
    for lo, hi in zip(starts, stops):
        host = str(sc.host_vocab[hc[lo]])
        series[host] = list(zip(ts[lo:hi].tolist(), vs[lo:hi].tolist()))
    return series


def job_statistical_view(store: StoreLike, job: str, metric: str,
                         kind: str = "perf", span_s: float = 60.0
                         ) -> Dict[str, List[Tuple[float, float]]]:
    """The paper's second job dashboard: min/median/max curves across all
    hosts per time bucket, computed exactly by a NumPy bucket group-by
    over the columnar store (the streaming ``QuantileSet`` sketch remains
    for relays that cannot hold samples)."""
    sc = store.scan(job=job, kind=kind, fields=(metric,))
    vals, present = sc.field(metric)
    valid = present & ~np.isnan(vals)
    out: Dict[str, List[Tuple[float, float]]] = {
        "min": [], "median": [], "max": []}
    if not valid.any():
        return out
    vs = vals[valid]
    buckets = np.floor(sc.ts[valid] / span_s) * span_s
    order = np.lexsort((vs, buckets))  # value-sorted within each bucket
    buckets, vs = buckets[order], vs[order]
    cuts = np.nonzero(buckets[1:] != buckets[:-1])[0] + 1
    starts = np.concatenate([[0], cuts])
    stops = np.concatenate([cuts, [len(vs)]])
    counts = stops - starts
    mins = vs[starts]
    maxs = vs[stops - 1]
    med_lo = vs[starts + (counts - 1) // 2]
    med_hi = vs[starts + counts // 2]
    medians = 0.5 * (med_lo + med_hi)
    out["min"] = list(zip(buckets[starts].tolist(), mins.tolist()))
    out["median"] = list(zip(buckets[starts].tolist(), medians.tolist()))
    out["max"] = list(zip(buckets[starts].tolist(), maxs.tolist()))
    return out


# ------------------------------------------------------- specialized views --

def view_top_apps_by_device_hours(store: StoreLike,
                                  manifests: Dict[str, JobManifest],
                                  limit: int = 10) -> List[Dict]:
    """Paper: 'most executed applications by core hours'."""
    rows = query(store, "search kind=perf "
                        "| stats min(ts) max(ts) count by job")
    acc: Dict[str, float] = {}
    for r in rows:
        man = manifests.get(r["job"])
        if man is None:
            continue
        dur_h = max(float(r["max_ts"]) - float(r["min_ts"]), 0.0) / 3600.0
        acc[man.app] = acc.get(man.app, 0.0) + dur_h * man.num_chips
    table = [{"app": a, "device_hours": round(h, 4)}
             for a, h in sorted(acc.items(), key=lambda kv: -kv[1])]
    return table[:limit]


_IDLE_ACCEL_Q = ("search kind=device | stats max(hbm_frac_used) count "
                 "by job | where max_hbm_frac_used<{max_frac} "
                 "| sort max_hbm_frac_used")
# same aggregation prefix as the idle view (the threshold lives in the
# idle view's *tail*), so both streaming views share one set of cached
# per-segment partials — the fingerprint excludes tail stages
_MEMORY_PEAK_Q = "search kind=device | stats max(hbm_frac_used) count by job"
_PARTICIPATION_Q = "search kind=perf gflops>0 | stats dc(host) by job"


def view_idle_accelerators(store: StoreLike, max_frac: float = 0.05
                           ) -> List[Dict]:
    """Paper: 'jobs that reserved GPU nodes without using GPUs'."""
    return query(store, _IDLE_ACCEL_Q.format(max_frac=max_frac))


def _memory_underuse_rows(rows: List[Dict],
                          manifests: Dict[str, JobManifest],
                          max_frac: float) -> List[Dict]:
    out = []
    for r in rows:
        man = manifests.get(r["job"])
        if man is None or man.extra.get("large_memory") not in ("1", 1, True):
            continue
        v = r.get("max_hbm_frac_used")
        if isinstance(v, (int, float)) and v < max_frac:
            out.append({"job": r["job"], "peak_frac": v, "app": man.app})
    return out


def view_memory_underuse(store: StoreLike,
                         manifests: Dict[str, JobManifest],
                         max_frac: float = 0.25) -> List[Dict]:
    """Paper: 'jobs that reserved large memory nodes without using much
    memory'."""
    return _memory_underuse_rows(query(store, _MEMORY_PEAK_Q), manifests,
                                 max_frac)


def _low_participation_rows(rows: List[Dict],
                            manifests: Dict[str, JobManifest],
                            min_frac: float) -> List[Dict]:
    out = []
    for r in rows:
        man = manifests.get(r["job"])
        if man is None or man.num_hosts <= 1:
            continue
        active = int(r["dc_host"])
        if active < min_frac * man.num_hosts:
            out.append({"job": r["job"], "active_hosts": active,
                        "allocated_hosts": man.num_hosts, "app": man.app})
    return out


def view_low_participation(store: StoreLike,
                           manifests: Dict[str, JobManifest],
                           min_frac: float = 0.5) -> List[Dict]:
    """Paper: 'jobs that use less than half of the available CPU cores'."""
    return _low_participation_rows(query(store, _PARTICIPATION_Q), manifests,
                                   min_frac)


# ------------------------------------------------------- streaming views ---

class StreamingView:
    """One continuously-refreshed dashboard view (paper §4.4's
    "interactive analysis" loop): a :class:`QueryHandle` plus an
    optional row post-processor and renderer.

    Call :meth:`refresh` after each aggregator pump.  The handle makes
    the refresh incremental — with no new data it returns the previous
    rows untouched, and with new data a mergeable query recomputes only
    the append buffer plus newly sealed segments (the sealed fleet
    comes from the store's segment-keyed partial-aggregate cache; see
    docs/incremental.md).  Post-processing and rendering re-run only
    when the underlying rows actually changed.

    ``service`` routes refreshes through a
    :class:`~repro.core.service.QueryService` (tenant ``"dashboard"``,
    ``shed_ok``): many concurrent views over the same query share one
    execution, and at saturation a refresh returns the previous rows
    instead of joining the backlog — docs/service.md.
    """

    def __init__(self, store: StoreLike, q: str,
                 postprocess: Optional[Callable[[List[Dict]], List[Dict]]]
                 = None,
                 render: Optional[Callable[[List[Dict]], str]] = None,
                 service=None) -> None:
        self.handle = QueryHandle(store, q, service=service,
                                  tenant="dashboard",
                                  shed_ok=service is not None)
        self.postprocess = postprocess
        self.render = render
        self.renders = 0
        self._rows_seen: Optional[List[Dict]] = None
        self._result: List[Dict] = []
        self._rendered: Optional[str] = None

    def refresh(self) -> List[Dict]:
        """Current (post-processed) rows; incremental under the hood.

        ``postprocess`` re-runs on every refresh — it may close over
        mutable state (e.g. a manifests dict that gained a job without
        any new metric records), so only the query itself is memoized
        on the store version; the render invalidates whenever the
        post-processed output actually changed."""
        rows = self.handle.refresh()
        if rows is not self._rows_seen or self.postprocess is not None:
            result = self.postprocess(rows) if self.postprocess else rows
            if result != self._result:
                self._result = result
                self._rendered = None
            self._rows_seen = rows
        return self._result

    def rendered(self) -> str:
        """Rendered form of the current rows (markdown by default);
        re-rendered only when a refresh changed the row *content* —
        new records that leave the aggregate unchanged cost nothing."""
        self.refresh()
        if self._rendered is None:
            self._rendered = (self.render(self._result) if self.render
                              else markdown_table(self._result))
            self.renders += 1
        return self._rendered

    def explain(self) -> Dict:
        return self.handle.explain()


def streaming_specialized_views(store: StoreLike,
                                manifests: Optional[
                                    Dict[str, JobManifest]] = None,
                                idle_max_frac: float = 0.05,
                                memory_max_frac: float = 0.25,
                                participation_min_frac: float = 0.5,
                                service=None
                                ) -> Dict[str, StreamingView]:
    """The paper's specialized views as streaming dashboards.

    Returns named :class:`StreamingView` instances over the same
    queries as the one-shot ``view_*`` functions — refreshing them
    between pumps matches the one-shot results exactly, but repeated
    refreshes cost only buffer work.  The idle-accelerator view's
    threshold lives in a *tail* stage, so it shares cached per-segment
    partials with the memory view's identical aggregation prefix.
    ``service`` is forwarded to every view (see
    :class:`StreamingView`).
    """
    if manifests is None:  # keep the caller's dict: postprocess closes
        manifests = {}     # over it and re-reads it on every refresh
    return {
        "idle_accelerators": StreamingView(
            store, _IDLE_ACCEL_Q.format(max_frac=idle_max_frac),
            service=service),
        "memory_underuse": StreamingView(
            store, _MEMORY_PEAK_Q,
            postprocess=lambda rows: _memory_underuse_rows(
                rows, manifests, memory_max_frac),
            service=service),
        "low_participation": StreamingView(
            store, _PARTICIPATION_Q,
            postprocess=lambda rows: _low_participation_rows(
                rows, manifests, participation_min_frac),
            service=service),
    }


# ------------------------------------------------------ fleet health (ops) --
#
# The monitor monitoring itself (docs/observability.md): these views run
# over the dedicated ``_telemetry`` store that ``telemetry.SelfMonitor``
# pumps ``kind=fleet`` registry snapshots into — not over job metrics.

FLEET_HEALTH_FIELDS = (
    "remote.queries", "remote.degraded_queries", "remote.retries",
    "breaker.open", "breaker.opens", "breaker.rejections",
    "cache.partial.hits", "cache.partial.misses",
    "storage.segments", "storage.quarantined_segments",
    "tracer.spans_started", "tracer.slow_queries",
)


def _fleet_health_rows(rows: List[Dict],
                       fields: Sequence[str] = FLEET_HEALTH_FIELDS
                       ) -> List[Dict]:
    """Latest snapshot row -> one {metric, value} row per listed field
    (fields absent from the snapshot — e.g. breaker.* on a breakerless
    fleet — are simply omitted)."""
    if not rows:
        return []
    latest = max(rows, key=lambda r: float(r.get("ts", 0.0) or 0.0))
    out = []
    for f in fields:
        v = latest.get(f)
        if isinstance(v, (int, float)):
            out.append({"metric": f, "value": float(v)})
    return out


def view_fleet_health(telemetry_store: StoreLike,
                      fields: Sequence[str] = FLEET_HEALTH_FIELDS
                      ) -> List[Dict]:
    """Ops dashboard: the fleet's own vitals from its newest
    self-ingested ``kind=fleet`` snapshot, as {metric, value} rows
    (render with :func:`markdown_table`)."""
    return _fleet_health_rows(query(telemetry_store, "search kind=fleet"),
                              fields)


def streaming_fleet_health(telemetry_store: StoreLike,
                           fields: Sequence[str] = FLEET_HEALTH_FIELDS,
                           service=None) -> StreamingView:
    """:func:`view_fleet_health` as a :class:`StreamingView` — refresh
    after each self-monitor pump; unchanged vitals re-render nothing."""
    return StreamingView(
        telemetry_store, "search kind=fleet",
        postprocess=lambda rows: _fleet_health_rows(rows, fields),
        service=service)


def view_slow_queries(telemetry_store: StoreLike, limit: int = 10
                      ) -> List[Dict]:
    """Slowest recent queries from the self-ingested slow-query events
    (``kind=event event=slow_query``), worst first."""
    rows = query(telemetry_store, "search kind=event")
    slow = [r for r in rows if r.get("event") == "slow_query"]
    slow.sort(key=lambda r: -float(r.get("duration_s", 0.0) or 0.0))
    return [{"trace_id": r.get("trace_id"), "name": r.get("name"),
             "duration_s": float(r.get("duration_s", 0.0) or 0.0),
             "ts": float(r.get("ts", 0.0) or 0.0)}
            for r in slow[:limit]]


def markdown_table(rows: List[Dict], columns: Optional[List[str]] = None
                   ) -> str:
    if not rows:
        return "*(empty)*\n"
    cols = columns or list(rows[0].keys())
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(fmt(r.get(c, "")) for c in cols) + " |")
    return "\n".join(lines) + "\n"
