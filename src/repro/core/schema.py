"""Metric record schema and the syslog-style ``key=value`` wire format.

The paper's hpcmd writes measured values as single log lines of key-value
pairs to the local syslog.  We keep exactly that philosophy: one record ==
one greppable text line, self-describing, order-insensitive, append-only.

Line format (all on one line)::

    hpcmd ts=1726400000.000 host=node0017 job=cobra.4213 kind=perf \
        step=1200 gflops=812.4 hbm_gbs=410.2 ai=1.98 app="gemma2-27b"

Values: ints and floats are bare; strings are bare when they match
``[A-Za-z0-9._:/+-]+`` and double-quoted with backslash escaping otherwise.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Union

Scalar = Union[int, float, str]

PREFIX = "hpcmd"
_BARE_RE = re.compile(r"^[A-Za-z0-9._:/+-]+$")
_KEY_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")

# Reserved keys that map to MetricRecord attributes rather than fields.
_RESERVED = ("ts", "host", "job", "kind")


@dataclass
class MetricRecord:
    """One sample from one host, attributed to one job."""

    ts: float
    host: str
    job: str
    kind: str  # perf | device | proc | pipeline | net | meta | event
    fields: Dict[str, Scalar] = field(default_factory=dict)

    def get(self, key: str, default=None):
        if key in _RESERVED:
            return getattr(self, key)
        return self.fields.get(key, default)

    def as_dict(self) -> Dict[str, Scalar]:
        d = {"ts": self.ts, "host": self.host, "job": self.job,
             "kind": self.kind}
        d.update(self.fields)
        return d


def _encode_value(v: Scalar) -> str:
    if isinstance(v, bool):  # guard: bools are ints in python
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, float):
        if math.isnan(v):
            return "nan"
        if math.isinf(v):
            return "inf" if v > 0 else "-inf"
        return repr(v)
    s = str(v)
    if s and _BARE_RE.match(s):
        return s
    return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'


def _decode_value(s: str) -> Scalar:
    if s.startswith('"'):
        body = s[1:-1]
        return body.replace('\\"', '"').replace("\\\\", "\\")
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    return s


def encode_line(rec: MetricRecord) -> str:
    parts = [PREFIX,
             f"ts={_encode_value(round(float(rec.ts), 6))}",
             f"host={_encode_value(rec.host)}",
             f"job={_encode_value(rec.job)}",
             f"kind={_encode_value(rec.kind)}"]
    for k in sorted(rec.fields):
        if not _KEY_RE.match(k):
            raise ValueError(f"invalid metric key {k!r}")
        parts.append(f"{k}={_encode_value(rec.fields[k])}")
    return " ".join(parts)


_TOKEN_RE = re.compile(
    r'([A-Za-z_][A-Za-z0-9_.]*)=("(?:[^"\\]|\\.)*"|[^\s"]*)')


def parse_line(line: str) -> Optional[MetricRecord]:
    """Parse one wire line; returns None for non-hpcmd / corrupt lines.

    Transport is at-least-once over plain text, so parsing must never
    raise on garbage (truncated writes, interleaved lines).
    """
    line = line.strip()
    if not line.startswith(PREFIX + " "):
        return None
    body = line[len(PREFIX) + 1:]
    fields: Dict[str, Scalar] = {}
    reserved_raw: Dict[str, str] = {}
    consumed = 0
    for m in _TOKEN_RE.finditer(body):
        key, raw = m.group(1), m.group(2)
        consumed += 1
        if key in _RESERVED:
            # host/job/kind are identifiers: never numeric-decoded
            # (hostname "001" must stay "001")
            if raw.startswith('"'):
                reserved_raw[key] = str(_decode_value(raw))
            else:
                reserved_raw[key] = raw
        else:
            fields[key] = _decode_value(raw)
    if consumed == 0:
        return None
    try:
        ts = float(reserved_raw["ts"])
        host = reserved_raw["host"]
        job = reserved_raw["job"]
        kind = reserved_raw["kind"]
    except (KeyError, ValueError, TypeError):
        return None
    return MetricRecord(ts=ts, host=host, job=job, kind=kind, fields=fields)


def parse_lines(lines: Iterable[str]) -> Iterator[MetricRecord]:
    for line in lines:
        rec = parse_line(line)
        if rec is not None:
            yield rec


def encode_many(recs: Iterable[MetricRecord]) -> str:
    return "".join(encode_line(r) + "\n" for r in recs)
