"""Loop-aware static cost analysis of post-optimization HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers program (ours: every model) is undercounted by ~the layer
count.  This analyzer parses the partitioned HLO text, builds the call
graph (entry -> while bodies / fusions / calls), extracts loop trip counts
from the loop-condition constants, and accumulates:

* **flops** — 2 x prod(result_dims) x prod(contraction_dims) per ``dot``
  (including dots inside fusion subcomputations), x loop multiplier;
* **traffic bytes** — operand + result bytes of every top-level op in a
  computation (post-fusion top-level ops are the kernel boundaries, i.e.
  the HBM traffic model), x loop multiplier;
* **collective bytes/counts** — per kind, x loop multiplier.

This is the TPU analog of the paper's PMU counters: exact static per-step
figures read off the compiled executable (validated against XLA's own
cost analysis on loop-free programs in tests/test_hlo_cost.py).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hlo import _DTYPE_BYTES, COLLECTIVE_KINDS, _normalize_opcode

# ---------------------------------------------------------------- parsing --

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->.*{\s*$")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# result types may be tuples containing /*index=N*/ comments (with '='),
# so the type capture must be permissive; the opcode is the first
# whitespace-preceded word directly followed by '('.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z].*?)\s"
    r"([a-z][a-z0-9\-]*)\(")
_ATTR_COMP_RE = re.compile(
    r"\b(body|condition|to_apply|calls|branch_computations)="
    r"(%?[\w.\-]+|\{[^}]*\})")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"\bconstant\((\d+)\)")
_DOT_DNUMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of_type(text: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(text):
        width = _DTYPE_BYTES.get(dt)
        if width is None:
            continue
        total += width * (math.prod(dims) if dims else 1)
    return total


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    line: str

    def operands_text(self) -> str:
        i = self.line.find(self.opcode + "(")
        if i < 0:
            return ""
        start = i + len(self.opcode)
        depth = 0
        for j in range(start, len(self.line)):
            c = self.line[j]
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return self.line[start + 1:j]
        return self.line[start + 1:]

    def operand_names(self) -> List[str]:
        return _OPERAND_NAME_RE.findall(self.operands_text())

    def called(self) -> Dict[str, List[str]]:
        """attr -> computation names for body/condition/calls/..."""
        out: Dict[str, List[str]] = {}
        for m in _ATTR_COMP_RE.finditer(self.line):
            attr, blob = m.group(1), m.group(2)
            names = re.findall(r"%?([\w.\-]+)", blob)
            out.setdefault(attr, []).extend(names)
        return out


@dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: List[Instruction] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # name -> type

    def operand_bytes(self, inst: Instruction) -> float:
        total = 0.0
        text = inst.operands_text()
        inline = _bytes_of_type(text)
        if inline:
            return inline  # long-form HLO with inline operand types
        for name in inst.operand_names():
            t = self.types.get(name)
            if t:
                total += _bytes_of_type(t)
        return total


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            stripped = line.strip()
            if stripped.endswith("{"):
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = Computation(name=m.group(2),
                                      is_entry=bool(m.group(1)))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            inst = Instruction(name=m.group(1),
                               result_type=m.group(2).strip(),
                               opcode=m.group(3), line=line)
            cur.instructions.append(inst)
            cur.types[inst.name] = inst.result_type
    if cur is not None:
        comps[cur.name] = cur
    return comps


# ------------------------------------------------------------- cost model --

_SKIP_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "iota",
    "get-dimension-size", "partition-id", "replica-id", "copy-start",
    "copy-done",
}


def _instruction_traffic(comp: Computation, inst: Instruction,
                         comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of one top-level (kernel-boundary) instruction.

    Slice-aware: ``dynamic-update-slice`` is an in-place RMW of the update
    region only; ``dynamic-slice`` reads the slice, not the whole buffer.
    Fusions rooted in a DUS (XLA in-place fusions) and fusions that merely
    slice a big parameter are treated accordingly — without this, a scan
    that checkpoints activations into a [L, ...] stack appears to move the
    whole stack every layer.
    """
    op = inst.opcode
    if op == "dynamic-slice":
        return 2.0 * _bytes_of_type(inst.result_type)  # read slice + write
    if op == "dynamic-update-slice":
        names = inst.operand_names()
        upd = _bytes_of_type(comp.types.get(names[1], "")) if len(
            names) > 1 else 0.0
        return 2.0 * upd  # read update + write region (buffer aliased)
    if op != "fusion":
        return (_bytes_of_type(inst.result_type)
                + comp.operand_bytes(inst))

    called = inst.called().get("calls", [])
    fcomp = comps.get(called[0]) if called else None
    if fcomp is None or not fcomp.instructions:
        return (_bytes_of_type(inst.result_type)
                + comp.operand_bytes(inst))
    # map fusion parameters to "effective read bytes"
    param_by_idx: Dict[int, Instruction] = {}
    for fi in fcomp.instructions:
        if fi.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", fi.line)
            if m:
                param_by_idx[int(m.group(1))] = fi
    root = fcomp.instructions[-1]
    dus_buffer_param = None
    if root.opcode == "dynamic-update-slice":
        names = root.operand_names()
        if names:
            dus_buffer_param = names[0]
    reads = 0.0
    operand_names = inst.operand_names()
    for idx, oname in enumerate(operand_names):
        fparam = param_by_idx.get(idx)
        full = _bytes_of_type(comp.types.get(oname, ""))
        if fparam is None:
            reads += full
            continue
        if fparam.name == dus_buffer_param:
            continue  # aliased in-place target: no full read
        # if the param is only consumed by dynamic-slice ops, the kernel
        # reads just the slices
        slice_bytes, other_use = 0.0, False
        for fi in fcomp.instructions:
            if fi is fparam:
                continue
            if fparam.name in fi.operand_names():
                if fi.opcode == "dynamic-slice":
                    slice_bytes += _bytes_of_type(fi.result_type)
                else:
                    other_use = True
        if other_use or (slice_bytes == 0.0):
            reads += full
        else:
            reads += slice_bytes
    if root.opcode == "dynamic-update-slice":
        names = root.operand_names()
        upd = _bytes_of_type(fcomp.types.get(names[1], "")) if len(
            names) > 1 else 0.0
        write = 2.0 * upd
    else:
        write = _bytes_of_type(inst.result_type)
    return reads + write


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    """2 * prod(result) * prod(lhs contracting dims)."""
    shapes = _shape_dims(inst.result_type)
    if not shapes:
        return 0.0
    result_elems = math.prod(shapes[0][1]) if shapes[0][1] else 1
    # lhs type: inline or resolved through the def map
    ops_text = inst.operands_text()
    op_shapes = _shape_dims(ops_text)
    if not op_shapes:
        names = inst.operand_names()
        if names:
            t = comp.types.get(names[0], "")
            op_shapes = _shape_dims(t)
    if not op_shapes:
        return 0.0
    lhs_dims = op_shapes[0][1]
    m = _DOT_DNUMS_RE.search(inst.line)
    if m and m.group(1):
        contract = 1
        for d in m.group(1).split(","):
            idx = int(d)
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    else:
        contract = lhs_dims[-1] if lhs_dims else 1
    return 2.0 * result_elems * contract


def _trip_count(cond: Computation) -> int:
    """Loop trip count: the largest integer constant in the condition
    computation (all our scans have static trip counts)."""
    best = 1
    for inst in cond.instructions:
        for m in _CONST_INT_RE.finditer(inst.line):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_result_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    loop_trips: Dict[str, int] = field(default_factory=dict)
    traffic_by_tag: Dict[str, float] = field(default_factory=dict)

    def add_collective(self, kind: str, operand_bytes: float,
                       result_bytes: float, mult: float) -> None:
        self.collective_bytes += operand_bytes * mult
        self.collective_result_bytes += result_bytes * mult
        self.collective_counts[kind] = (self.collective_counts.get(kind, 0)
                                        + int(mult))
        self.collective_bytes_by_kind[kind] = (
            self.collective_bytes_by_kind.get(kind, 0.0)
            + operand_bytes * mult)

    def as_fields(self) -> Dict[str, float]:
        out = {"coll_bytes": self.collective_bytes,
               "coll_count": float(sum(self.collective_counts.values())),
               "hlo_flops": self.flops,
               "hlo_traffic_bytes": self.traffic_bytes}
        for kind, b in sorted(self.collective_bytes_by_kind.items()):
            key = kind.replace("-", "_")
            out[f"coll_{key}_bytes"] = b
            out[f"coll_{key}_count"] = float(
                self.collective_counts.get(kind, 0))
        return out


def analyze_hlo(hlo_text: str, tag_fn=None) -> HloCost:
    """``tag_fn(result_type_str) -> Optional[str]`` attributes traffic to
    named buckets (e.g. attention-score tensors) in ``traffic_by_tag``."""
    comps = parse_computations(hlo_text)
    cost = HloCost()
    entries = [c for c in comps.values() if c.is_entry]
    if not entries and comps:
        entries = [list(comps.values())[-1]]

    fusion_cache: Dict[str, float] = {}

    def fusion_flops(name: str) -> float:
        if name in fusion_cache:
            return fusion_cache[name]
        fusion_cache[name] = 0.0  # cycle guard
        comp = comps.get(name)
        total = 0.0
        if comp is not None:
            for inst in comp.instructions:
                if inst.opcode in ("dot", "convolution"):
                    total += _dot_flops(inst, comp)
                for names in inst.called().values():
                    for sub in names:
                        if sub in comps and sub != name:
                            total += fusion_flops(sub)
        fusion_cache[name] = total
        return total

    stack: List[str] = []

    def walk(comp: Computation, mult: float) -> None:
        if comp.name in stack:  # defensive: HLO has no recursion
            return
        stack.append(comp.name)
        for inst in comp.instructions:
            op = inst.opcode
            base = _normalize_opcode(op)
            if op in ("dot", "convolution"):
                cost.flops += _dot_flops(inst, comp) * mult
            if base in COLLECTIVE_KINDS and not op.endswith("-done"):
                ob = comp.operand_bytes(inst)
                rb = _bytes_of_type(inst.result_type)
                cost.add_collective(base, ob, rb, mult)
            called = inst.called()
            if op == "fusion":
                for name in called.get("calls", []):
                    cost.flops += fusion_flops(name) * mult
            elif op == "while":
                body_names = called.get("body", [])
                cond_names = called.get("condition", [])
                cond = comps.get(cond_names[0]) if cond_names else None
                trips = _trip_count(cond) if cond is not None else 1
                cost.loop_trips[f"{comp.name}/{inst.name}"] = trips
                for name in body_names:
                    if name in comps:
                        walk(comps[name], mult * trips)
            elif op in ("call", "custom-call", "conditional"):
                for key in ("to_apply", "calls", "branch_computations"):
                    for name in called.get(key, []):
                        if name in comps:
                            walk(comps[name], mult)
            if op not in _SKIP_TRAFFIC_OPS and not op.endswith("-done"):
                traffic = _instruction_traffic(comp, inst, comps) * mult
                cost.traffic_bytes += traffic
                if tag_fn is not None:
                    tag = tag_fn(inst.result_type)
                    if tag:
                        cost.traffic_by_tag[tag] = (
                            cost.traffic_by_tag.get(tag, 0.0) + traffic)
        stack.pop()

    for entry in entries:
        walk(entry, 1.0)
    return cost
