"""Shard worker processes — serve one durable shard store over the
length-prefixed JSON wire protocol (``repro.core.remote``,
docs/remote.md).

A worker is the leaf of the PerSyst-style agent tree: it owns one
``ColumnarMetricStore`` directory (a ``shard-NN/`` dir from a sharded
fleet, or any standalone store dir), executes serialized
:class:`~repro.core.splunklite.ScatterPlan`s against it — consulting
its own segment-keyed partial-aggregate cache — and ships back merged
partial-state maps.  Everything a worker serves is reconstructed from
its directory on startup (segments mmap in, the WAL tail replays,
dedup keys reload), so killing and restarting a worker loses nothing.

Run one directly::

    repro-shard-worker --dir fleet/shard-00            # console script
    python -m repro.core.workers --dir fleet/shard-00  # equivalent

The worker prints one ``REPRO_WORKER_READY host=... port=...`` line on
stdout once it is listening (``--port 0`` picks an ephemeral port);
fleet spawners parse it.  Connections are served **overlapped** — one
thread per client, so a coordinator's pooled connections (concurrent
scatters from a multi-tenant ``QueryService``) don't serialize on the
accept loop; store operations themselves run one at a time under a
worker-wide lock, which keeps the version-then-compute sequence of a
conditional scatter atomic.  A disconnected client can reconnect — the
listener survives.  ``--idle-timeout-s`` makes an orphaned worker exit
on its own, so a wedged coordinator cannot leak processes in CI.
"""

from __future__ import annotations

import argparse
import socket
import struct
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np

from repro.core import faults, remote, splunklite
from repro.core.columnar import ColumnarMetricStore
from repro.core.schema import encode_line, parse_line
from repro.core.splunklite import QueryError, ScatterPlan, _Fallback
from repro.core.telemetry import Telemetry

_LEN = struct.Struct("!I")


class _ConnDone(Exception):
    """Client went away (EOF) or the worker is shutting down."""


class ShardWorker:
    """Serve one shard store directory on a localhost socket."""

    # a client that stalls mid-frame is dropped after this long; a
    # fresh connection is always welcome afterwards
    FRAME_STALL_S = 60.0

    # bounded memory of recently applied mutation idempotency keys →
    # their successful replies: a coordinator retry that resends a key
    # replays the recorded reply instead of re-applying (docs/faults.md)
    IDEM_CACHE_MAX = 512
    MUTATION_OPS = frozenset({"insert", "lines", "seal", "adopt_replica",
                              "compact", "retention"})

    def __init__(self, directory, host: str = "127.0.0.1", port: int = 0,
                 seal_threshold: int = 4096,
                 dedup_horizon_s: Optional[float] = None,
                 wal_fsync: bool = False,
                 partial_cache_entries: int = 512,
                 idle_timeout_s: Optional[float] = None,
                 frame_checksums: bool = True) -> None:
        self._store_kwargs = dict(
            seal_threshold=seal_threshold, dedup_horizon_s=dedup_horizon_s,
            wal_fsync=wal_fsync, partial_cache_entries=partial_cache_entries)
        self.store = ColumnarMetricStore(directory=directory,
                                         **self._store_kwargs)
        self.sock = socket.create_server((host, int(port)))
        self.sock.settimeout(0.5)
        self.address = self.sock.getsockname()[:2]
        self.idle_timeout_s = idle_timeout_s
        self.requests_served = 0
        self._shutdown = False
        # fault-injection knob (``set_delay`` op): sleep before serving
        # scatter/gather, so tests and benchmarks can make one worker
        # artificially slow (hedged-scatter tail-latency measurements)
        self.delay_s = 0.0
        # robustness state (docs/faults.md): crc32c trailers on reply
        # frames, mutation idempotency replay cache, and the
        # ``set_faults`` knobs (storage fault plan, kill countdown)
        self.frame_checksums = bool(frame_checksums)
        self._idem_cache: "OrderedDict[str, Dict]" = OrderedDict()
        self._idem_replays = 0
        self._kill_after_ops: Optional[int] = None
        self._fault_plan: Optional[faults.FaultPlan] = None
        # _last_activity, requests_served, and the in-flight count are
        # touched from every per-connection thread plus the accept
        # loop's idle check — one small lock keeps the counters exact
        # (lost += updates made them lie under thread-per-connection)
        self._stats_lock = threading.Lock()
        self._inflight = 0
        self._last_activity = time.monotonic()
        # one thread per connection; ops serialize on this lock so a
        # scatter's version read and its partial computation see one
        # consistent store state even while another connection ingests
        self._op_lock = threading.RLock()
        self._conn_threads: List[threading.Thread] = []
        # worker-side telemetry (docs/observability.md): spans are
        # created only for requests carrying a ``trace`` context from a
        # trace-capable coordinator (negotiated at hello) and shipped
        # back in the reply's ``spans`` list
        import os as _os
        self.telemetry = Telemetry(tracing=True,
                                   node=f"worker:{_os.getpid()}")
        self.telemetry.registry.register_collector(
            "worker", self._telemetry_samples)

    def _telemetry_samples(self) -> Dict[str, float]:
        with self._stats_lock:
            out = {"worker.requests_served": float(self.requests_served),
                   "worker.inflight": float(self._inflight)}
        out["worker.idem_replays"] = float(self._idem_replays)
        pc = self.store.partial_cache
        out["worker.cache.partial.hits"] = float(pc.hits)
        out["worker.cache.partial.misses"] = float(pc.misses)
        return out

    # ------------------------------------------------------------ serving --
    def _touch(self) -> None:
        with self._stats_lock:
            self._last_activity = time.monotonic()

    def _idle_expired(self) -> bool:
        """Idle only counts while nothing is in flight: a request whose
        handler runs longer than ``idle_timeout_s`` (a cold fleet scan,
        a replica catch-up) must not get its worker shut down
        underneath it — the timer starts again when the reply is
        sent."""
        if self.idle_timeout_s is None:
            return False
        with self._stats_lock:
            if self._inflight:
                return False
            idle_for = time.monotonic() - self._last_activity
        return idle_for > self.idle_timeout_s

    def serve_forever(self) -> None:
        try:
            while not self._shutdown and not self._idle_expired():
                try:
                    conn, _addr = self.sock.accept()
                except socket.timeout:
                    continue
                t = threading.Thread(target=self._conn_main, args=(conn,),
                                     daemon=True,
                                     name=f"worker-conn-{self.address[1]}")
                t.start()
                self._conn_threads.append(t)
                self._conn_threads = [x for x in self._conn_threads
                                      if x.is_alive()]
        finally:
            for t in self._conn_threads:
                t.join(timeout=2.0)
            self.close()

    def _conn_main(self, conn: socket.socket) -> None:
        with conn:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._serve_conn(conn)

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.settimeout(0.5)
        self._touch()
        while not self._shutdown:
            try:
                msg = self._read_frame(conn)
            except _ConnDone:
                return
            except (OSError, remote.RemoteProtocolError):
                return  # framing broken: drop the connection, keep serving
            with self._stats_lock:
                self._inflight += 1
                self._last_activity = time.monotonic()
            served = False
            try:
                reply = self.handle(msg)
                try:
                    remote.send_frame(conn, reply,
                                      checksum=self.frame_checksums)
                    served = True
                except (OSError, ValueError):
                    return
            finally:
                with self._stats_lock:
                    self._inflight -= 1
                    self._last_activity = time.monotonic()
                    if served:
                        self.requests_served += 1

    def _read_frame(self, conn: socket.socket) -> Dict:
        """Read one frame, waking every 0.5s while *between* frames to
        honor shutdown/idle deadlines; once a frame starts, a stalled
        client is abandoned after ``FRAME_STALL_S``."""
        header = self._read_exact(conn, 4, waiting_for_frame=True)
        (word,) = _LEN.unpack(header)
        checked = bool(word & remote.FRAME_CRC_FLAG)
        n = word & ~remote.FRAME_CRC_FLAG
        if n > remote.MAX_FRAME_BYTES:
            raise remote.RemoteProtocolError(f"oversized frame: {n}B")
        payload = self._read_exact(conn, n, waiting_for_frame=False)
        if checked:
            trailer = self._read_exact(conn, 4, waiting_for_frame=False)
            (want,) = _LEN.unpack(trailer)
            if faults.crc32c(payload) != want:
                # the request bytes are untrustworthy and the stream
                # position is too: drop the connection (the caller of
                # _read_frame treats any protocol error that way), the
                # client sees EOF and retries on a fresh socket
                raise remote.FrameChecksumError(
                    "request frame checksum mismatch")
        import json
        try:
            msg = json.loads(payload.decode("utf-8"))
        except ValueError as exc:
            raise remote.RemoteProtocolError(str(exc)) from exc
        if not isinstance(msg, dict):
            raise remote.RemoteProtocolError("frame payload must be object")
        return msg

    def _read_exact(self, conn: socket.socket, n: int,
                    waiting_for_frame: bool) -> bytes:
        buf = bytearray()
        started = time.monotonic()
        while len(buf) < n:
            try:
                chunk = conn.recv(min(n - len(buf), 1 << 20))
            except socket.timeout:
                if waiting_for_frame and not buf:
                    if self._shutdown or self._idle_expired():
                        raise _ConnDone
                    continue
                if time.monotonic() - started > self.FRAME_STALL_S:
                    raise remote.RemoteProtocolError("client stalled "
                                                     "mid-frame")
                continue
            if not chunk:
                raise _ConnDone
            buf += chunk
            started = time.monotonic()
        return bytes(buf)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass
        self.store.close()

    # ----------------------------------------------------------- dispatch --
    def handle(self, msg: Dict) -> Dict:
        op = msg.get("op")
        fn = getattr(self, f"_op_{op}", None) if isinstance(op, str) else None
        if fn is None or op.startswith("_"):
            return {"ok": False, "kind": "RemoteProtocolError",
                    "error": f"unknown op {op!r}"}
        if self.delay_s > 0 and op in ("scatter", "gather"):
            # injected slowness sleeps outside the op lock: a slow
            # query must not also stall this worker's pings/ingest
            time.sleep(self.delay_s)
        idem = msg.get("idem")
        if not (isinstance(idem, str) and op in self.MUTATION_OPS):
            idem = None
        # optional distributed-trace context (docs/observability.md):
        # popped before dispatch so op handlers never see it; only
        # trace-capable coordinators send it (negotiated at hello)
        tctx = msg.pop("trace", None)
        if not isinstance(tctx, dict):
            tctx = None
        try:
            with self._op_lock:
                if idem is not None:
                    hit = self._idem_cache.get(idem)
                    if hit is not None:
                        # the mutation already applied; its reply was
                        # lost in transit — replay it, apply nothing
                        self._idem_cache.move_to_end(idem)
                        self._idem_replays += 1
                        return dict(hit)
                self._maybe_kill()
                if tctx is not None:
                    span = self.telemetry.tracer.start_span(
                        f"worker.{op}", parent_ctx=tctx)
                    with span:
                        out = fn(msg) or {}
                        st = out.get("stats")
                        if isinstance(st, dict):
                            span.set(**{k: v for k, v in st.items()
                                        if isinstance(v, (int, float))})
                        for flag in ("not_modified", "fallback"):
                            if out.get(flag):
                                span.set(**{flag: True})
                else:
                    span = None
                    out = fn(msg) or {}
                out["ok"] = True
                if idem is not None:
                    # success-only: a failed mutation must stay
                    # retryable under a fresh attempt, not replay its
                    # error forever (replies are cached without spans —
                    # a replay belongs to the retry's trace, not the
                    # original's)
                    self._idem_cache[idem] = dict(out)
                    while len(self._idem_cache) > self.IDEM_CACHE_MAX:
                        self._idem_cache.popitem(last=False)
                if span is not None:
                    out["spans"] = self.telemetry.tracer.take_trace(
                        span.trace_id)
                return out
        except QueryError as exc:
            return {"ok": False, "kind": "QueryError", "error": str(exc)}
        except Exception as exc:  # noqa: BLE001 - must never kill the loop
            return {"ok": False, "kind": type(exc).__name__,
                    "error": f"{type(exc).__name__}: {exc}"}

    def _maybe_kill(self) -> None:
        """``set_faults(kill_after_ops=k)`` countdown: the k-th
        subsequent op hard-kills the process mid-op (no reply, no
        cleanup) — the chaos suite's worker-crash primitive."""
        k = self._kill_after_ops
        if k is None:
            return
        if k <= 0:
            import os
            os._exit(1)
        self._kill_after_ops = k - 1

    # ---------------------------------------------------------------- ops --
    def _op_hello(self, msg: Dict) -> Dict:
        if msg.get("proto") != remote.PROTOCOL_VERSION or \
                msg.get("codec") != remote.CODEC_VERSION:
            raise remote.RemoteProtocolError(
                f"protocol {msg.get('proto')}/codec {msg.get('codec')} "
                f"unsupported (this worker: {remote.PROTOCOL_VERSION}/"
                f"{remote.CODEC_VERSION})")
        import os
        return {"proto": remote.PROTOCOL_VERSION,
                "codec": remote.CODEC_VERSION,
                "nrecords": len(self.store), "pid": os.getpid(),
                "dir": str(self.store.directory),
                # capability flag: this worker accepts a ``trace``
                # context on requests and returns its spans in replies;
                # old coordinators ignore the key, old workers simply
                # never advertise it (docs/observability.md)
                "trace": True}

    def _op_ping(self, msg: Dict) -> Dict:
        return {}

    def _op_shutdown(self, msg: Dict) -> Dict:
        self._shutdown = True
        return {}

    def _op_len(self, msg: Dict) -> Dict:
        return {"n": len(self.store)}

    def _op_dups(self, msg: Dict) -> Dict:
        return {"n": self.store.duplicates_dropped}

    def _op_version(self, msg: Dict) -> Dict:
        return {"v": list(self.store._version())}

    def _op_insert(self, msg: Dict) -> Dict:
        rec = parse_line(str(msg.get("line", "")))
        accepted = rec is not None and self.store.insert(rec)
        return {"accepted": bool(accepted)}

    def _op_lines(self, msg: Dict) -> Dict:
        return {"n": self.store.ingest_lines(
            str(ln) for ln in msg.get("lines", []))}

    def _op_seal(self, msg: Dict) -> Dict:
        self.store.seal()
        return {}

    def _op_scatter(self, msg: Dict) -> Dict:
        """Worker half of a distributed query: reduce every matching
        segment to partial states (cache-aware — the PR 4 warm path)
        and reply with the worker-locally merged map (level 1 of the
        two-level gather).

        A request whose ``etag`` matches this plan fingerprint at the
        store's current version short-circuits to ``not_modified`` —
        the coordinator already holds this exact map decoded.  The
        (sealed, buffer) version is content-stable: stores are
        append-only between versions and a restarted worker's WAL
        replay reproduces the pre-crash state bit-for-bit."""
        plan = ScatterPlan.from_state(msg["plan"])
        version = list(self.store._version())
        etag = msg.get("etag")
        if (isinstance(etag, list) and len(etag) == 2
                and etag[0] == plan.fingerprint
                and list(etag[1]) == version):
            return {"not_modified": True, "version": version}
        stats: Dict[str, int] = {}
        try:
            pmap = splunklite.scatter_partials(
                self.store, plan, cache=self.store.partial_cache,
                stats=stats)
        except _Fallback:
            # mirror in-process semantics: the coordinator re-plans the
            # whole query as an exact gather
            return {"fallback": True}
        return {"groups": remote.encode_partial_map(pmap), "stats": stats,
                "version": version}

    def _op_gather(self, msg: Dict) -> Dict:
        stages = [[str(t) for t in toks] for toks in msg.get("stages", [])]
        ts, rows, _rest = splunklite.gather_filtered(self.store, stages)
        return {"ts": remote.encode_array(np.asarray(ts, np.float64)),
                "rows": remote.encode_rows(rows)}

    def _op_scan(self, msg: Dict) -> Dict:
        sc = self.store.scan(job=msg.get("job"), kind=msg.get("kind"),
                             since=msg.get("since"), until=msg.get("until"),
                             fields=tuple(msg.get("fields") or ()))
        return {"scan": remote.encode_scan(sc)}

    def _op_records(self, msg: Dict) -> Dict:
        return {"lines": [encode_line(r) for r in self.store.records]}

    def _op_select(self, msg: Dict) -> Dict:
        return {"lines": [encode_line(r) for r in self.store.select(
            job=msg.get("job"), kind=msg.get("kind"),
            since=msg.get("since"), until=msg.get("until"))]}

    def _op_vocab(self, msg: Dict) -> Dict:
        which = msg.get("which")
        if which == "jobs":
            return {"values": self.store.jobs()}
        if which == "kinds":
            return {"values": self.store.kinds()}
        if which == "hosts":
            return {"values": self.store.hosts(msg.get("job"))}
        raise remote.RemoteProtocolError(f"unknown vocab {which!r}")

    def _op_cache_stats(self, msg: Dict) -> Dict:
        pc = self.store.partial_cache
        return {"hits": pc.hits, "misses": pc.misses,
                "evictions": pc.evictions, "entries": len(pc)}

    def _op_clear_cache(self, msg: Dict) -> Dict:
        self.store.partial_cache.clear()
        return {}

    def _op_explain(self, msg: Dict) -> Dict:
        fp = str(msg.get("fingerprint", ""))
        sealed = cached = 0
        for _seg, uid in self.store.segment_units(include_buffer=False):
            sealed += 1
            if self.store.partial_cache.peek((uid, fp)):
                cached += 1
        pc = self.store.partial_cache
        return {"sealed": sealed, "cached": cached,
                "buffer_rows": len(self.store._buffer),
                "cache": {"hits": pc.hits, "misses": pc.misses,
                          "evictions": pc.evictions, "entries": len(pc)},
                "storage": self.store.storage_stats(),
                "idem_replays": self._idem_replays,
                "quarantined_segments": self.store.quarantined_segments,
                "telemetry": self.telemetry.registry.flat_snapshot()}

    def _op_compact(self, msg: Dict) -> Dict:
        """Run segment compaction on the worker's store.  The reply
        carries ``retired_uids`` so the coordinator can evict its own
        decoded-scatter memos for the retired segments (the stale-etag
        window after compaction; see RemoteShard.compact)."""
        kwargs = {k: msg[k] for k in ("small_rows", "target_rows",
                                      "min_run", "compress") if k in msg}
        return {"stats": self.store.compact(**kwargs),
                "version": list(self.store._version())}

    def _op_retention(self, msg: Dict) -> Dict:
        kwargs: Dict = {}
        if "rollups" in msg:
            kwargs["rollups"] = [tuple(t) if isinstance(t, list) else t
                                 for t in msg["rollups"]]
        if "raw_max_age_s" in msg:
            kwargs["raw_max_age_s"] = msg["raw_max_age_s"]
        return {"stats": self.store.apply_retention(**kwargs),
                "version": list(self.store._version())}

    def _op_storage(self, msg: Dict) -> Dict:
        return {"storage": self.store.storage_stats()}

    def _op_set_delay(self, msg: Dict) -> Dict:
        """Fault injection: sleep this long before every scatter/gather
        (tests and bench_replication make one worker artificially slow
        to exercise hedging)."""
        self.delay_s = max(0.0, float(msg.get("s", 0.0)))
        return {"delay_s": self.delay_s}

    def _op_set_faults(self, msg: Dict) -> Dict:
        """Install worker-side fault injection (chaos tests/bench only;
        docs/faults.md).  Knobs:

        ``clear``            drop any installed storage fault plan
        ``seed``/``seal_rates``   probabilistic seal faults
        ``seal_enospc`` / ``seal_torn_bin`` / ``seal_torn_manifest``
                             force exactly N scripted seal faults
        ``delay_s``          scatter/gather slowness (as ``set_delay``)
        ``kill_after_ops``   hard-kill the process mid-op after N ops
        ``frame_checksums``  toggle crc32c trailers on reply frames
        """
        if msg.get("clear"):
            faults.install_storage_faults(None)
            self._fault_plan = None
        scripted = ("seal_enospc", "seal_torn_bin", "seal_torn_manifest")
        if ("seed" in msg or "seal_rates" in msg
                or any(k in msg for k in scripted)):
            rates = ({"seal": dict(msg["seal_rates"])}
                     if msg.get("seal_rates") else None)
            plan = faults.FaultPlan(seed=int(msg.get("seed", 0)),
                                    rates=rates)
            for kind, key in (("enospc", "seal_enospc"),
                              ("torn_bin", "seal_torn_bin"),
                              ("torn_manifest", "seal_torn_manifest")):
                times = int(msg.get(key, 0))
                if times:
                    plan.force("seal", kind, times=times)
            faults.install_storage_faults(plan)
            self._fault_plan = plan
        if "delay_s" in msg:
            self.delay_s = max(0.0, float(msg["delay_s"]))
        if "kill_after_ops" in msg:
            v = msg["kill_after_ops"]
            self._kill_after_ops = None if v is None else int(v)
        if "frame_checksums" in msg:
            self.frame_checksums = bool(msg["frame_checksums"])
        return {"installed": self._fault_plan is not None,
                "delay_s": self.delay_s,
                "kill_after_ops": self._kill_after_ops,
                "frame_checksums": self.frame_checksums}

    # ------------------------------------------------------- replication --
    def _op_sync_state(self, msg: Dict) -> Dict:
        """Primary half of replica catch-up (docs/replication.md): the
        store's committed history (ordered sealed + rollup stems with
        content uids), its WAL tail, and its mutation generation — the
        coordinator diffs this against each replica's own sync_state to
        plan whole-segment shipping."""
        st = self.store
        return {"version": list(st._version()),
                "seq": int(st._next_seq),
                "sealed": [{"stem": stem, "uid": seg.uid}
                           for seg, stem in zip(st._sealed,
                                                st._sealed_stems)],
                "rollups": [{"stem": stem, "uid": seg.uid}
                            for seg, stem in zip(st._rollups,
                                                 st._rollup_stems)],
                "buffer_lines": [encode_line(r) for r in st._buffer]}

    def _op_fetch_segment(self, msg: Dict) -> Dict:
        """Ship one committed segment's file pair (manifest JSON +
        base64 data) for whole-segment adoption on a replica.  The stem
        is validated against the segment naming scheme — this op serves
        segment files, not arbitrary paths."""
        import base64
        import json as _json
        from pathlib import Path
        stem = str(msg.get("stem", ""))
        if (not stem.startswith("seg-") or "/" in stem or "\\" in stem
                or ".." in stem):
            raise remote.RemoteProtocolError(f"bad segment stem {stem!r}")
        seg_dir = Path(self.store.directory) / "segments"
        with open(seg_dir / (stem + ".json"), encoding="utf-8") as f:
            manifest = _json.load(f)
        data = (seg_dir / (stem + ".bin")).read_bytes()
        return {"manifest": manifest,
                "bin": base64.b64encode(data).decode("ascii")}

    def _op_adopt_replica(self, msg: Dict) -> Dict:
        """Replica half of catch-up: optionally reset the store (the
        replica's history diverged — compaction/retention rewrote the
        primary's past), adopt shipped whole segments in primary order,
        and finally replace the buffer with the primary's WAL tail
        while fast-forwarding the mutation generation, so the replica's
        ``(sealed, buffer, seq)`` version converges to the primary's
        exactly.  Each call ships a bounded batch; the coordinator
        sequences them (reset → segments → buffer+seq)."""
        if msg.get("reset"):
            self._reset_store()
        adopted = 0
        for item in msg.get("segments", []):
            adopted += self._adopt_shipped(item)
        if "buffer_lines" in msg:
            self.store.adopt_buffer(
                [str(ln) for ln in msg["buffer_lines"]],
                next_seq=msg.get("seq"))
        return {"version": list(self.store._version()), "adopted": adopted}

    def _reset_store(self) -> None:
        """Wipe and reopen the store directory (full re-adoption)."""
        import shutil
        from pathlib import Path
        directory = Path(self.store.directory)
        self.store.close()
        for child in directory.iterdir():
            if child.is_dir():
                shutil.rmtree(child, ignore_errors=True)
            else:
                try:
                    child.unlink()
                except OSError:
                    pass
        self.store = ColumnarMetricStore(directory=directory,
                                         **self._store_kwargs)

    def _adopt_shipped(self, item: Dict) -> int:
        """Write a shipped segment pair to a staging dir, then adopt it
        through the store's own commit protocol (copy under its next
        stem, fsync, route rollups to the rollup tier)."""
        import base64
        import json as _json
        import tempfile
        from pathlib import Path
        with tempfile.TemporaryDirectory(
                dir=self.store.directory) as td:
            man_path = Path(td) / "shipped.json"
            (Path(td) / "shipped.bin").write_bytes(
                base64.b64decode(str(item["bin"])))
            with open(man_path, "w", encoding="utf-8") as f:
                _json.dump(item["manifest"], f)
            return self.store.adopt_segment(man_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-shard-worker",
        description="Serve one shard store directory over the repro "
                    "remote wire protocol (docs/remote.md).")
    ap.add_argument("--dir", required=True,
                    help="store directory to serve (created if missing)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port; 0 picks an ephemeral one")
    ap.add_argument("--seal-threshold", type=int, default=4096)
    ap.add_argument("--dedup-horizon-s", type=float, default=None)
    ap.add_argument("--wal-fsync", action="store_true")
    ap.add_argument("--partial-cache-entries", type=int, default=512)
    ap.add_argument("--idle-timeout-s", type=float, default=None,
                    help="exit after this long with no client activity "
                         "(orphan protection for CI)")
    ap.add_argument("--no-frame-checksums", action="store_true",
                    help="send reply frames without crc32c trailers "
                         "(benchmark baseline; docs/faults.md)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the READY line")
    args = ap.parse_args(argv)
    worker = ShardWorker(
        args.dir, host=args.host, port=args.port,
        seal_threshold=args.seal_threshold,
        dedup_horizon_s=args.dedup_horizon_s,
        wal_fsync=args.wal_fsync,
        partial_cache_entries=args.partial_cache_entries,
        idle_timeout_s=args.idle_timeout_s,
        frame_checksums=not args.no_frame_checksums)
    if not args.quiet:
        print(f"{remote.READY_PREFIX} host={worker.address[0]} "
              f"port={worker.address[1]}", flush=True)
    worker.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
