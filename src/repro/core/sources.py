"""Metric sources — the hpcmd data-source layer (paper §4.1), TPU-adapted.

Each source is a cheap, *never-raising* callable that returns one bundle of
fields per sample.  The daemon owns scheduling; sources own measurement.
Mapping to the paper (see DESIGN.md §2 for the full table):

* ``XlaCostSource``   — CPU core/uncore PMU analog (FLOPs, bytes, AI, MFU)
* ``CollectiveSource``— network-counter analog (ICI traffic)
* ``DeviceSource``    — nvidia-smi analog (device memory occupancy)
* ``ProcSource``      — ps/numastat//proc analog (RSS, threads, loadavg)
* ``PipelineSource``  — I/O analog (data-pipeline throughput and stalls)
* ``EnvSource``       — job environment capture (one-shot meta record)
"""

from __future__ import annotations

import os
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core import derived
from repro.core.derived import HardwareSpec, TPU_V5E

Fields = Dict[str, object]


class MetricSource:
    """Base class.  ``collect`` must be cheap and must not raise."""

    name = "base"
    kind = "meta"
    once = False  # one-shot sources emit a single record then go quiet

    def collect(self, now: float) -> Optional[Fields]:
        raise NotImplementedError

    def safe_collect(self, now: float) -> Optional[Fields]:
        try:
            return self.collect(now)
        except Exception as exc:  # noqa: BLE001 — monitoring must not kill jobs
            return {"source_error": f"{type(exc).__name__}: {exc}",
                    "source_name": self.name}


# --------------------------------------------------------------------- clock

@dataclass
class StepEvent:
    ts: float
    step: int
    tokens: int
    loss: float
    cum_tokens: int = 0


class StepClock:
    """Shared step progress state, fed by the training/serving loop hook.

    Samples are differenced between daemon ticks, so the daemon sees the
    *rate* over its own sampling window — matching hpcmd's interval
    semantics rather than per-step noise.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: Deque[StepEvent] = deque(maxlen=4096)
        self.last_step = -1
        self.last_loss = float("nan")
        self.total_tokens = 0
        self._last_sample: Optional[StepEvent] = None

    def record(self, step: int, tokens: int = 0,
               loss: float = float("nan"), ts: Optional[float] = None) -> None:
        with self._lock:
            self.total_tokens += tokens
            ev = StepEvent(ts if ts is not None else time.time(), step,
                           tokens, loss, cum_tokens=self.total_tokens)
            self._events.append(ev)
            self.last_step = step
            self.last_loss = loss

    def window(self, now: Optional[float] = None
               ) -> Optional[Tuple[StepEvent, StepEvent]]:
        """(previous-sample anchor, latest event); advances the anchor.

        When no new step events arrived since the last sample, a synthetic
        zero-progress window ending at ``now`` is returned — this is what
        makes hanging jobs *visible* (paper §5: livelocked processes keep
        "running" while GFLOP/s drops to zero).
        """
        with self._lock:
            if not self._events:
                return None
            latest = self._events[-1]
            prev = self._last_sample
            if prev is None:
                self._last_sample = latest
                return None
            if latest.ts <= prev.ts:
                t = now if now is not None else time.time()
                if t <= prev.ts:
                    return None
                return prev, StepEvent(t, prev.step, 0, prev.loss,
                                       cum_tokens=prev.cum_tokens)
            self._last_sample = latest
            return prev, latest


# ------------------------------------------------------------------ XLA cost

@dataclass
class StaticStepCost:
    """Per-step figures from the compiled executable (per chip)."""

    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    num_chips: int = 1
    tokens_per_step: int = 0


class XlaCostSource(MetricSource):
    """PMU analog: achieved GFLOP/s, HBM GB/s, AI, MFU.

    The per-step FLOP/byte figures are static properties of the compiled
    step; runtime cost of this source is two clock reads per sample —
    the "negligible overhead" property the paper demands of hpcmd.
    """

    name = "xla_cost"
    kind = "perf"

    def __init__(self, clock: StepClock, hw: HardwareSpec = TPU_V5E) -> None:
        self.clock = clock
        self.hw = hw
        self.cost = StaticStepCost()

    def set_cost(self, cost: StaticStepCost) -> None:
        self.cost = cost

    def collect(self, now: float) -> Optional[Fields]:
        win = self.clock.window(now)
        if win is None:
            return None
        prev, latest = win
        dt = latest.ts - prev.ts
        dstep = latest.step - prev.step
        if dstep <= 0 or dt <= 0:
            # no forward progress in this window — still emit, the hang
            # detector keys off exactly this case
            return {"step": latest.step, "steps_per_s": 0.0,
                    "tokens_per_s": 0.0, "loss": latest.loss,
                    "gflops": 0.0, "gflops_per_chip": 0.0, "hbm_gbs": 0.0,
                    "ici_gbs": 0.0, "mfu": 0.0, "ai": 0.0,
                    "step_time_s": 0.0}
        step_time = dt / dstep
        c = self.cost
        fields = derived.perf_fields(
            c.flops * c.num_chips, c.bytes * c.num_chips,
            c.collective_bytes * c.num_chips, step_time, c.num_chips, self.hw)
        fields.update({
            "step": latest.step,
            "steps_per_s": dstep / dt,
            "tokens_per_s": (
                (latest.cum_tokens - prev.cum_tokens) / dt
                if latest.cum_tokens > prev.cum_tokens
                else dstep * c.tokens_per_step / dt),
            "loss": latest.loss,
        })
        return fields


class CollectiveSource(MetricSource):
    """Network-counter analog: static per-step collective mix from the HLO."""

    name = "collectives"
    kind = "net"
    once = True

    def __init__(self, coll_fields: Dict[str, float]) -> None:
        self._fields = dict(coll_fields)

    def collect(self, now: float) -> Optional[Fields]:
        return dict(self._fields)


# -------------------------------------------------------------------- device

class DeviceSource(MetricSource):
    """nvidia-smi analog: per-device memory occupancy via jax."""

    name = "device"
    kind = "device"

    def __init__(self, devices: Optional[List] = None) -> None:
        self._devices = devices

    def collect(self, now: float) -> Optional[Fields]:
        import jax
        devs = self._devices if self._devices is not None else jax.local_devices()
        in_use, limit, reporting = 0.0, 0.0, 0
        for d in devs:
            try:
                stats = d.memory_stats()
            except Exception:  # noqa: BLE001
                stats = None
            if not stats:
                continue
            reporting += 1
            in_use += float(stats.get("bytes_in_use", 0))
            limit += float(stats.get("bytes_limit", 0))
        fields: Fields = {
            "local_devices": len(devs),
            "devices_reporting": reporting,
            "hbm_bytes_in_use": in_use,
        }
        if limit:
            fields["hbm_bytes_limit"] = limit
            fields["hbm_frac_used"] = in_use / limit
        return fields


# ---------------------------------------------------------------------- proc

class ProcSource(MetricSource):
    """ps / /proc analog: host-side process metrics, stdlib only."""

    name = "proc"
    kind = "proc"

    def __init__(self, pid: Optional[int] = None) -> None:
        self.pid = pid or os.getpid()
        self._page = os.sysconf("SC_PAGE_SIZE")

    def collect(self, now: float) -> Optional[Fields]:
        fields: Fields = {"pid": self.pid}
        try:
            with open(f"/proc/{self.pid}/statm") as f:
                parts = f.read().split()
            fields["rss_bytes"] = int(parts[1]) * self._page
            fields["vsz_bytes"] = int(parts[0]) * self._page
        except OSError:
            pass
        try:
            with open(f"/proc/{self.pid}/stat") as f:
                stat = f.read()
            # field 20 (1-based) = num_threads; fields 14/15 = utime/stime
            after = stat.rsplit(")", 1)[1].split()
            fields["num_threads"] = int(after[17])
            tick = os.sysconf("SC_CLK_TCK")
            fields["cpu_seconds"] = (int(after[11]) + int(after[12])) / tick
        except (OSError, IndexError, ValueError):
            pass
        try:
            with open("/proc/loadavg") as f:
                fields["loadavg_1m"] = float(f.read().split()[0])
        except (OSError, ValueError):
            pass
        return fields


# ------------------------------------------------------------------ pipeline

class PipelineStats:
    """Counters owned by the data pipeline; source reports windowed deltas."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.batches = 0
        self.tokens = 0
        self.wait_s = 0.0

    def on_batch(self, tokens: int, wait_s: float) -> None:
        with self._lock:
            self.batches += 1
            self.tokens += tokens
            self.wait_s += wait_s

    def snapshot(self) -> Tuple[int, int, float]:
        with self._lock:
            return self.batches, self.tokens, self.wait_s


class PipelineSource(MetricSource):
    """I/O analog: data-pipeline throughput and input stalls."""

    name = "pipeline"
    kind = "pipeline"

    def __init__(self, stats: PipelineStats) -> None:
        self.stats = stats
        self._prev: Tuple[float, int, int, float] = (0.0, 0, 0, 0.0)

    def collect(self, now: float) -> Optional[Fields]:
        b, t, w = self.stats.snapshot()
        pt, pb, ptok, pw = self._prev
        self._prev = (now, b, t, w)
        dt = now - pt
        if pt == 0.0 or dt <= 0:
            return {"batches_total": b, "tokens_total": t,
                    "input_wait_s_total": round(w, 6)}
        return {
            "batches_total": b,
            "tokens_total": t,
            "input_wait_s_total": round(w, 6),
            "batches_per_s": (b - pb) / dt,
            "input_tokens_per_s": (t - ptok) / dt,
            "input_stall_frac": max(0.0, min(1.0, (w - pw) / dt)),
        }


# ----------------------------------------------------------------------- env

class EnvSource(MetricSource):
    """One-shot job metadata record (paper: job environment capture)."""

    name = "env"
    kind = "meta"
    once = True

    ENV_WHITELIST = ("SLURM_JOB_ID", "SLURM_NTASKS", "XLA_FLAGS",
                     "JAX_PLATFORMS", "REPRO_ARCH", "REPRO_SHAPE")

    def __init__(self, extra: Optional[Fields] = None) -> None:
        self.extra = dict(extra or {})

    def collect(self, now: float) -> Optional[Fields]:
        fields: Fields = {
            "python": sys.version.split()[0],
            "argv": " ".join(sys.argv[:4])[:200],
        }
        try:
            import jax
            fields["jax_version"] = jax.__version__
            fields["backend"] = jax.default_backend()
            fields["device_count"] = jax.device_count()
        except Exception:  # noqa: BLE001
            pass
        for key in self.ENV_WHITELIST:
            if key in os.environ:
                fields[f"env_{key}"] = os.environ[key][:200]
        fields.update(self.extra)
        return fields
