"""splunklite — an SPL-like pipeline query engine over metric records.

The paper's analysis layer is Splunk: "a powerful query language over
large volumes of temporally ordered log-line data" (§4).  This module is
the self-contained analog used by dashboards, detectors, reports, and by
staff directly (the paper's "custom queries" for specialized views).

Supported pipeline, e.g.::

    search kind=perf job=cobra.42 gflops>10 app=gemma*
      | stats avg(gflops) p90(step_time_s) count by host
      | sort -avg_gflops | head 5

Commands: ``search``/``where``, ``stats``, ``timechart``, ``sort``,
``head``, ``fields``, ``dedup``, ``eval``.
Aggregations: count, dc, sum, avg/mean, min, max, median, p25/p50/p75/p90/
p95/p99, stdev, range, first, last.
"""

from __future__ import annotations

import ast
import fnmatch
import math
import re
import shlex
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Union

from repro.core.aggregator import MetricStore
from repro.core.schema import MetricRecord
from repro.core.sketches import exact_quantile

Row = Dict[str, Any]


class QueryError(ValueError):
    pass


# ----------------------------------------------------------------- search ---
_CMP_RE = re.compile(r"^([A-Za-z_][A-Za-z0-9_.]*)(!=|>=|<=|=|>|<)(.*)$")


def _to_number(s: str) -> Optional[float]:
    try:
        return float(s)
    except ValueError:
        return None


def _match_term(row: Row, term: str) -> bool:
    m = _CMP_RE.match(term)
    if not m:
        # bare word: substring/wildcard match against any string value
        pat = term if any(ch in term for ch in "*?") else f"*{term}*"
        return any(isinstance(v, str) and fnmatch.fnmatch(v, pat)
                   for v in row.values())
    key, op, raw = m.groups()
    val = row.get(key)
    if op in ("=", "!="):
        if val is None:
            return op == "!="
        num = _to_number(raw)
        if num is not None and isinstance(val, (int, float)):
            eq = float(val) == num
        else:
            eq = fnmatch.fnmatch(str(val), raw) if any(
                ch in raw for ch in "*?") else str(val) == raw
        return eq if op == "=" else not eq
    # numeric comparisons
    if val is None or not isinstance(val, (int, float)):
        return False
    num = _to_number(raw)
    if num is None:
        return False
    v = float(val)
    return {"<": v < num, "<=": v <= num,
            ">": v > num, ">=": v >= num}[op]


def _cmd_search(rows: Iterable[Row], args: List[str]) -> List[Row]:
    return [r for r in rows if all(_match_term(r, t) for t in args)]


# ------------------------------------------------------------------ stats ---
_AGG_RE = re.compile(r"^([a-z0-9]+)(?:\(([A-Za-z0-9_.*]*)\))?$")


def _agg_fn(name: str) -> Callable[[List[Any]], Any]:
    def nums(vals):
        return [float(v) for v in vals
                if isinstance(v, (int, float)) and not (
                    isinstance(v, float) and math.isnan(v))]

    if name == "count":
        return lambda vals: len(vals)
    if name == "dc":
        return lambda vals: len(set(map(str, vals)))
    if name == "sum":
        return lambda vals: sum(nums(vals))
    if name in ("avg", "mean"):
        return lambda vals: (sum(nums(vals)) / len(nums(vals))) if nums(vals) else math.nan
    if name == "min":
        return lambda vals: min(nums(vals)) if nums(vals) else math.nan
    if name == "max":
        return lambda vals: max(nums(vals)) if nums(vals) else math.nan
    if name in ("median", "p50"):
        return lambda vals: exact_quantile(nums(vals), 0.5)
    if name.startswith("p") and name[1:].isdigit():
        q = int(name[1:]) / 100.0
        return lambda vals: exact_quantile(nums(vals), q)
    if name == "stdev":
        def _stdev(vals):
            xs = nums(vals)
            if len(xs) < 2:
                return 0.0
            mu = sum(xs) / len(xs)
            return math.sqrt(sum((x - mu) ** 2 for x in xs) / (len(xs) - 1))
        return _stdev
    if name == "range":
        return lambda vals: (max(nums(vals)) - min(nums(vals))) if nums(vals) else math.nan
    if name == "first":
        return lambda vals: vals[0] if vals else None
    if name == "last":
        return lambda vals: vals[-1] if vals else None
    raise QueryError(f"unknown aggregation {name!r}")


def _parse_aggs(tokens: List[str]):
    """Parse ``agg(field) [as alias] ...`` returning [(fn, field, out)]."""
    aggs = []
    i = 0
    while i < len(tokens):
        tok = tokens[i]
        m = _AGG_RE.match(tok)
        if not m:
            raise QueryError(f"bad aggregation token {tok!r}")
        name, fieldname = m.group(1), m.group(2)
        out = f"{name}_{fieldname}" if fieldname else name
        if i + 2 < len(tokens) and tokens[i + 1] == "as":
            out = tokens[i + 2]
            i += 2
        aggs.append((_agg_fn(name), fieldname, out))
        i += 1
    return aggs


def _group_rows(rows: List[Row], by: List[str]):
    groups: Dict[tuple, List[Row]] = {}
    for r in rows:
        key = tuple(str(r.get(b, "")) for b in by)
        groups.setdefault(key, []).append(r)
    return groups


def _cmd_stats(rows: List[Row], args: List[str]) -> List[Row]:
    if "by" in args:
        split = args.index("by")
        agg_tokens, by = args[:split], args[split + 1:]
    else:
        agg_tokens, by = args, []
    aggs = _parse_aggs(agg_tokens)
    out: List[Row] = []
    for key, group in sorted(_group_rows(rows, by).items()):
        row: Row = dict(zip(by, key))
        for fn, fieldname, name in aggs:
            if fieldname:
                vals = [r[fieldname] for r in group if fieldname in r]
            else:
                vals = group
            row[name] = fn(vals)
        out.append(row)
    return out


def _cmd_timechart(rows: List[Row], args: List[str]) -> List[Row]:
    span = 60.0
    rest: List[str] = []
    for tok in args:
        if tok.startswith("span="):
            span = float(tok[5:])
        else:
            rest.append(tok)
    by: List[str] = []
    if "by" in rest:
        split = rest.index("by")
        rest, by = rest[:split], rest[split + 1:]
    aggs = _parse_aggs(rest)
    out: List[Row] = []
    keyed: Dict[tuple, List[Row]] = {}
    for r in rows:
        ts = r.get("ts")
        if not isinstance(ts, (int, float)):
            continue
        bucket = math.floor(float(ts) / span) * span
        key = (bucket,) + tuple(str(r.get(b, "")) for b in by)
        keyed.setdefault(key, []).append(r)
    for key, group in sorted(keyed.items()):
        row: Row = {"_time": key[0]}
        row.update(dict(zip(by, key[1:])))
        for fn, fieldname, name in aggs:
            vals = ([r[fieldname] for r in group if fieldname in r]
                    if fieldname else group)
            row[name] = fn(vals)
        out.append(row)
    return out


# ------------------------------------------------------------------- eval ---
_ALLOWED_NODES = (ast.Expression, ast.BinOp, ast.UnaryOp, ast.Name,
                  ast.Constant, ast.Add, ast.Sub, ast.Mult, ast.Div,
                  ast.Pow, ast.Mod, ast.USub, ast.UAdd, ast.Call,
                  ast.Load, ast.IfExp, ast.Compare, ast.Gt, ast.GtE,
                  ast.Lt, ast.LtE, ast.Eq, ast.NotEq)
_EVAL_FUNCS = {"abs": abs, "min": min, "max": max, "round": round,
               "log": math.log, "log2": math.log2, "log10": math.log10,
               "sqrt": math.sqrt, "exp": math.exp, "floor": math.floor,
               "ceil": math.ceil}


def _safe_eval(expr: str, row: Row) -> Any:
    tree = ast.parse(expr, mode="eval")
    for node in ast.walk(tree):
        if not isinstance(node, _ALLOWED_NODES):
            raise QueryError(f"eval: disallowed syntax {type(node).__name__}")
        if isinstance(node, ast.Call):
            if not (isinstance(node.func, ast.Name)
                    and node.func.id in _EVAL_FUNCS):
                raise QueryError("eval: disallowed function")
    names = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    env = dict(_EVAL_FUNCS)
    for n in names:
        if n in env:
            continue
        v = row.get(n)
        env[n] = float(v) if isinstance(v, (int, float)) else math.nan
    return eval(compile(tree, "<eval>", "eval"), {"__builtins__": {}}, env)


def _cmd_eval(rows: List[Row], args: List[str]) -> List[Row]:
    expr = " ".join(args)
    if "=" not in expr:
        raise QueryError("eval needs name=expr")
    name, rhs = expr.split("=", 1)
    name = name.strip()
    out = []
    for r in rows:
        r = dict(r)
        try:
            r[name] = _safe_eval(rhs, r)
        except QueryError:
            raise
        except Exception:  # noqa: BLE001 — eval on missing fields -> nan
            r[name] = math.nan
        out.append(r)
    return out


# ------------------------------------------------------------------- misc ---
def _cmd_sort(rows: List[Row], args: List[str]) -> List[Row]:
    if not args:
        return rows
    keys = []
    for a in args:
        desc = a.startswith("-")
        keys.append((a.lstrip("+-"), desc))
    out = list(rows)
    for key, desc in reversed(keys):
        out.sort(key=lambda r: (
            (0, float(r[key])) if isinstance(r.get(key), (int, float))
            and not (isinstance(r.get(key), float) and math.isnan(r[key]))
            else (1, 0.0) if key in r else (2, 0.0)), reverse=desc)
    return out


def _cmd_head(rows: List[Row], args: List[str]) -> List[Row]:
    n = int(args[0]) if args else 10
    return rows[:n]


def _cmd_fields(rows: List[Row], args: List[str]) -> List[Row]:
    return [{k: r[k] for k in args if k in r} for r in rows]


def _cmd_dedup(rows: List[Row], args: List[str]) -> List[Row]:
    seen = set()
    out = []
    for r in rows:
        key = tuple(str(r.get(a, "")) for a in args)
        if key not in seen:
            seen.add(key)
            out.append(r)
    return out


_COMMANDS = {
    "search": _cmd_search,
    "where": _cmd_search,
    "stats": _cmd_stats,
    "timechart": _cmd_timechart,
    "sort": _cmd_sort,
    "head": _cmd_head,
    "fields": _cmd_fields,
    "table": _cmd_fields,
    "dedup": _cmd_dedup,
    "eval": _cmd_eval,
}


def _split_pipeline(q: str) -> List[List[str]]:
    stages = []
    for part in q.split("|"):
        part = part.strip()
        if not part:
            continue
        toks = shlex.split(part)
        stages.append(toks)
    return stages


def query(source: Union[MetricStore, Sequence[Row], Sequence[MetricRecord]],
          q: str) -> List[Row]:
    """Run an SPL-like pipeline over a store / record list / row list."""
    if isinstance(source, MetricStore):
        rows: List[Row] = [r.as_dict() for r in source.records]
    else:
        rows = [r.as_dict() if isinstance(r, MetricRecord) else dict(r)
                for r in source]
    stages = _split_pipeline(q)
    if not stages:
        return rows
    for i, toks in enumerate(stages):
        cmd, args = toks[0], toks[1:]
        if i == 0 and cmd not in _COMMANDS:
            cmd, args = "search", toks  # leading implicit search
        if cmd not in _COMMANDS:
            raise QueryError(f"unknown command {cmd!r}")
        rows = _COMMANDS[cmd](rows, args)
    return rows
