"""splunklite — an SPL-like pipeline query engine over metric records.

The paper's analysis layer is Splunk: "a powerful query language over
large volumes of temporally ordered log-line data" (§4).  This module is
the self-contained analog used by dashboards, detectors, reports, and by
staff directly (the paper's "custom queries" for specialized views).

Supported pipeline, e.g.::

    search kind=perf job=cobra.42 gflops>10 app=gemma*
      | stats avg(gflops) p90(step_time_s) count by host
      | sort -avg_gflops | head 5

Commands: ``search``/``where``, ``stats``, ``timechart``, ``sort``,
``head``, ``fields``, ``dedup``, ``eval``.
Aggregations: count, dc, sum, avg/mean, min, max, median, p25/p50/p75/p90/
p95/p99, stdev, range, first, last.

Two executors share the surface syntax:

* the **columnar executor** (default for a :class:`ColumnarMetricStore`)
  compiles ``search``/``where`` predicates to vectorized boolean masks
  with zone-map segment pruning and dictionary-id equality pushdown,
  runs ``stats``/``timechart`` through NumPy group-by kernels, and keeps
  ``eval``/``dedup``/``sort``/``head``/``fields`` on column batches;
* the **row executor** (used for plain row/record lists, or via
  ``engine="rows"``) is the original pure-Python implementation and
  doubles as the parity oracle in tests.
"""

from __future__ import annotations

import ast
import fnmatch
import hashlib
import math
import re
import shlex
import warnings
import zlib
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core.columnar import (ColumnarMetricStore, MISSING, NumColumn,
                                 ObjColumn, Segment, StrColumn, build_column,
                                 columns_from_rows, materialize_rows)
from repro.core.schema import MetricRecord
from repro.core.sketches import exact_quantile

Row = Dict[str, Any]


class QueryError(ValueError):
    pass


# ----------------------------------------------------------------- search ---
_CMP_RE = re.compile(r"^([A-Za-z_][A-Za-z0-9_.]*)(!=|>=|<=|=|>|<)(.*)$")


def _to_number(s: str) -> Optional[float]:
    try:
        return float(s)
    except ValueError:
        return None


def _match_term(row: Row, term: str) -> bool:
    m = _CMP_RE.match(term)
    if not m:
        # bare word: substring/wildcard match against any string value
        pat = term if any(ch in term for ch in "*?") else f"*{term}*"
        return any(isinstance(v, str) and fnmatch.fnmatch(v, pat)
                   for v in row.values())
    key, op, raw = m.groups()
    val = row.get(key)
    if op in ("=", "!="):
        if val is None:
            return op == "!="
        num = _to_number(raw)
        if num is not None and isinstance(val, (int, float)):
            eq = float(val) == num
        else:
            eq = fnmatch.fnmatch(str(val), raw) if any(
                ch in raw for ch in "*?") else str(val) == raw
        return eq if op == "=" else not eq
    # numeric comparisons
    if val is None or not isinstance(val, (int, float)):
        return False
    num = _to_number(raw)
    if num is None:
        return False
    v = float(val)
    return {"<": v < num, "<=": v <= num,
            ">": v > num, ">=": v >= num}[op]


def _cmd_search(rows: Iterable[Row], args: List[str]) -> List[Row]:
    return [r for r in rows if all(_match_term(r, t) for t in args)]


# ------------------------------------------------------------------ stats ---
_AGG_RE = re.compile(r"^([a-z0-9]+)(?:\(([A-Za-z0-9_.*]*)\))?$")

_PCT_RE = re.compile(r"^p(\d+)$")

_KNOWN_AGGS = {"count", "dc", "sum", "avg", "mean", "min", "max", "median",
               "stdev", "range", "first", "last"}


def _agg_fn(name: str) -> Callable[[List[Any]], Any]:
    def nums(vals):
        return [float(v) for v in vals
                if isinstance(v, (int, float)) and not (
                    isinstance(v, float) and math.isnan(v))]

    if name == "count":
        return lambda vals: len(vals)
    if name == "dc":
        return lambda vals: len(set(map(str, vals)))
    if name == "sum":
        return lambda vals: sum(nums(vals))
    if name in ("avg", "mean"):
        return lambda vals: (sum(nums(vals)) / len(nums(vals))) if nums(vals) else math.nan
    if name == "min":
        return lambda vals: min(nums(vals)) if nums(vals) else math.nan
    if name == "max":
        return lambda vals: max(nums(vals)) if nums(vals) else math.nan
    if name in ("median", "p50"):
        return lambda vals: exact_quantile(nums(vals), 0.5)
    if name.startswith("p") and name[1:].isdigit():
        q = int(name[1:]) / 100.0
        return lambda vals: exact_quantile(nums(vals), q)
    if name == "stdev":
        def _stdev(vals):
            xs = nums(vals)
            if len(xs) < 2:
                return 0.0
            mu = sum(xs) / len(xs)
            return math.sqrt(sum((x - mu) ** 2 for x in xs) / (len(xs) - 1))
        return _stdev
    if name == "range":
        return lambda vals: (max(nums(vals)) - min(nums(vals))) if nums(vals) else math.nan
    if name == "first":
        return lambda vals: vals[0] if vals else None
    if name == "last":
        return lambda vals: vals[-1] if vals else None
    raise QueryError(f"unknown aggregation {name!r}")


def _check_agg(name: str) -> None:
    if name in _KNOWN_AGGS:
        return
    if _PCT_RE.match(name):
        return
    raise QueryError(f"unknown aggregation {name!r}")


def _parse_aggs(tokens: List[str]):
    """Parse ``agg(field) [as alias] ...`` returning [(name, field, out)]."""
    aggs = []
    i = 0
    while i < len(tokens):
        tok = tokens[i]
        m = _AGG_RE.match(tok)
        if not m:
            raise QueryError(f"bad aggregation token {tok!r}")
        name, fieldname = m.group(1), m.group(2)
        _check_agg(name)
        out = f"{name}_{fieldname}" if fieldname else name
        if i + 2 < len(tokens) and tokens[i + 1] == "as":
            out = tokens[i + 2]
            i += 2
        aggs.append((name, fieldname, out))
        i += 1
    return aggs


def _stats_split(args: List[str]):
    """stats args -> (agg tokens, by columns)."""
    if "by" in args:
        split = args.index("by")
        return args[:split], args[split + 1:]
    return list(args), []


def _timechart_split(args: List[str]):
    """timechart args -> (span seconds, agg tokens, by columns)."""
    span = 60.0
    rest: List[str] = []
    for tok in args:
        if tok.startswith("span="):
            span = float(tok[5:])
        else:
            rest.append(tok)
    by: List[str] = []
    if "by" in rest:
        split = rest.index("by")
        rest, by = rest[:split], rest[split + 1:]
    return span, rest, by


def _group_rows(rows: List[Row], by: List[str]):
    groups: Dict[tuple, List[Row]] = {}
    for r in rows:
        key = tuple(str(r.get(b, "")) for b in by)
        groups.setdefault(key, []).append(r)
    return groups


def _cmd_stats(rows: List[Row], args: List[str]) -> List[Row]:
    agg_tokens, by = _stats_split(args)
    aggs = [(_agg_fn(name), fieldname, outname)
            for name, fieldname, outname in _parse_aggs(agg_tokens)]
    out: List[Row] = []
    for key, group in sorted(_group_rows(rows, by).items()):
        row: Row = dict(zip(by, key))
        for fn, fieldname, outname in aggs:
            if fieldname:
                vals = [r[fieldname] for r in group if fieldname in r]
            else:
                vals = group
            row[outname] = fn(vals)
        out.append(row)
    return out


def _cmd_timechart(rows: List[Row], args: List[str]) -> List[Row]:
    span, rest, by = _timechart_split(args)
    aggs = [(_agg_fn(name), fieldname, outname)
            for name, fieldname, outname in _parse_aggs(rest)]
    out: List[Row] = []
    keyed: Dict[tuple, List[Row]] = {}
    for r in rows:
        ts = r.get("ts")
        if not isinstance(ts, (int, float)):
            continue
        bucket = math.floor(float(ts) / span) * span
        key = (bucket,) + tuple(str(r.get(b, "")) for b in by)
        keyed.setdefault(key, []).append(r)
    for key, group in sorted(keyed.items()):
        row: Row = {"_time": key[0]}
        row.update(dict(zip(by, key[1:])))
        for fn, fieldname, outname in aggs:
            vals = ([r[fieldname] for r in group if fieldname in r]
                    if fieldname else group)
            row[outname] = fn(vals)
        out.append(row)
    return out


# ------------------------------------------------------------------- eval ---
_ALLOWED_NODES = (ast.Expression, ast.BinOp, ast.UnaryOp, ast.Name,
                  ast.Constant, ast.Add, ast.Sub, ast.Mult, ast.Div,
                  ast.Pow, ast.Mod, ast.USub, ast.UAdd, ast.Call,
                  ast.Load, ast.IfExp, ast.Compare, ast.Gt, ast.GtE,
                  ast.Lt, ast.LtE, ast.Eq, ast.NotEq)
_EVAL_FUNCS = {"abs": abs, "min": min, "max": max, "round": round,
               "log": math.log, "log2": math.log2, "log10": math.log10,
               "sqrt": math.sqrt, "exp": math.exp, "floor": math.floor,
               "ceil": math.ceil}


def _safe_eval(expr: str, row: Row) -> Any:
    tree = ast.parse(expr, mode="eval")
    for node in ast.walk(tree):
        if not isinstance(node, _ALLOWED_NODES):
            raise QueryError(f"eval: disallowed syntax {type(node).__name__}")
        if isinstance(node, ast.Call):
            if not (isinstance(node.func, ast.Name)
                    and node.func.id in _EVAL_FUNCS):
                raise QueryError("eval: disallowed function")
    names = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    env = dict(_EVAL_FUNCS)
    for n in names:
        if n in env:
            continue
        v = row.get(n)
        env[n] = float(v) if isinstance(v, (int, float)) else math.nan
    return eval(compile(tree, "<eval>", "eval"), {"__builtins__": {}}, env)


def _cmd_eval(rows: List[Row], args: List[str]) -> List[Row]:
    expr = " ".join(args)
    if "=" not in expr:
        raise QueryError("eval needs name=expr")
    name, rhs = expr.split("=", 1)
    name = name.strip()
    out = []
    for r in rows:
        r = dict(r)
        try:
            r[name] = _safe_eval(rhs, r)
        except QueryError:
            raise
        except Exception:  # noqa: BLE001 — eval on missing fields -> nan
            r[name] = math.nan
        out.append(r)
    return out


# ------------------------------------------------------------------- misc ---
def _cmd_sort(rows: List[Row], args: List[str]) -> List[Row]:
    if not args:
        return rows
    keys = []
    for a in args:
        desc = a.startswith("-")
        keys.append((a.lstrip("+-"), desc))
    out = list(rows)
    for key, desc in reversed(keys):
        out.sort(key=lambda r: (
            (0, float(r[key])) if isinstance(r.get(key), (int, float))
            and not (isinstance(r.get(key), float) and math.isnan(r[key]))
            else (1, 0.0) if key in r else (2, 0.0)), reverse=desc)
    return out


def _cmd_head(rows: List[Row], args: List[str]) -> List[Row]:
    n = int(args[0]) if args else 10
    return rows[:n]


def _cmd_fields(rows: List[Row], args: List[str]) -> List[Row]:
    return [{k: r[k] for k in args if k in r} for r in rows]


def _cmd_dedup(rows: List[Row], args: List[str]) -> List[Row]:
    seen = set()
    out = []
    for r in rows:
        key = tuple(str(r.get(a, "")) for a in args)
        if key not in seen:
            seen.add(key)
            out.append(r)
    return out


_COMMANDS = {
    "search": _cmd_search,
    "where": _cmd_search,
    "stats": _cmd_stats,
    "timechart": _cmd_timechart,
    "sort": _cmd_sort,
    "head": _cmd_head,
    "fields": _cmd_fields,
    "table": _cmd_fields,
    "dedup": _cmd_dedup,
    "eval": _cmd_eval,
}


def _split_pipeline(q: str) -> List[List[str]]:
    stages = []
    for part in q.split("|"):
        part = part.strip()
        if not part:
            continue
        toks = shlex.split(part)
        stages.append(toks)
    return stages


# ===========================================================================
# Columnar executor
# ===========================================================================

class _Fallback(Exception):
    """Construct the columnar engine does not vectorize; the executor
    materializes the current batch to rows and continues on the row
    engine (results stay identical)."""


class _Batch:
    """A set of equal-length columns mid-pipeline."""

    __slots__ = ("n", "cols")

    def __init__(self, n: int, cols: Dict[str, object]) -> None:
        self.n = n
        self.cols = cols

    def take(self, idx: np.ndarray) -> "_Batch":
        return _Batch(int(len(idx)),
                      {k: c.take(idx) for k, c in self.cols.items()})


def _batch_from_rows(rows: List[Row]) -> _Batch:
    n, cols = columns_from_rows(rows)
    return _Batch(n, cols)


def _rows_from_batch(batch: _Batch) -> List[Row]:
    return materialize_rows(batch.n, batch.cols)


# ------------------------------------------------------------- predicates ---

class _Term:
    __slots__ = ("key", "op", "raw", "num", "bare_pat", "pat")

    def __init__(self, term: str) -> None:
        m = _CMP_RE.match(term)
        if not m:
            self.key = self.op = self.raw = self.num = self.pat = None
            self.bare_pat = (term if any(ch in term for ch in "*?")
                             else f"*{term}*")
            return
        self.bare_pat = None
        self.key, self.op, self.raw = m.groups()
        self.num = _to_number(self.raw)
        self.pat = self.raw if any(ch in self.raw for ch in "*?") else None


def _vocab_match(col: StrColumn, raw: str, pat: Optional[str]) -> np.ndarray:
    """Boolean mask over rows whose (present) string matches raw/pat."""
    if pat is None:
        code = col.index.get(raw)
        if code is None:
            return np.zeros(len(col.codes), bool)
        return col.codes == code
    hit = np.array([fnmatch.fnmatch(v, pat) for v in col.vocab], bool)
    if not hit.any():
        return np.zeros(len(col.codes), bool)
    return hit[np.clip(col.codes, 0, None)] & (col.codes >= 0)


def _num_label(v: float, is_int: bool) -> str:
    if is_int:
        return str(int(v))
    return str(float(v))


def _num_str_match(col: NumColumn, raw: str, pat: Optional[str]
                   ) -> np.ndarray:
    """String-compare a numeric column (rare: e.g. ``step=1*``)."""
    codes, labels = _factorize_num(col)
    if pat is None:
        hit = np.array([lab == raw for lab in labels], bool)
    else:
        hit = np.array([fnmatch.fnmatch(lab, pat) for lab in labels], bool)
    return hit[codes] & col.present


def _term_mask(cs, t: _Term) -> np.ndarray:
    """Evaluate one search term against a column set (Segment/_Batch)."""
    n = cs.n
    if t.bare_pat is not None:
        mask = np.zeros(n, bool)
        for col in cs.cols.values():
            if col.kind == "str":
                mask |= _vocab_match(col, "", t.bare_pat)
            elif col.kind == "obj":
                vv = col.vals
                for i in range(n):
                    v = vv[i]
                    if isinstance(v, str) and fnmatch.fnmatch(v, t.bare_pat):
                        mask[i] = True
        return mask
    col = cs.cols.get(t.key)
    if t.op in ("=", "!="):
        if col is None:
            eq = np.zeros(n, bool)
            present = np.zeros(n, bool)
        elif col.kind == "num":
            present = col.present
            if t.num is not None:
                with np.errstate(invalid="ignore"):
                    eq = present & (col.vals == t.num)
            else:
                eq = _num_str_match(col, t.raw, t.pat)
        elif col.kind == "str":
            present = col.codes >= 0
            if t.num is not None and t.pat is None:
                # raw parses as a number, but values are strings -> the
                # row engine falls through to exact string compare
                eq = _vocab_match(col, t.raw, None)
            else:
                eq = _vocab_match(col, t.raw, t.pat)
        else:  # obj
            present = col.present
            eq = np.zeros(n, bool)
            for i in range(n):
                if not present[i]:
                    continue
                v = col.vals[i]
                if t.num is not None and isinstance(v, (int, float)):
                    eq[i] = float(v) == t.num
                elif t.pat is not None:
                    eq[i] = fnmatch.fnmatch(str(v), t.pat)
                else:
                    eq[i] = str(v) == t.raw
        return eq if t.op == "=" else (~eq | ~present)
    # numeric comparisons
    if col is None or t.num is None:
        return np.zeros(n, bool)
    if col.kind == "num":
        with np.errstate(invalid="ignore"):
            cmp = {"<": col.vals < t.num, "<=": col.vals <= t.num,
                   ">": col.vals > t.num, ">=": col.vals >= t.num}[t.op]
        return col.present & cmp
    if col.kind == "obj":
        mask = np.zeros(n, bool)
        for i in range(n):
            v = col.vals[i]
            if col.present[i] and isinstance(v, (int, float)):
                fv = float(v)
                mask[i] = {"<": fv < t.num, "<=": fv <= t.num,
                           ">": fv > t.num, ">=": fv >= t.num}[t.op]
        return mask
    return np.zeros(n, bool)  # str column never numeric-compares


def _prune_segment(seg: Segment, terms: List[_Term]) -> bool:
    """Zone-map / dictionary pruning: True = no row can match."""
    for t in terms:
        if t.bare_pat is not None:
            continue
        col = seg.cols.get(t.key)
        if col is None:
            if t.op != "!=":
                return True
            continue
        if col.kind == "num" and t.num is not None and t.op != "!=":
            lo, hi = seg.zone(t.key)
            if lo > hi:
                return True
            if t.op == "=" and (t.num < lo or t.num > hi):
                return True
            if t.op == ">" and not hi > t.num:
                return True
            if t.op == ">=" and not hi >= t.num:
                return True
            if t.op == "<" and not lo < t.num:
                return True
            if t.op == "<=" and not lo <= t.num:
                return True
        elif col.kind == "str" and t.op == "=" and t.pat is None:
            if t.raw not in col.index:
                return True
    return False


def _merge_parts(parts: List, cols: Optional[frozenset] = None) -> _Batch:
    """Concatenate (segment, row-idx) gathers into one batch, merging
    string dictionaries and unioning columns across segments.  ``cols``
    (from :func:`referenced_columns`) restricts the gather to columns
    the rest of the pipeline actually touches (projection pushdown)."""
    total = int(sum(len(idx) for _, idx in parts))
    names: Dict[str, None] = {}
    for seg, _ in parts:
        for k in seg.cols:
            if k not in names and (cols is None or k in cols):
                names[k] = None
    cols: Dict[str, object] = {}
    for name in names:
        kinds = {seg.cols[name].kind for seg, _ in parts if name in seg.cols}
        if kinds == {"num"}:
            vals = np.full(total, np.nan)
            present = np.zeros(total, bool)
            is_int = np.zeros(total, bool)
            pos = 0
            for seg, idx in parts:
                m = len(idx)
                col = seg.cols.get(name)
                if col is not None:
                    vals[pos:pos + m] = col.vals[idx]
                    present[pos:pos + m] = col.present[idx]
                    is_int[pos:pos + m] = col.is_int[idx]
                pos += m
            cols[name] = NumColumn(vals, present, is_int)
        elif kinds == {"str"}:
            index: Dict[str, int] = {}
            codes = np.full(total, -1, np.int32)
            pos = 0
            for seg, idx in parts:
                m = len(idx)
                col = seg.cols.get(name)
                if col is not None:
                    remap = np.array(
                        [index.setdefault(v, len(index)) for v in col.vocab],
                        np.int32) if len(col.vocab) else np.empty(0, np.int32)
                    cc = col.codes[idx]
                    codes[pos:pos + m] = np.where(
                        cc >= 0, remap[np.clip(cc, 0, None)], -1)
                pos += m
            cols[name] = StrColumn(codes, np.array(list(index), dtype=object),
                                   index)
        else:
            vals = np.empty(total, dtype=object)
            vals[:] = MISSING
            present = np.zeros(total, bool)
            pos = 0
            for seg, idx in parts:
                m = len(idx)
                col = seg.cols.get(name)
                if col is not None:
                    vals[pos:pos + m] = col.materialize()[idx]
                    present[pos:pos + m] = col.present_mask()[idx]
                pos += m
            vals[~present] = MISSING
            cols[name] = ObjColumn(vals, present)
    return _Batch(total, cols)


def _batch_from_store(store: ColumnarMetricStore, terms: List[_Term],
                      cols: Optional[frozenset] = None) -> _Batch:
    parts = _store_parts(store, terms)
    if not parts:
        return _Batch(0, {})
    return _merge_parts(parts, cols)


def _segment_match_idx(seg: Segment,
                       terms: List[_Term]) -> Optional[np.ndarray]:
    """Matching-row indices for one segment (zone-map pruning plus
    vectorized predicate masks); ``None`` when nothing matches."""
    if terms and _prune_segment(seg, terms):
        return None
    if not terms:
        return np.arange(seg.n)
    mask = np.ones(seg.n, bool)
    for t in terms:
        mask &= _term_mask(seg, t)
        if not mask.any():
            return None
    return np.nonzero(mask)[0]


def _store_parts(store: ColumnarMetricStore,
                 terms: List[_Term]) -> List[tuple]:
    """(segment, matching-row-idx) pairs after zone-map pruning and
    vectorized predicate evaluation — the shared scan for both the
    local executor and the sharded gather path."""
    parts = []
    for seg in store.segments():
        idx = _segment_match_idx(seg, terms)
        if idx is not None:
            parts.append((seg, idx))
    return parts


# ------------------------------------------------------------ factorizing ---

def _factorize_num(col: NumColumn):
    """Codes + str labels for a numeric column; missing rows get "".

    Values that were ints label as ``str(int(v))`` and floats as
    ``str(float(v))`` to mirror the row engine's ``str(value)`` keys.
    """
    u, inv = np.unique(col.vals, return_inverse=True)
    raw = np.where(col.present, inv * 2 + col.is_int, -1)
    u2, codes = np.unique(raw, return_inverse=True)
    labels = []
    for token in u2.tolist():
        if token < 0:
            labels.append("")
        else:
            labels.append(_num_label(u[token >> 1], bool(token & 1)))
    return codes.astype(np.int64), labels


def _factorize(col, n: int):
    """(codes, labels) for group-by / dedup keys; missing == ""."""
    if col is None:
        return np.zeros(n, np.int64), [""]
    if col.kind == "num":
        return _factorize_num(col)
    if col.kind == "str":
        codes = col.codes.astype(np.int64)
        labels = list(col.vocab)
        if (codes < 0).any():
            mcode = col.index.get("")
            if mcode is None:
                mcode = len(labels)
                labels = labels + [""]
            codes = np.where(codes >= 0, codes, mcode)
        return codes, labels
    # obj
    index: Dict[str, int] = {}
    codes = np.empty(n, np.int64)
    for i in range(n):
        label = str(col.vals[i]) if col.present[i] else ""
        codes[i] = index.setdefault(label, len(index))
    return codes, list(index)


def _combine_codes(code_arrays: List[np.ndarray],
                   sizes: List[int]) -> np.ndarray:
    combined = code_arrays[0].astype(np.int64)
    for codes, size in zip(code_arrays[1:], sizes[1:]):
        combined = combined * size + codes
    return combined


# -------------------------------------------------------------- group/agg ---

class _Grouping:
    __slots__ = ("gid", "keys", "G", "_order", "_bounds")

    def __init__(self, gid: np.ndarray, keys: List[tuple]) -> None:
        self.gid = gid
        self.keys = keys
        self.G = len(keys)
        # row-order structures are lazy: the vectorized partial kernels
        # never need them, so shards skip the argsort entirely
        self._order = None
        self._bounds = None

    @property
    def order(self) -> np.ndarray:
        if self._order is None:
            self._order = np.argsort(self.gid, kind="stable")
        return self._order

    @property
    def bounds(self) -> np.ndarray:
        if self._bounds is None:
            go = self.gid[self.order]
            self._bounds = np.searchsorted(go, np.arange(self.G + 1))
        return self._bounds


def _decompose_key(token: int, sizes: List[int]) -> List[int]:
    """Mixed-radix decode of one combined group code (see
    :func:`_combine_codes`) back into per-column label indices."""
    parts: List[int] = []
    for size in reversed(sizes[1:]):
        parts.append(token % size)
        token //= size
    parts.append(token)
    parts.reverse()
    return parts


def _group_str_fast(batch: _Batch, by: List[str]) -> Optional[_Grouping]:
    """Dictionary-aware group-by for all-string key columns.

    ``stats ... by a, b`` over dictionary-encoded columns never needs a
    sort over the rows: the combined mixed-radix dictionary code is
    bincounted to find the used key combinations, and a dense
    code→rank lookup labels every row — O(rows + key-space) instead of
    the general path's O(rows·log rows) ``np.unique``.  Missing rows
    group under ``""`` exactly like the row engine (``_factorize``
    appends the label).  Returns ``None`` when a key column is not
    dictionary-encoded or the key space is too large for a dense
    bincount (the general path then takes over)."""
    cols = [batch.cols.get(b) for b in by]
    if not all(c is not None and c.kind == "str" for c in cols):
        return None
    code_arrays: List[np.ndarray] = []
    labels_list: List[List] = []
    sizes: List[int] = []
    space = 1
    for col in cols:
        codes, labels = _factorize(col, batch.n)
        code_arrays.append(codes)
        labels_list.append(labels)
        sizes.append(len(labels))
        space *= len(labels)
    if space > max(4 * batch.n, 1024):
        return None  # sparse key space: dense bincount would dominate
    combined = _combine_codes(code_arrays, sizes)
    counts = np.bincount(combined, minlength=space)
    used = np.nonzero(counts)[0]
    keys = []
    for token in used.tolist():
        parts = _decompose_key(token, sizes)
        keys.append(tuple(labels_list[j][p] for j, p in enumerate(parts)))
    order = sorted(range(len(keys)), key=keys.__getitem__)
    lookup = np.empty(space, np.int64)
    for rank, j in enumerate(order):
        lookup[used[j]] = rank
    return _Grouping(lookup[combined], [keys[j] for j in order])


def _group(batch: _Batch, by: List[str],
           extra: Optional[tuple] = None) -> _Grouping:
    """Group rows by the ``by`` columns (plus an optional pre-computed
    (codes, keyvals) leading key, used for timechart buckets).  Groups
    come out sorted by their key tuples, matching the row engine."""
    if extra is None and by and batch.n:
        grouping = _group_str_fast(batch, by)
        if grouping is not None:
            return grouping
    code_arrays: List[np.ndarray] = []
    labels_list: List[List] = []
    if extra is not None:
        code_arrays.append(extra[0])
        labels_list.append(extra[1])
    for b in by:
        codes, labels = _factorize(batch.cols.get(b), batch.n)
        code_arrays.append(codes)
        labels_list.append(labels)
    if batch.n == 0:
        return _Grouping(np.zeros(0, np.int64), [])
    if not code_arrays:
        return _Grouping(np.zeros(batch.n, np.int64), [()])
    sizes = [len(lb) for lb in labels_list]
    combined = _combine_codes(code_arrays, sizes)
    uniq, inv = np.unique(combined, return_inverse=True)
    # decompose each unique combined code back into per-column labels
    keys = []
    for token in uniq.tolist():
        parts = _decompose_key(token, sizes)
        keys.append(tuple(labels_list[j][p] for j, p in enumerate(parts)))
    order = sorted(range(len(keys)), key=keys.__getitem__)
    perm = np.empty(len(keys), np.int64)
    perm[np.array(order, np.int64)] = np.arange(len(keys))
    return _Grouping(perm[inv], [keys[i] for i in order])


def _quantile(xs: np.ndarray, q: float) -> float:
    if xs.size == 0:
        return math.nan
    if xs.size <= 4:  # tiny groups: exact oracle path
        return exact_quantile(xs.tolist(), q)
    return float(np.quantile(xs, q))


def _field_masks(batch: _Batch, fname: str):
    """(column, present-mask, numeric-mask, float values) for one
    aggregated field, regardless of column kind."""
    col = batch.cols.get(fname)
    if col is None:
        present = np.zeros(batch.n, bool)
        numeric = present
        vals = np.full(batch.n, np.nan)
    elif col.kind == "num":
        present = col.present
        numeric = present & ~np.isnan(col.vals)
        vals = col.vals
    elif col.kind == "str":
        present = col.codes >= 0
        numeric = np.zeros(batch.n, bool)
        vals = np.full(batch.n, np.nan)
    else:
        present = col.present
        vals = np.full(batch.n, np.nan)
        numeric = np.zeros(batch.n, bool)
        for i in range(batch.n):
            v = col.vals[i]
            if present[i] and isinstance(v, (int, float)) and not (
                    isinstance(v, float) and math.isnan(v)):
                numeric[i] = True
                vals[i] = float(v)
    return col, present, numeric, vals


def _field_group_data(batch: _Batch, grouping: _Grouping, fname: str):
    """(column, present-mask, numeric-mask, float values, per-group
    numeric slices) for one aggregated field — the fused single-store
    kernels' view of a field."""
    G = grouping.G
    gid, order = grouping.gid, grouping.order
    col, present, numeric, vals = _field_masks(batch, fname)
    # per-group numeric slices (ordered by gid, original order kept)
    num_o = numeric[order]
    vals_o = vals[order][num_o]
    go = gid[order][num_o]
    cuts = np.searchsorted(go, np.arange(1, G))
    slices = np.split(vals_o, cuts)
    return (col, present, numeric, vals, slices)


def _aggregate(batch: _Batch, grouping: _Grouping, aggs) -> List[Dict]:
    """NumPy group-by kernels for every supported aggregation.

    This is the fused single-store fast path; it must stay result-
    identical to ``finalize ∘ merge ∘ partial`` over the same rows (the
    sharded algebra below) — the shard-parity suite runs both over the
    same workloads and asserts equality.
    """
    G = grouping.G
    gid, order = grouping.gid, grouping.order
    out: List[Dict] = [dict() for _ in range(G)]
    field_cache: Dict[str, tuple] = {}

    def field_data(fname: str):
        cached = field_cache.get(fname)
        if cached is None:
            cached = _field_group_data(batch, grouping, fname)
            field_cache[fname] = cached
        return cached

    for name, fname, outname in aggs:
        if not fname:
            if name == "count":
                cnt = np.bincount(gid, minlength=G)
                for g in range(G):
                    out[g][outname] = int(cnt[g])
                continue
            raise _Fallback  # field-less first/dc/... aggregate row dicts
        col, present, numeric, _vals, slices = field_data(fname)
        if name == "count":
            cnt = np.bincount(gid[present], minlength=G)
            for g in range(G):
                out[g][outname] = int(cnt[g])
        elif name == "sum":
            for g in range(G):
                xs = slices[g]
                # row engine: sum([]) is int 0; non-empty sums are float
                out[g][outname] = float(xs.sum()) if xs.size else 0
        elif name in ("avg", "mean"):
            for g in range(G):
                xs = slices[g]
                out[g][outname] = float(xs.mean()) if xs.size else math.nan
        elif name == "min":
            for g in range(G):
                xs = slices[g]
                out[g][outname] = float(xs.min()) if xs.size else math.nan
        elif name == "max":
            for g in range(G):
                xs = slices[g]
                out[g][outname] = float(xs.max()) if xs.size else math.nan
        elif name == "range":
            for g in range(G):
                xs = slices[g]
                out[g][outname] = (float(xs.max() - xs.min()) if xs.size
                                   else math.nan)
        elif name == "stdev":
            for g in range(G):
                xs = slices[g]
                out[g][outname] = (float(xs.std(ddof=1)) if xs.size > 1
                                   else 0.0)
        elif name in ("median",) or _PCT_RE.match(name):
            q = 0.5 if name == "median" else int(name[1:]) / 100.0
            for g in range(G):
                out[g][outname] = _quantile(slices[g], q)
        elif name == "dc":
            codes, _labels = _factorize(col, batch.n)
            pc = codes[present]
            pg = gid[present]
            if pg.size:
                pair = np.unique(pg * (codes.max() + 1) + pc)
                cnt = np.bincount(pair // (codes.max() + 1), minlength=G)
            else:
                cnt = np.zeros(G, np.int64)
            for g in range(G):
                out[g][outname] = int(cnt[g])
        elif name in ("first", "last"):
            po = present[order]
            for g in range(G):
                lo, hi = grouping.bounds[g], grouping.bounds[g + 1]
                seg_idx = order[lo:hi][po[lo:hi]]
                if seg_idx.size == 0:
                    out[g][outname] = None
                else:
                    i = int(seg_idx[0] if name == "first" else seg_idx[-1])
                    out[g][outname] = col.value_at(i)
        else:  # pragma: no cover - _check_agg guards this
            raise QueryError(f"unknown aggregation {name!r}")
    return out


# ------------------------------------------------------- columnar commands --

def _col_search(batch: _Batch, args: List[str]) -> _Batch:
    terms = [_Term(t) for t in args]
    mask = np.ones(batch.n, bool)
    for t in terms:
        mask &= _term_mask(batch, t)
    return batch.take(np.nonzero(mask)[0])


def _col_stats(batch: _Batch, args: List[str]) -> _Batch:
    agg_tokens, by = _stats_split(args)
    aggs = _parse_aggs(agg_tokens)
    grouping = _group(batch, by)
    agg_rows = _aggregate(batch, grouping, aggs)
    rows: List[Row] = []
    for key, vals in zip(grouping.keys, agg_rows):
        row: Row = dict(zip(by, key))
        row.update(vals)
        rows.append(row)
    return _batch_from_rows(rows)


def _col_timechart(batch: _Batch, args: List[str]) -> _Batch:
    span, rest, by = _timechart_split(args)
    aggs = _parse_aggs(rest)
    ts_col = batch.cols.get("ts")
    if ts_col is None or ts_col.kind != "num":
        raise _Fallback
    valid = ts_col.present & ~np.isnan(ts_col.vals)
    sub = batch.take(np.nonzero(valid)[0])
    if sub.n == 0:
        return _batch_from_rows([])
    buckets = np.floor(sub.cols["ts"].vals / span) * span
    u, inv = np.unique(buckets, return_inverse=True)
    grouping = _group(sub, by, extra=(inv.astype(np.int64), u.tolist()))
    agg_rows = _aggregate(sub, grouping, aggs)
    rows: List[Row] = []
    for key, vals in zip(grouping.keys, agg_rows):
        row: Row = {"_time": key[0]}
        row.update(dict(zip(by, key[1:])))
        row.update(vals)
        rows.append(row)
    return _batch_from_rows(rows)


def _eval_env_array(batch: _Batch, name: str) -> np.ndarray:
    col = batch.cols.get(name)
    if col is None:
        return np.full(batch.n, np.nan)
    if col.kind == "str":
        return np.full(batch.n, np.nan)  # row engine: non-numeric -> nan
    if col.kind == "obj":
        raise _Fallback  # mixed column: numeric rows need row semantics
    return np.where(col.present, col.vals, np.nan)


def _vec_eval(node: ast.AST, batch: _Batch):
    """Vectorized safe-eval mirroring the row engine's per-row
    semantics (exceptions there become NaN here)."""
    nan = math.nan
    if isinstance(node, ast.Expression):
        return _vec_eval(node.body, batch)
    if isinstance(node, ast.Constant):
        if isinstance(node.value, bool):
            return float(node.value)
        if isinstance(node.value, (int, float)):
            return float(node.value)
        raise _Fallback
    if isinstance(node, ast.Name):
        if node.id in _EVAL_FUNCS:
            raise _Fallback
        return _eval_env_array(batch, node.id)
    if isinstance(node, ast.UnaryOp):
        v = _vec_eval(node.operand, batch)
        return -v if isinstance(node.op, ast.USub) else +v
    if isinstance(node, ast.BinOp):
        a = _vec_eval(node.left, batch)
        b = _vec_eval(node.right, batch)
        with np.errstate(all="ignore"):
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.Div):
                r = np.divide(a, b)
                return np.where(np.asarray(b) == 0, nan, r)
            if isinstance(node.op, ast.Mod):
                r = np.mod(a, b)
                return np.where(np.asarray(b) == 0, nan, r)
            if isinstance(node.op, ast.Pow):
                r = np.power(a, b)
                bad = (np.isinf(r) & np.isfinite(np.asarray(a))
                       & np.isfinite(np.asarray(b)))
                return np.where(bad, nan, r)
        raise _Fallback
    if isinstance(node, ast.Compare):
        cur = _vec_eval(node.left, batch)
        acc = None
        with np.errstate(invalid="ignore"):
            for op, comp in zip(node.ops, node.comparators):
                nxt = _vec_eval(comp, batch)
                c = {ast.Gt: lambda x, y: x > y,
                     ast.GtE: lambda x, y: x >= y,
                     ast.Lt: lambda x, y: x < y,
                     ast.LtE: lambda x, y: x <= y,
                     ast.Eq: lambda x, y: x == y,
                     ast.NotEq: lambda x, y: x != y}[type(op)](cur, nxt)
                acc = c if acc is None else (acc & c)
                cur = nxt
        return np.asarray(acc, dtype=np.float64)
    if isinstance(node, ast.IfExp):
        cond = np.asarray(_vec_eval(node.test, batch))
        a = _vec_eval(node.body, batch)
        b = _vec_eval(node.orelse, batch)
        return np.where(cond.astype(bool), a, b)
    if isinstance(node, ast.Call):
        fname = node.func.id  # validated earlier
        args = [_vec_eval(a, batch) for a in node.args]
        with np.errstate(all="ignore"), warnings.catch_warnings():
            warnings.simplefilter("ignore")
            if fname == "abs" and len(args) == 1:
                return np.abs(args[0])
            if fname in ("min", "max"):
                if len(args) < 2:  # row engine: TypeError -> nan
                    return np.full(batch.n, nan)
                # mirror python's builtin exactly (NaN comparisons are
                # False, so NaN operands only win in the first position)
                acc = np.asarray(args[0], dtype=np.float64)
                for a in args[1:]:
                    a = np.asarray(a, dtype=np.float64)
                    better = (a < acc) if fname == "min" else (a > acc)
                    acc = np.where(better, a, acc)
                return acc
            if fname == "round" and len(args) == 1:
                return np.round(args[0])
            if fname in ("log", "log2", "log10") and len(args) == 1:
                a = np.asarray(args[0], dtype=np.float64)
                fn = {"log": np.log, "log2": np.log2,
                      "log10": np.log10}[fname]
                return np.where(a > 0, fn(np.where(a > 0, a, 1.0)), nan)
            if fname == "sqrt" and len(args) == 1:
                return np.sqrt(args[0])
            if fname == "exp" and len(args) == 1:
                a = np.asarray(args[0], dtype=np.float64)
                r = np.exp(a)
                return np.where(np.isinf(r) & np.isfinite(a), nan, r)
            if fname in ("floor", "ceil") and len(args) == 1:
                return (np.floor if fname == "floor" else np.ceil)(args[0])
        raise _Fallback
    raise _Fallback


_INT_FUNCS = ("floor", "ceil", "round")


def _nonfloat_leaks(node: ast.AST, is_root: bool = True) -> bool:
    """True when the row engine could produce a non-float result (bool
    from compares, int from floor/ceil/round) somewhere the vectorized
    f64 pipeline cannot reproduce it.  Root-level int funcs are handled
    specially by the caller; an IfExp's *test* only feeds truthiness,
    so compares there never leak into the value."""
    if isinstance(node, ast.Compare):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in _INT_FUNCS and not is_root:
        return True
    if isinstance(node, ast.IfExp):
        return (_nonfloat_leaks(node.body, False)
                or _nonfloat_leaks(node.orelse, False))
    return any(_nonfloat_leaks(c, False)
               for c in ast.iter_child_nodes(node))


def _col_eval(batch: _Batch, args: List[str]) -> _Batch:
    expr = " ".join(args)
    if "=" not in expr:
        raise QueryError("eval needs name=expr")
    name, rhs = expr.split("=", 1)
    name = name.strip()
    try:
        tree = ast.parse(rhs, mode="eval")
    except SyntaxError:  # row engine: per-row exception -> nan
        vals = np.full(batch.n, np.nan)
        cols = dict(batch.cols)
        cols[name] = NumColumn(vals, np.ones(batch.n, bool),
                               np.zeros(batch.n, bool))
        return _Batch(batch.n, cols)
    for node in ast.walk(tree):
        if not isinstance(node, _ALLOWED_NODES):
            raise QueryError(f"eval: disallowed syntax {type(node).__name__}")
        if isinstance(node, ast.Call):
            if not (isinstance(node.func, ast.Name)
                    and node.func.id in _EVAL_FUNCS):
                raise QueryError("eval: disallowed function")
    # expressions whose row-engine result is not a plain float (bool
    # compares, nested int funcs, pure-constant int arithmetic) run on
    # the row engine so values and str() group keys stay identical
    root = tree.body
    root_int_fn = (isinstance(root, ast.Call)
                   and isinstance(root.func, ast.Name)
                   and root.func.id in _INT_FUNCS and len(root.args) == 1)
    if _nonfloat_leaks(root):
        raise _Fallback
    if not any(isinstance(n, ast.Name) and n.id not in _EVAL_FUNCS
               for n in ast.walk(tree)):
        raise _Fallback  # constant expression: row engine keeps int-ness
    result = _vec_eval(tree, batch)
    result = np.asarray(result, dtype=np.float64)
    if result.ndim == 0:
        result = np.full(batch.n, float(result))
    is_int = np.zeros(batch.n, bool)
    if root_int_fn:
        # math.floor/ceil/round return ints (inf/nan raise -> nan)
        result = np.where(np.isinf(result), np.nan, result)
        is_int = ~np.isnan(result)
    cols = dict(batch.cols)
    cols[name] = NumColumn(result, np.ones(batch.n, bool), is_int)
    return _Batch(batch.n, cols)


def _sort_key_arrays(batch: _Batch, key: str):
    """(tier, value) arrays mirroring the row engine's 3-tier sort key:
    0 = numeric non-NaN, 1 = present but non-numeric/NaN, 2 = missing."""
    n = batch.n
    col = batch.cols.get(key)
    if col is None:
        return np.full(n, 2.0), np.zeros(n)
    if col.kind == "num":
        isn = np.isnan(col.vals)
        tier = np.where(col.present & ~isn, 0.0,
                        np.where(col.present, 1.0, 2.0))
        val = np.where(tier == 0.0, np.where(isn, 0.0, col.vals), 0.0)
        return tier, val
    if col.kind == "str":
        present = col.codes >= 0
        return np.where(present, 1.0, 2.0), np.zeros(n)
    tier = np.empty(n)
    val = np.zeros(n)
    for i in range(n):
        v = col.vals[i]
        if not col.present[i]:
            tier[i] = 2.0
        elif isinstance(v, (int, float)) and not (
                isinstance(v, float) and math.isnan(v)):
            tier[i] = 0.0
            val[i] = float(v)
        else:
            tier[i] = 1.0
    return tier, val


def _col_sort(batch: _Batch, args: List[str]) -> _Batch:
    if not args:
        return batch
    lex: List[np.ndarray] = []
    for a in reversed(args):  # least-significant key first for lexsort
        desc = a.startswith("-")
        tier, val = _sort_key_arrays(batch, a.lstrip("+-"))
        if desc:
            tier, val = -tier, -val
        lex.append(val)
        lex.append(tier)
    order = np.lexsort(tuple(lex))
    return batch.take(order)


def _col_head(batch: _Batch, args: List[str]) -> _Batch:
    n = int(args[0]) if args else 10
    stop = min(n, batch.n) if n >= 0 else max(batch.n + n, 0)
    return batch.take(np.arange(stop))


def _col_fields(batch: _Batch, args: List[str]) -> _Batch:
    return _Batch(batch.n, {k: batch.cols[k] for k in args
                            if k in batch.cols})


def _col_dedup(batch: _Batch, args: List[str]) -> _Batch:
    if batch.n == 0:
        return batch
    code_arrays = []
    sizes = []
    for a in args:
        codes, labels = _factorize(batch.cols.get(a), batch.n)
        code_arrays.append(codes)
        sizes.append(len(labels))
    if not code_arrays:
        return batch.take(np.arange(min(1, batch.n)))
    combined = _combine_codes(code_arrays, sizes)
    _, first_idx = np.unique(combined, return_index=True)
    return batch.take(np.sort(first_idx))


_COL_COMMANDS = {
    "search": _col_search,
    "where": _col_search,
    "stats": _col_stats,
    "timechart": _col_timechart,
    "sort": _col_sort,
    "head": _col_head,
    "fields": _col_fields,
    "table": _col_fields,
    "dedup": _col_dedup,
    "eval": _col_eval,
}


# ---------------------------------------------------- projection pushdown --

def referenced_columns(stages: List[List[str]]) -> Optional[frozenset]:
    """Columns the pipeline can possibly read from its input rows.

    Backward pass over the stages; ``None`` means "any column" (no
    restricting stage, a bare search term that scans every string
    column, or a whole-row aggregate).  Used to gather only referenced
    columns from segments (projection pushdown) — both by the local
    columnar executor and by the sharded exact-gather path.
    """
    need: Optional[set] = None
    for toks in reversed(list(stages)):
        if not toks:
            continue
        cmd, args = toks[0], toks[1:]
        if cmd in ("fields", "table"):
            need = set(args)
        elif cmd in ("stats", "timechart"):
            if cmd == "timechart":
                try:
                    _span, agg_tokens, by = _timechart_split(args)
                except ValueError:
                    return None
            else:
                agg_tokens, by = _stats_split(args)
            try:
                aggs = _parse_aggs(agg_tokens)
            except QueryError:
                return None  # executors raise the real error
            need = set(by)
            if cmd == "timechart":
                need.add("ts")
            for name, fieldname, _out in aggs:
                if fieldname:
                    need.add(fieldname)
                elif name != "count":
                    return None  # whole-row aggregate (first/dc/... )
        elif cmd in ("search", "where"):
            if need is None:
                continue
            for t in args:
                m = _CMP_RE.match(t)
                if not m:
                    return None  # bare term scans every string column
                need.add(m.group(1))
        elif cmd == "sort":
            if need is not None:
                need.update(a.lstrip("+-") for a in args)
        elif cmd == "dedup":
            if need is not None:
                need.update(args)
        elif cmd == "head":
            pass
        elif cmd == "eval":
            expr = " ".join(args)
            if "=" not in expr:
                return None  # executors raise
            name, rhs = expr.split("=", 1)
            if need is not None:
                need.discard(name.strip())
                try:
                    tree = ast.parse(rhs, mode="eval")
                except SyntaxError:
                    continue  # all-NaN output column: no inputs read
                need.update(n.id for n in ast.walk(tree)
                            if isinstance(n, ast.Name)
                            and n.id not in _EVAL_FUNCS)
        else:
            return None  # unknown command: executors raise
    return None if need is None else frozenset(need)


def _leading_terms(stages: List[List[str]]):
    """Normalize a leading implicit search and consume every leading
    ``search``/``where`` stage into predicate terms.  Returns
    (terms, remaining stages)."""
    stages = list(stages)
    if stages and stages[0] and stages[0][0] not in _COMMANDS:
        stages = [["search"] + list(stages[0])] + stages[1:]
    terms: List[_Term] = []
    i = 0
    while i < len(stages) and stages[i] and stages[i][0] in ("search",
                                                             "where"):
        terms.extend(_Term(t) for t in stages[i][1:])
        i += 1
    return terms, stages[i:]


def _columnar_query(store: ColumnarMetricStore,
                    stages: List[List[str]]) -> List[Row]:
    # plan: push the leading search's predicates down to the segment
    # scan, and gather only the columns the pipeline references
    terms, rest = _leading_terms(stages)
    batch = _batch_from_store(store, terms, referenced_columns(rest))
    rows: Optional[List[Row]] = None
    for toks in rest:
        cmd, args = toks[0], toks[1:]
        if cmd not in _COMMANDS:
            raise QueryError(f"unknown command {cmd!r}")
        if rows is None:
            try:
                batch = _COL_COMMANDS[cmd](batch, args)
                continue
            except _Fallback:
                rows = _rows_from_batch(batch)
        rows = _COMMANDS[cmd](rows, args)
    return rows if rows is not None else _rows_from_batch(batch)


# ===========================================================================
# Sharded scatter/gather: the mergeable aggregation algebra
# ===========================================================================
#
# Every distributable aggregation is split into a partial/merge/finalize
# triple so N shards can each reduce their rows to a small partial state
# and a gather node can combine the states without seeing any row:
#
#   agg          partial state                merge            finalize
#   -----------  ---------------------------  ---------------  ----------
#   count        n                            +                n
#   sum, avg     (n, sum)                     elementwise +    sum / n
#   min/max/rng  (n, min, max)                min / max        min, max-min
#   stdev        (n, mean, M2)                Chan et al.      sqrt(M2/(n-1))
#   p50/p90/...  (P2Summary, ...)             concatenate      CDF-average
#   dc           set of labels                set union        len(set)
#
# ``dc`` is the canonical non-mergeable-by-count aggregate: summing
# per-shard distinct counts over-counts any value seen on two shards, so
# its partial is the exact label set (union-merge).  ``first``/``last``
# depend on global row order and are not distributable at all — plans
# containing them compile to None and callers fall back to an exact
# row gather.  The fused kernels in ``_aggregate`` are an optimization
# of ``finalize ∘ partial`` for the single-store case; the shard-parity
# suite keeps the two paths result-identical.

_ROW_LOCAL_CMDS = ("search", "where", "eval", "fields", "table")


class ScatterPlan:
    """Compiled scatter/gather plan for one ``stats``/``timechart``
    pipeline: predicate terms + row-local prefix stages that every shard
    runs, the aggregation to compute partials for, and the tail stages
    the gather node runs on the merged rows.

    ``fingerprint`` canonically identifies the *partial-producing* half
    of the plan (terms, prefix, aggregation, group keys, span, gathered
    columns — everything **except** the tail, which only runs on merged
    rows).  It keys the per-segment partial-aggregate caches, so two
    queries differing only in their tail (``... | sort``, ``... |
    where``) share cached partials.  See docs/incremental.md for the
    format.

    :meth:`state` / :meth:`from_state` round-trip the plan through a
    JSON-safe dict — the wire form shipped to remote shard workers
    (``repro.core.remote``).  The fingerprint is *recomputed* from the
    same canonical tuple on reconstruction, so worker-side partial
    caches key identically to the coordinator's.

    ``tolerance`` (seconds, default ``None``) opts the plan into
    *approximate* rollup-tier answers: time-range bounds within
    ``tolerance`` of a rollup bucket boundary are snapped to it
    (docs/storage.md).  ``None`` means rollups substitute only when the
    result is exactly equivalent to the raw scan.  A non-``None``
    tolerance joins the fingerprint canon (snapping changes results, so
    tolerant and exact runs must never share cached partials); ``None``
    is omitted so pre-existing fingerprints are unchanged."""

    __slots__ = ("terms", "prefix", "cols", "cmd", "aggs", "by", "span",
                 "tail", "term_tokens", "tolerance", "fingerprint")

    STATE_VERSION = 1

    def __init__(self, terms, prefix, cols, cmd, aggs, by, span,
                 tail, term_tokens, tolerance=None) -> None:
        # term_tokens is deliberately required: the fingerprint is a
        # correctness-critical cache key, and defaulting the predicate
        # tokens to () would let two plans with different predicates
        # share cached partials
        if len(term_tokens) != len(terms):
            raise ValueError("term_tokens must mirror terms")
        self.terms = terms
        self.prefix = prefix
        self.cols = cols
        self.cmd = cmd
        self.aggs = aggs
        self.by = by
        self.span = span
        self.tail = tail
        self.term_tokens = list(term_tokens)
        self.tolerance = (float(tolerance) if tolerance is not None
                          else None)
        canon = ("plan-v1", cmd, float(span) if span is not None else None,
                 tuple(term_tokens),
                 tuple(tuple(toks) for toks in prefix),
                 tuple((name, fieldname or "", out)
                       for name, fieldname, out in aggs),
                 tuple(by),
                 tuple(sorted(cols)) if cols is not None else None)
        if self.tolerance is not None:
            canon = canon + ("tol", self.tolerance)
        self.fingerprint = hashlib.blake2b(
            repr(canon).encode("utf-8"), digest_size=12).hexdigest()

    def state(self) -> Dict[str, Any]:
        """The plan as a plain JSON-safe dict (wire form; versioned)."""
        return {
            "v": self.STATE_VERSION,
            "cmd": self.cmd,
            "span": float(self.span) if self.span is not None else None,
            "terms": list(self.term_tokens),
            "prefix": [list(toks) for toks in self.prefix],
            "aggs": [[name, fieldname, out]
                     for name, fieldname, out in self.aggs],
            "by": list(self.by),
            "cols": (sorted(self.cols) if self.cols is not None else None),
            "tail": [list(toks) for toks in self.tail],
            "tolerance": self.tolerance,
        }

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "ScatterPlan":
        """Rebuild a plan from :meth:`state` output.  Raises
        ``ValueError`` on a malformed or version-mismatched state."""
        if not isinstance(state, dict) or \
                state.get("v") != cls.STATE_VERSION:
            raise ValueError(f"unsupported scatter-plan state: "
                             f"{state.get('v') if isinstance(state, dict) else state!r}")
        try:
            term_tokens = [str(t) for t in state["terms"]]
            cols = state["cols"]
            return cls(
                terms=[_Term(t) for t in term_tokens],
                prefix=[[str(t) for t in toks] for toks in state["prefix"]],
                cols=(frozenset(str(c) for c in cols)
                      if cols is not None else None),
                cmd=str(state["cmd"]),
                # a bare `count` parses with fieldname None; "" is
                # equivalent everywhere (incl. the fingerprint canon)
                aggs=[(str(n), "" if f is None else str(f), str(o))
                      for n, f, o in state["aggs"]],
                by=[str(b) for b in state["by"]],
                span=state["span"],
                tail=[[str(t) for t in toks] for toks in state["tail"]],
                term_tokens=term_tokens,
                tolerance=state.get("tolerance"))
        except (KeyError, TypeError) as exc:
            raise ValueError(f"malformed scatter-plan state: {exc}") from exc


def compile_scatter_plan(stages: List[List[str]],
                         tolerance: Optional[float] = None
                         ) -> Optional[ScatterPlan]:
    """Compile a pipeline into a scatter/gather plan, or ``None`` when
    it is not distributable (no leading row-local prefix ending in a
    ``stats``/``timechart``, or a non-mergeable aggregate).
    ``tolerance`` opts the plan into approximate rollup-tier answers
    (see :class:`ScatterPlan`)."""
    stages = list(stages)
    if not stages:
        return None
    if stages[0] and stages[0][0] not in _COMMANDS:
        stages = [["search"] + list(stages[0])] + stages[1:]
    k = 0
    while k < len(stages) and stages[k] and stages[k][0] in _ROW_LOCAL_CMDS:
        k += 1
    if k >= len(stages):
        return None
    cmd, args = stages[k][0], stages[k][1:]
    if cmd not in ("stats", "timechart"):
        return None
    span = None
    if cmd == "timechart":
        try:
            span, agg_tokens, by = _timechart_split(args)
        except ValueError:
            return None
    else:
        agg_tokens, by = _stats_split(args)
    try:
        aggs = _parse_aggs(agg_tokens)
    except QueryError:
        return None  # the fallback executor raises the real error
    for name, fieldname, _out in aggs:
        if name in ("first", "last"):
            return None  # global-row-order dependent: exact gather
        if not fieldname and name != "count":
            return None  # whole-row aggregate
    terms: List[_Term] = []
    term_tokens: List[str] = []
    prefix = stages[:k]
    if prefix and prefix[0][0] in ("search", "where"):
        term_tokens = list(prefix[0][1:])
        terms = [_Term(t) for t in term_tokens]
        prefix = prefix[1:]
    cols = referenced_columns(prefix + [stages[k]])
    return ScatterPlan(terms, prefix, cols, cmd, aggs, by, span,
                       stages[k + 1:], term_tokens=term_tokens,
                       tolerance=tolerance)


def _batch_partials(batch: _Batch, plan: ScatterPlan
                    ) -> Dict[tuple, Dict[str, Any]]:
    """Run a plan's prefix + grouping + partial kernels on one gathered
    batch, reducing it to ``{group key: {output name: partial state}}``.

    Raises ``_Fallback`` when the batch's data defeats vectorization in
    a way the partial kernels cannot express (eval on a mixed-type
    column, non-float row semantics, ...): partial kernels cannot
    reproduce row-engine value semantics, so callers re-plan the whole
    query as an exact gather.
    """
    for toks in plan.prefix:
        batch = _COL_COMMANDS[toks[0]](batch, toks[1:])
    if plan.cmd == "timechart":
        ts_col = batch.cols.get("ts")
        if batch.n and (ts_col is None or ts_col.kind != "num"):
            raise _Fallback
        if batch.n:
            valid = ts_col.present & ~np.isnan(ts_col.vals)
            batch = batch.take(np.nonzero(valid)[0])
        if batch.n == 0:
            return {}
        buckets = np.floor(batch.cols["ts"].vals / plan.span) * plan.span
        u, inv = np.unique(buckets, return_inverse=True)
        grouping = _group(batch, plan.by,
                          extra=(inv.astype(np.int64), u.tolist()))
    else:
        if batch.n == 0:
            return {}
        grouping = _group(batch, plan.by)
    return _partial_aggregate(batch, grouping, plan.aggs)


def _segment_partials(seg, plan: ScatterPlan) -> Dict[tuple, Dict[str, Any]]:
    """Partial states of one segment under a plan — the cacheable unit.

    Segments are immutable and the plan fingerprint pins everything
    that shapes this result, so the value is valid for the segment's
    whole lifetime (including after adoption by another store)."""
    idx = _segment_match_idx(seg, plan.terms)
    if idx is None or not len(idx):
        return {}
    batch = _merge_parts([(seg, idx)], plan.cols)
    return _batch_partials(batch, plan)


# ------------------------------------------------- rollup-tier planning ---
#
# Retention (repro.core.compaction) downsamples raw segments into
# rollup segments: one row per (bucket, host, job, kind) carrying
# mergeable partial-aggregate stat columns per metric field.  The
# scatter planner substitutes a rollup for the raw segments it covers
# when the plan is *provably* answerable from buckets — the result is
# then the exact partial algebra over pre-reduced rows.  Plans that
# fail any rule below simply scan raw (no behavior change).  The full
# eligibility table lives in docs/storage.md.

_ROLLUP_AGG_NAMES = frozenset(
    ("count", "sum", "avg", "mean", "min", "max", "range", "stdev"))


def _plan_rollup_shape(plan: ScatterPlan) -> Optional[tuple]:
    """Split a plan's predicate terms for rollup evaluation, or ``None``
    when the plan can never be answered from rollup segments: it must
    have no prefix stages, group only by rollup dimensions, use only
    bucket-derivable aggregations over non-reserved fields, and filter
    only on dimension equality or ``ts`` range terms."""
    if plan.prefix:
        return None
    from repro.core.compaction import ROLLUP_DIMS
    if any(b not in ROLLUP_DIMS for b in plan.by):
        return None
    for name, fieldname, _out in plan.aggs:
        if name not in _ROLLUP_AGG_NAMES:
            return None
        if name == "count" and not fieldname:
            continue  # bare count: physical rows per bucket
        if not fieldname or fieldname == "ts" or fieldname in ROLLUP_DIMS:
            return None  # reserved names may be shadowed by fields
    dim_terms: List[_Term] = []
    ts_terms: List[_Term] = []
    for t in plan.terms:
        if t.key == "ts" and t.num is not None and \
                t.op in (">", ">=", "<", "<="):
            ts_terms.append(t)
        elif t.key in ROLLUP_DIMS and t.op in ("=", "!="):
            dim_terms.append(t)
        else:
            return None  # full-text / field predicates need raw rows
    return dim_terms, ts_terms


def _rollup_ts_bounds(ts_terms: List[_Term], gran: float,
                      tolerance: Optional[float]) -> Optional[tuple]:
    """``[lo, hi)`` bucket bounds equivalent to the plan's ``ts`` range
    terms, or ``None`` when a bound cannot be expressed on bucket
    boundaries.  Exact equivalence needs ``>=``/``<`` with a
    granularity-aligned value; with ``tolerance`` opted in, any bound
    within ``tolerance`` seconds of a boundary snaps to it (``>`` is
    then read as ``>=`` and ``<=`` as ``<``)."""
    lo, hi = -math.inf, math.inf
    for t in ts_terms:
        x = float(t.num)
        aligned = math.floor(x / gran) * gran
        exact = x == aligned
        snap = math.floor(x / gran + 0.5) * gran
        if t.op == ">=" and exact:
            lo = max(lo, x)
        elif t.op == "<" and exact:
            hi = min(hi, x)
        elif tolerance is not None and abs(x - snap) <= tolerance:
            if t.op in (">", ">="):
                lo = max(lo, snap)
            else:
                hi = min(hi, snap)
        else:
            return None
    return lo, hi


def _rollup_eligible(plan: ScatterPlan, rseg,
                     shape: tuple) -> Optional[tuple]:
    """Bucket ``[lo, hi)`` bounds for evaluating ``plan`` against one
    rollup segment, or ``None`` when this rollup cannot answer it:
    timechart spans must be whole multiples of the granularity, no
    aggregated field may be in the rollup's ``excluded`` list (object-
    typed somewhere in the covered raw), and the time range must land
    on bucket boundaries (see :func:`_rollup_ts_bounds`)."""
    info = rseg.rollup
    gran = float(info["gran"])
    if gran <= 0:
        return None
    if plan.cmd == "timechart":
        k = plan.span / gran
        if not (abs(k - round(k)) < 1e-9 and round(k) >= 1):
            return None
    excluded = info.get("excluded") or ()
    if excluded:
        for _name, fieldname, _out in plan.aggs:
            if fieldname and fieldname in excluded:
                return None
    return _rollup_ts_bounds(shape[1], gran, plan.tolerance)


def _select_rollups(store, plan: ScatterPlan):
    """Pick rollup segments to substitute for the raw segments they
    cover.  Returns ``(chosen, skip_uids, shape)`` where ``chosen`` is
    ``[(rollup segment, uid, ts-bounds)]`` and ``skip_uids`` the live
    raw uids those rollups replace.

    Coarsest granularity first; a rollup is selected when the plan is
    answerable from it and its covers don't overlap an already-selected
    rollup's.  A rollup whose covers include retired raw uids
    (retention dropped the rows) is the *only* remaining source for
    them — retention guarantees a dropped uid is covered at the
    coarsest granularity, so the coarsest-first order accounts for
    every dropped row exactly once."""
    units = getattr(store, "rollup_units", None)
    units = units() if units is not None else []
    if not units:
        return [], frozenset(), None
    shape = _plan_rollup_shape(plan)
    if shape is None:
        return [], frozenset(), None
    live = {uid for _seg, uid in store.segment_units(include_buffer=False)
            if uid is not None}
    order = sorted(range(len(units)),
                   key=lambda i: -float(units[i][0].rollup["gran"]))
    chosen: List[tuple] = []
    claimed: set = set()
    for i in order:
        rseg, ruid = units[i]
        covers = set(rseg.rollup.get("covers") or ())
        if not covers or covers & claimed:
            continue
        bounds = _rollup_eligible(plan, rseg, shape)
        if bounds is None:
            continue
        chosen.append((rseg, ruid, bounds))
        claimed |= covers
    return chosen, frozenset(claimed & live), shape


def _rollup_partials(rseg, plan: ScatterPlan, bounds: tuple,
                     shape: tuple) -> Dict[tuple, Dict[str, Any]]:
    """Partial states of one rollup segment under a plan — same
    cacheable unit as :func:`_segment_partials`, derived from the stat
    columns instead of raw rows.  Bucket rows are filtered by the dim
    terms and snapped ts bounds, grouped exactly like raw rows (bucket
    starts land in the same timechart buckets because the span is a
    whole multiple of the granularity), and each group's states are the
    exact merge of its buckets' pre-reduced partials."""
    from repro.core.compaction import ROLLUP_ROWS, rollup_stat_col
    dim_terms, _ts_terms = shape
    idx = _segment_match_idx(rseg, dim_terms)
    if idx is None or not len(idx):
        return {}
    lo, hi = bounds
    if lo != -math.inf or hi != math.inf:
        ts = rseg.attrs["ts"].vals[idx]
        idx = idx[(ts >= lo) & (ts < hi)]
        if not len(idx):
            return {}
    need = {ROLLUP_ROWS, "ts"} | set(plan.by)
    for _name, fieldname, _out in plan.aggs:
        if fieldname:
            need.update(rollup_stat_col(s, fieldname)
                        for s in ("cnt", "num", "sum", "min", "max", "m2"))
    batch = _merge_parts([(rseg, idx)], frozenset(need))
    if plan.cmd == "timechart":
        buckets = np.floor(batch.cols["ts"].vals / plan.span) * plan.span
        u, inv = np.unique(buckets, return_inverse=True)
        grouping = _group(batch, plan.by,
                          extra=(inv.astype(np.int64), u.tolist()))
    else:
        grouping = _group(batch, plan.by)
    G, gid = grouping.G, grouping.gid
    out: List[Dict[str, Any]] = [dict() for _ in range(G)]

    def wsum(weights: np.ndarray) -> np.ndarray:
        return np.bincount(gid, weights=weights, minlength=G)

    def stat(fieldname: str, s: str) -> Optional[np.ndarray]:
        col = batch.cols.get(rollup_stat_col(s, fieldname))
        return col.vals if col is not None else None

    for name, fieldname, outname in plan.aggs:
        if not fieldname:  # bare count
            n = wsum(batch.cols[ROLLUP_ROWS].vals)
            for g in range(G):
                out[g][outname] = int(n[g])
            continue
        num = stat(fieldname, "num")
        if num is None:
            # field absent from every covered raw segment: the same
            # empty states the raw partial kernels produce
            empty = {"count": 0, "sum": (0, 0.0), "avg": (0, 0.0),
                     "mean": (0, 0.0), "min": (0, math.inf, -math.inf),
                     "max": (0, math.inf, -math.inf),
                     "range": (0, math.inf, -math.inf),
                     "stdev": (0, 0.0, 0.0)}[name]
            for g in range(G):
                out[g][outname] = empty
            continue
        if name == "count":
            n = wsum(stat(fieldname, "cnt"))
            for g in range(G):
                out[g][outname] = int(n[g])
        elif name in ("sum", "avg", "mean"):
            n = wsum(num)
            s = wsum(stat(fieldname, "sum"))
            for g in range(G):
                out[g][outname] = (int(n[g]), float(s[g]))
        elif name in ("min", "max", "range"):
            n = wsum(num)
            mn = np.full(G, math.inf)
            mx = np.full(G, -math.inf)
            sel = num > 0
            if sel.any():
                np.minimum.at(mn, gid[sel], stat(fieldname, "min")[sel])
                np.maximum.at(mx, gid[sel], stat(fieldname, "max")[sel])
            for g in range(G):
                c = int(n[g])
                out[g][outname] = ((c, float(mn[g]), float(mx[g]))
                                   if c else (0, math.inf, -math.inf))
        elif name == "stdev":
            s_i = stat(fieldname, "sum")
            m2_i = stat(fieldname, "m2")
            n = wsum(num)
            s = wsum(s_i)
            means = s / np.maximum(n, 1)
            # Chan et al. in closed form: per-bucket M2 plus each
            # bucket's squared mean deviation from the group mean
            mean_i = s_i / np.maximum(num, 1)
            m2 = wsum(m2_i + num * (mean_i - means[gid]) ** 2)
            for g in range(G):
                c = int(n[g])
                out[g][outname] = ((c, float(means[g]), float(m2[g]))
                                   if c else (0, 0.0, 0.0))
    return {key: out[g] for g, key in enumerate(grouping.keys)}


def scatter_partials(store: ColumnarMetricStore, plan: ScatterPlan,
                     cache=None, stats: Optional[Dict[str, int]] = None
                     ) -> Dict[tuple, Dict[str, Any]]:
    """Store-local half of a plan: reduce every group of every segment
    to partial aggregation states and merge them into one
    ``{group key: {output name: partial state}}`` map.

    The partial stage runs **per sealed segment** so results are
    cacheable: with a ``cache`` (a
    :class:`~repro.core.columnar.PartialAggregateCache`), each sealed
    segment's map is looked up by ``(segment uid, plan fingerprint)``
    and only missing segments — plus the unsealed append buffer, which
    has no uid — are recomputed.  ``stats`` (when given) is incremented
    in place: ``segments_cached`` / ``segments_computed`` /
    ``buffer_rows``.

    Raises ``_Fallback`` when some segment's data defeats the partial
    kernels (callers then re-run the whole query through the exact
    gather path); segments cached before the fallback stay valid.

    When a single plan's sealed-segment sweep cannot fit in the cache
    (``sealed > max_entries``) the cache is bypassed for this query
    (``stats["cache_bypassed"]``): an LRU fed a cyclic sweep larger
    than itself would evict every entry the next run needs — 0% hits
    *and* it would clobber other plans' entries.  Size
    ``partial_cache_entries`` to at least segments × actively refreshed
    plans (docs/incremental.md).
    """
    maps: List[Dict[tuple, Dict[str, Any]]] = []
    if hasattr(store, "segment_units"):
        units = store.segment_units()
    else:  # pragma: no cover - stores always expose segment_units
        units = [(seg, None) for seg in store.segments()]
    rollups, skip_uids, shape = _select_rollups(store, plan)
    if cache is not None and cache.max_entries < sum(
            1 for _seg, uid in units if uid is not None):
        cache = None
        if stats is not None:
            stats["cache_bypassed"] = True
    for rseg, ruid, rbounds in rollups:
        key = (ruid, plan.fingerprint)
        if stats is not None:
            stats["rollup_segments"] = stats.get("rollup_segments", 0) + 1
        if cache is not None:
            hit = cache.get(key)
            if hit is not None:
                maps.append(hit)
                if stats is not None:
                    stats["segments_cached"] = \
                        stats.get("segments_cached", 0) + 1
                continue
        pmap = _rollup_partials(rseg, plan, rbounds, shape)
        if cache is not None:
            cache.put(key, pmap)
        maps.append(pmap)
    for seg, uid in units:
        if uid is not None and uid in skip_uids:
            if stats is not None:
                stats["rollup_replaced"] = \
                    stats.get("rollup_replaced", 0) + 1
            continue
        key = (uid, plan.fingerprint) if uid is not None else None
        if cache is not None and key is not None:
            hit = cache.get(key)
            if hit is not None:
                maps.append(hit)
                if stats is not None:
                    stats["segments_cached"] = \
                        stats.get("segments_cached", 0) + 1
                continue
        try:
            pmap = _segment_partials(seg, plan)
        except (ValueError, KeyError, OSError, zlib.error):
            # A sealed segment whose payload defeats decode (bit rot
            # past the open-time checksum, truncated mmap, ...) must
            # not take the whole query down: quarantine it and degrade,
            # surfacing the count instead of crashing.  Buffer batches
            # (uid None) have no backing files and are never corrupt
            # this way, so decode errors there stay fatal.
            quarantine = getattr(store, "quarantine_segment", None)
            if uid is None or quarantine is None or not quarantine(seg):
                raise
            if stats is not None:
                stats["quarantined_segments"] = \
                    stats.get("quarantined_segments", 0) + 1
            continue
        if cache is not None and key is not None:
            cache.put(key, pmap)
        if stats is not None:
            if uid is None:
                stats["buffer_rows"] = stats.get("buffer_rows", 0) + seg.n
            else:
                stats["segments_computed"] = \
                    stats.get("segments_computed", 0) + 1
        maps.append(pmap)
    return merge_partial_maps(maps, plan.aggs)


def _partial_aggregate(batch: _Batch, grouping: _Grouping, aggs
                       ) -> Dict[tuple, Dict[str, Any]]:
    """Reduce every group of a shard-local batch to partial states.

    Fully vectorized: per field one pass builds numeric masks, one
    ``bincount`` family per moment aggregate, and one group-major value
    sort shared by min/max/range and every quantile summary — no
    per-group NumPy calls (a shard pays fixed overhead once, however
    many groups it holds)."""
    from repro.core.sketches import p2_summaries_from_sorted_groups
    G = grouping.G
    gid = grouping.gid
    out: List[Dict[str, Any]] = [dict() for _ in range(G)]
    cache: Dict[tuple, tuple] = {}

    def masks(fname: str):
        c = cache.get(("m", fname))
        if c is None:
            c = cache[("m", fname)] = _field_masks(batch, fname)
        return c

    def numeric_groups(fname: str):
        c = cache.get(("n", fname))
        if c is None:
            _col, _present, numeric, vals = masks(fname)
            ngids = gid[numeric]
            nvals = vals[numeric]
            counts = np.bincount(ngids, minlength=G)
            c = cache[("n", fname)] = (ngids, nvals, counts)
        return c

    def sorted_groups(fname: str):
        """Group-major, value-sorted numeric values + group extents.

        Uses the grouping's shared row-order argsort (amortized across
        fields) and small in-place per-group sorts — much cheaper than
        a full two-key lexsort."""
        c = cache.get(("s", fname))
        if c is None:
            _col, _present, numeric, vals = masks(fname)
            num_o = numeric[grouping.order]
            svals = np.ascontiguousarray(vals[grouping.order][num_o])
            counts = np.bincount(gid[numeric], minlength=G)
            starts = np.zeros(G, np.int64)
            if G > 1:
                starts[1:] = np.cumsum(counts)[:-1]
            pos = 0
            for cnt in counts.tolist():
                if cnt > 1:
                    svals[pos:pos + cnt].sort()
                pos += cnt
            c = cache[("s", fname)] = (svals, starts, counts)
        return c

    for name, fname, outname in aggs:
        if not fname:  # plain `count`: rows per group
            cnt = np.bincount(gid, minlength=G)
            for g in range(G):
                out[g][outname] = int(cnt[g])
            continue
        if name == "count":
            _col, present, _numeric, _vals = masks(fname)
            cnt = np.bincount(gid[present], minlength=G)
            for g in range(G):
                out[g][outname] = int(cnt[g])
        elif name in ("sum", "avg", "mean"):
            ngids, nvals, counts = numeric_groups(fname)
            sums = (np.bincount(ngids, weights=nvals, minlength=G)
                    if ngids.size else np.zeros(G))
            for g in range(G):
                out[g][outname] = (int(counts[g]), float(sums[g]))
        elif name in ("min", "max", "range"):
            svals, starts, counts = sorted_groups(fname)
            if svals.size:
                last = svals.size - 1
                mins = svals[np.minimum(starts, last)]
                maxs = svals[np.minimum(
                    starts + np.maximum(counts - 1, 0), last)]
            for g in range(G):
                c = int(counts[g])
                out[g][outname] = ((c, float(mins[g]), float(maxs[g]))
                                   if c else (0, math.inf, -math.inf))
        elif name == "stdev":
            ngids, nvals, counts = numeric_groups(fname)
            if ngids.size:
                sums = np.bincount(ngids, weights=nvals, minlength=G)
                means = sums / np.maximum(counts, 1)
                # two-pass M2 (robust against catastrophic cancellation)
                m2 = np.bincount(ngids, weights=(nvals - means[ngids]) ** 2,
                                 minlength=G)
            for g in range(G):
                c = int(counts[g])
                out[g][outname] = ((c, float(means[g]), float(m2[g]))
                                   if c else (0, 0.0, 0.0))
        elif name in ("median",) or _PCT_RE.match(name):
            q = 0.5 if name == "median" else int(name[1:]) / 100.0
            summaries = p2_summaries_from_sorted_groups(
                *sorted_groups(fname), q)
            for g in range(G):
                out[g][outname] = [summaries[g]]
        elif name == "dc":
            col, present, _numeric, _vals = masks(fname)
            codes, labels = _factorize(col, batch.n)
            pg = gid[present]
            pc = codes[present]
            sets: List[set] = [set() for _ in range(G)]
            if pg.size:
                stride = len(labels) + 1
                pairs = np.unique(pg * stride + pc)
                gg = pairs // stride
                cc = pairs % stride
                cuts = np.searchsorted(gg, np.arange(1, G))
                for g, chunk in enumerate(np.split(cc, cuts)):
                    sets[g] = {labels[c] for c in chunk.tolist()}
            for g in range(G):
                out[g][outname] = sets[g]
        else:  # pragma: no cover - compile_scatter_plan guards this
            raise QueryError(f"non-mergeable aggregation {name!r}")
    return {key: out[g] for g, key in enumerate(grouping.keys)}


def _merge_partial(name: str, a, b):
    if name == "count":
        return a + b
    if name in ("sum", "avg", "mean"):
        return (a[0] + b[0], a[1] + b[1])
    if name in ("min", "max", "range"):
        return (a[0] + b[0], min(a[1], b[1]), max(a[2], b[2]))
    if name == "stdev":  # Chan et al. parallel variance merge
        (na, ma, m2a), (nb, mb, m2b) = a, b
        if na == 0:
            return b
        if nb == 0:
            return a
        n = na + nb
        d = mb - ma
        return (n, ma + d * nb / n, m2a + m2b + d * d * na * nb / n)
    if name in ("median",) or _PCT_RE.match(name):
        return a + b  # summary lists concatenate; the CDF merge is
        # order-insensitive, so gather order cannot matter
    if name == "dc":
        return a | b  # exact union — never sum per-shard counts
    raise QueryError(f"non-mergeable aggregation {name!r}")


def merge_partial_maps(maps: Iterable[Dict[tuple, Dict[str, Any]]],
                       aggs) -> Dict[tuple, Dict[str, Any]]:
    """Gather half, step 1: union group keys across partial maps
    (per-segment and/or per-shard) and merge each group's states.

    Never mutates the input maps or their partial states: inputs may be
    live partial-cache entries, so each group's accumulator starts as a
    shallow copy and every ``_merge_partial`` returns a fresh value
    (tuples/ints are immutable; quantile-summary lists and ``dc`` label
    sets are rebuilt, not extended in place)."""
    merged: Dict[tuple, Dict[str, Any]] = {}
    for m in maps:
        for key, partials in m.items():
            cur = merged.get(key)
            if cur is None:
                merged[key] = dict(partials)
                continue
            for name, _fname, outname in aggs:
                cur[outname] = _merge_partial(name, cur[outname],
                                              partials[outname])
    return merged


def _finalize_partial(name: str, part):
    from repro.core.sketches import merge_quantile_summaries
    if name == "count":
        return int(part)
    if name == "sum":
        n, s = part
        return float(s) if n else 0  # row engine: sum([]) is int 0
    if name in ("avg", "mean"):
        n, s = part
        return s / n if n else math.nan
    if name == "min":
        return part[1] if part[0] else math.nan
    if name == "max":
        return part[2] if part[0] else math.nan
    if name == "range":
        return part[2] - part[1] if part[0] else math.nan
    if name == "stdev":
        n, _mu, m2 = part
        return math.sqrt(max(m2, 0.0) / (n - 1)) if n >= 2 else 0.0
    if name in ("median",) or _PCT_RE.match(name):
        q = 0.5 if name == "median" else int(name[1:]) / 100.0
        return merge_quantile_summaries(part, q)
    if name == "dc":
        return len(part)
    raise QueryError(f"non-mergeable aggregation {name!r}")


def finalize_partial_rows(merged: Dict[tuple, Dict[str, Any]],
                          plan: ScatterPlan) -> List[Row]:
    """Gather half, step 2: finalize merged partials into result rows
    (sorted by group key, matching both local executors).  Quantile
    columns finalize batched: one vectorized CDF merge across all group
    keys instead of one Python merge per group."""
    from repro.core.sketches import merge_quantile_summary_groups
    keys = sorted(merged)
    rows: List[Row] = []
    for key in keys:
        if plan.cmd == "timechart":
            row: Row = {"_time": key[0]}
            row.update(dict(zip(plan.by, key[1:])))
        else:
            row = dict(zip(plan.by, key))
        rows.append(row)
    for name, _fname, outname in plan.aggs:
        if name in ("median",) or _PCT_RE.match(name):
            q = 0.5 if name == "median" else int(name[1:]) / 100.0
            vals = merge_quantile_summary_groups(
                [merged[k][outname] for k in keys], q)
            for row, v in zip(rows, vals):
                row[outname] = v
        else:
            for row, k in zip(rows, keys):
                row[outname] = _finalize_partial(name, merged[k][outname])
    return rows


def gather_filtered(store: ColumnarMetricStore, stages: List[List[str]]):
    """Exact-gather scan for one shard: push the leading searches down
    to the segment scan, gather only referenced columns, and return
    ``(ts array, rows, remaining stages)``.  The ts array comes from the
    record *attribute* (immune to field shadowing) so the gather node
    can canonically order rows across shards before running the rest of
    the pipeline."""
    terms, rest = _leading_terms(stages)
    parts = _store_parts(store, terms)
    if not parts:
        return np.empty(0), [], rest
    ts = np.concatenate([seg.attrs["ts"].vals[idx] for seg, idx in parts])
    batch = _merge_parts(parts, referenced_columns(rest))
    return ts, _rows_from_batch(batch), rest


def run_stages(rows: List[Row], stages: List[List[str]],
               implicit_first: bool = False) -> List[Row]:
    """Run pipeline stages on materialized rows (the row executor)."""
    for i, toks in enumerate(stages):
        cmd, args = toks[0], toks[1:]
        if i == 0 and implicit_first and cmd not in _COMMANDS:
            cmd, args = "search", toks  # leading implicit search
        if cmd not in _COMMANDS:
            raise QueryError(f"unknown command {cmd!r}")
        rows = _COMMANDS[cmd](rows, args)
    return rows


# ===========================================================================
# Incremental execution: segment-keyed partial-aggregate caches
# ===========================================================================
#
# Sealed segments are immutable, so a mergeable plan's partial states
# for a segment never change: computing them once per (segment, plan
# fingerprint) and caching turns a repeated fleet query into "recompute
# the unsealed buffer, merge, finalize".  The incremental result is
# byte-identical to recomputing every per-segment partial fresh (same
# partition, same deterministic kernels, order-insensitive merges) —
# the cached-vs-uncached parity suite asserts it.  Relative to the
# *fused* single-store kernels the algebra is exact for every
# aggregation except quantiles, which carry the documented P²-summary
# merge bound (docs/sharding.md).  See docs/incremental.md.

def _incremental_query(store: ColumnarMetricStore,
                       stages: List[List[str]],
                       plan: Optional[ScatterPlan] = None,
                       tolerance: Optional[float] = None):
    """Cache-aware execution of a pipeline against a single store.

    Returns ``(rows, stats)``.  Mergeable pipelines run per-segment
    partials through the store's :class:`PartialAggregateCache` —
    consulting rollup tiers when eligible (``tolerance`` opts into
    approximate time bounds; see :class:`ScatterPlan`); anything else —
    and any ``_Fallback`` from mixed-type data — runs the exact
    columnar executor (``stats["mode"] == "full"``).  ``plan`` skips
    recompilation when the caller (a :class:`QueryHandle`) already
    compiled these stages.
    """
    if plan is None:
        plan = compile_scatter_plan(stages, tolerance=tolerance)
    if plan is not None:
        stats = {"mode": "incremental", "fingerprint": plan.fingerprint,
                 "segments_cached": 0, "segments_computed": 0,
                 "buffer_rows": 0}
        try:
            merged = scatter_partials(store, plan,
                                      cache=store.partial_cache,
                                      stats=stats)
        except _Fallback:
            pass
        else:
            rows = finalize_partial_rows(merged, plan)
            return run_stages(rows, plan.tail), stats
    return _columnar_query(store, stages), {"mode": "full"}


class QueryHandle:
    """A registered, repeatedly-refreshed query — the streaming-
    dashboard surface of the incremental engine (the paper's
    "interactive analysis" loop: the aggregator pumps new samples, the
    dashboard re-renders).

    :meth:`refresh` returns the query's current rows.  While the store
    version is unchanged the previous rows are returned as-is (treat
    them as read-only); once data arrived, mergeable pipelines pay only
    for the unsealed buffer plus newly sealed segments — cached
    per-segment partials cover the rest.  Works over a single
    :class:`ColumnarMetricStore` or a sharded store (whose scatter path
    consults the per-shard caches on every query).

    ``service`` routes every refresh through a
    :class:`repro.core.service.QueryService` (as tenant ``tenant``):
    a thousand registered watchers on the same plan then cost one
    execution per store version — the service's in-flight dedup and
    shared result cache collapse them.  Results are byte-identical to
    the direct path.  ``shed_ok=True`` additionally lets the service
    drop a refresh under backpressure, in which case :meth:`refresh`
    returns the previous rows unchanged (stale beats a refresh convoy
    at saturation; the next quiet refresh catches up).

    :meth:`close` retires the handle: long-lived processes register
    and drop watches constantly, and an unclosed handle would otherwise
    be refreshed forever by ``Aggregator.refresh_watches``.
    """

    def __init__(self, store, q: str,
                 tolerance: Optional[float] = None,
                 service=None, tenant: str = "watch",
                 shed_ok: bool = False) -> None:
        self.store = store
        self.q = q
        self.tolerance = tolerance
        self.service = service
        self.tenant = str(tenant)
        self.shed_ok = bool(shed_ok)
        self.closed = False
        self._stages = _split_pipeline(q)
        self.plan = compile_scatter_plan(self._stages, tolerance=tolerance)
        self.refreshes = 0
        self.last_rows: Optional[List[Row]] = None
        self.last_stats: Optional[Dict] = None
        self._last_version = None

    def close(self) -> None:
        """Retire the handle.  Idempotent; a closed handle refuses
        :meth:`refresh` and is skipped by ``refresh_watches``."""
        self.closed = True

    def refresh(self, force: bool = False) -> List[Row]:
        if self.closed:
            raise RuntimeError("QueryHandle is closed")
        store = self.store
        version = store._version() if hasattr(store, "_version") else None
        if (not force and self.last_rows is not None
                and version is not None
                and version == self._last_version):
            return self.last_rows
        if self.service is not None:
            # "incremental" preserves the direct path's executor choice
            # for single stores; sharded stores plan their own
            # execution and ignore the hint's single-store meaning
            engine = (None if getattr(store, "is_sharded", False)
                      else "incremental")
            rows, stats = self.service.query_with_stats(
                self.q, tenant=self.tenant, engine=engine,
                tolerance=self.tolerance,
                # only shed when there is a previous answer to keep
                shed_ok=self.shed_ok and self.last_rows is not None)
            if stats.get("shed"):
                return self.last_rows  # stale, refreshed next round
        elif getattr(store, "is_sharded", False):
            rows, stats = store.query_with_stats(self.q,
                                                 tolerance=self.tolerance)
        elif isinstance(store, ColumnarMetricStore):
            if self.plan is None:  # not mergeable: skip recompiling
                rows, stats = _columnar_query(store, self._stages), \
                    {"mode": "full"}
            else:
                rows, stats = _incremental_query(store, self._stages,
                                                 plan=self.plan)
            store.last_query_stats = stats
        else:
            rows, stats = query_with_stats(store, self.q)
        self.refreshes += 1
        self.last_rows = rows
        self.last_stats = stats
        self._last_version = version
        return rows

    def explain(self) -> Dict[str, Any]:
        """Execution mode + the last refresh's recompute counters."""
        out: Dict[str, Any] = {"query": self.q,
                               "incremental": self.plan is not None,
                               "refreshes": self.refreshes}
        if self.last_stats:
            out.update(self.last_stats)
        return out


def explain_store(store: ColumnarMetricStore, q: str) -> Dict[str, Any]:
    """Describe how ``q`` executes incrementally against one store:
    plan shape, how many sealed segments already hold cached partials
    for this plan's fingerprint, and the store's cumulative cache
    counters.  Pure introspection — runs nothing, counts no hits."""
    stages = _split_pipeline(q)
    plan = compile_scatter_plan(stages)
    cache = store.partial_cache
    out: Dict[str, Any] = {
        "shards": 1,
        "cache": {"hits": cache.hits, "misses": cache.misses,
                  "entries": len(cache), "evictions": cache.evictions},
        "storage": store.storage_stats(),
    }
    if plan is None:
        terms, rest = _leading_terms(stages)
        cols = referenced_columns(rest)
        out.update({
            "mode": "full",
            "pushed_terms": len(terms),
            "columns": sorted(cols) if cols is not None else None,
            "stages": [t[0] for t in rest],
        })
        return out
    sealed = store.segment_units(include_buffer=False)
    cached = sum(1 for _seg, uid in sealed
                 if cache.peek((uid, plan.fingerprint)))
    rollups, skip_uids, _shape = _select_rollups(store, plan)
    out.update({
        "mode": "incremental",
        "fingerprint": plan.fingerprint,
        "partial_aggs": [name for name, _f, _o in plan.aggs],
        "group_by": list(plan.by),
        "columns": sorted(plan.cols) if plan.cols is not None else None,
        "tail_stages": [t[0] for t in plan.tail],
        "segments": {"sealed": len(sealed), "cached": cached,
                     "buffer_rows": len(store._buffer),
                     "rollup_segments": len(rollups),
                     "rollup_replaced": len(skip_uids)},
    })
    return out


# ----------------------------------------------------------------- driver ---

def query(source: Union[ColumnarMetricStore, Sequence[Row],
                        Sequence[MetricRecord]],
          q: str, engine: Optional[str] = None,
          tolerance: Optional[float] = None) -> List[Row]:
    """Run an SPL-like pipeline over a store / record list / row list.

    ``engine`` — ``None`` (auto: columnar for stores, rows otherwise),
    ``"columnar"`` or ``"rows"`` to force an executor, or
    ``"incremental"`` to run a single store through the segment-keyed
    partial-aggregate cache (mergeable pipelines only; anything else
    falls back to the exact columnar path).  A sharded store
    (``repro.core.shards.ShardedAggregator``) plans its own distributed
    execution — cache-aware by default — and is dispatched to directly.

    ``tolerance`` (seconds) opts scatter-planned paths into approximate
    rollup-tier answers: time-range bounds within ``tolerance`` of a
    rollup bucket boundary snap to it (docs/storage.md).  Without it,
    rollups substitute only when exactly equivalent to the raw scan.
    """
    rows, _stats = query_with_stats(source, q, engine=engine,
                                    tolerance=tolerance)
    return rows


def query_with_stats(source: Union[ColumnarMetricStore, Sequence[Row],
                                   Sequence[MetricRecord]],
                     q: str, engine: Optional[str] = None,
                     tolerance: Optional[float] = None
                     ) -> Tuple[List[Row], Dict]:
    """:func:`query` returning ``(rows, stats)``.

    This is the re-entrant contract for concurrent callers (the
    ``QueryService``): stats travel with the call instead of through
    the shared ``last_query_stats`` attribute, which two concurrent
    queries would cross-contaminate.  ``last_query_stats`` is still
    *written* where it used to be, as a best-effort backwards-compat
    alias — never read it after a concurrent query.
    """
    if getattr(source, "is_sharded", False):
        return source.query_with_stats(q, engine=engine,
                                       tolerance=tolerance)
    stages = _split_pipeline(q)
    if isinstance(source, ColumnarMetricStore):
        # rollup tiers live behind the scatter planner; once a store
        # has them (or the caller opted into snapping), auto dispatch
        # must go through it — the plain columnar scan would re-read
        # raw segments retention may already have dropped
        if engine is None and (tolerance is not None
                               or getattr(source, "_rollups", None)):
            engine = "incremental"
        if engine == "incremental":
            rows, stats = _incremental_query(source, stages,
                                             tolerance=tolerance)
            source.last_query_stats = stats
            return rows, stats
        if engine != "rows":
            return _columnar_query(source, stages), {"mode": "full"}
        rows: List[Row] = [r.as_dict() for r in source.records]
    else:
        if engine == "columnar":
            raise QueryError("columnar engine requires a ColumnarMetricStore")
        rows = [r.as_dict() if isinstance(r, MetricRecord) else dict(r)
                for r in source]
    stats = {"mode": "rows" if engine == "rows" else "full"}
    if not stages:
        return rows, stats
    return run_stages(rows, stages, implicit_first=True), stats
