"""Central aggregation — the Splunk-ingest analog (paper §4.3).

The aggregator tails inbox stream files (fed by shippers/relays), parses
wire lines into records, deduplicates (transport is at-least-once), and
maintains a columnar in-memory store (``repro.core.columnar``) with
optional on-disk persistence.  Detectors can be attached for streaming
evaluation on ingest.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.core.columnar import ColumnarMetricStore
from repro.core.schema import MetricRecord, parse_line
from repro.core.transport import TailReader


class MetricStore(ColumnarMetricStore):
    """Time-ordered, columnar metric store (back-compat name).

    The seed kept a flat ``records`` list; that survives as a
    materializing property — dashboards/detectors/splunklite now run on
    the column arrays instead.
    """


class Aggregator:
    """Tails inbox files into a :class:`MetricStore`.

    ``inbox_dir`` receives one or more ``*.log`` stream files (one per
    shipper uplink).  ``persist_path`` optionally appends every accepted
    record to a consolidated archive (the "Splunk index" on disk; the
    paper keeps unlimited retention — so do we).  Pass a pre-configured
    ``store`` to control sealing / dedup-eviction behavior.
    """

    def __init__(self, inbox_dir: os.PathLike,
                 persist_path: Optional[os.PathLike] = None,
                 store: Optional[MetricStore] = None) -> None:
        self.inbox_dir = Path(inbox_dir)
        self.inbox_dir.mkdir(parents=True, exist_ok=True)
        self.store = store if store is not None else MetricStore()
        self._readers: Dict[str, TailReader] = {}
        self.persist_path = Path(persist_path) if persist_path else None
        self._on_record: List[Callable[[MetricRecord], None]] = []

    def on_record(self, cb: Callable[[MetricRecord], None]) -> None:
        """Attach a streaming consumer (e.g. a detector bank)."""
        self._on_record.append(cb)

    def pump(self) -> int:
        """Batch-ingest all new lines from all inbox files.

        Lines are parsed and appended to the store's columnar buffer in
        one pass per file.  The archive is opened once per pump (not
        once per record as in the seed), but each accepted line is
        written *before* its callbacks run, so a crashing consumer
        never loses already-ingested records from the archive.
        """
        n = 0
        archive = (open(self.persist_path, "a", encoding="utf-8")
                   if self.persist_path is not None else None)
        try:
            for path in sorted(self.inbox_dir.glob("*.log")):
                reader = self._readers.get(path.name)
                if reader is None:
                    reader = self._readers[path.name] = TailReader(path)
                for line in reader.read_new_lines():
                    rec = parse_line(line)
                    if rec is None or not self.store.insert(rec):
                        continue
                    n += 1
                    if archive is not None:
                        archive.write(line + "\n")
                    for cb in self._on_record:
                        cb(rec)
        finally:
            if archive is not None:
                archive.close()
        return n

    def load_archive(self, path: os.PathLike) -> int:
        """Replay a persisted archive into the store (restart path)."""
        try:
            with open(path, encoding="utf-8") as f:
                return self.store.ingest_lines(f)
        except OSError:
            return 0
