"""Central aggregation — the Splunk-ingest analog (paper §4.3).

The aggregator tails inbox stream files (fed by shippers/relays), parses
wire lines into records, deduplicates (transport is at-least-once), and
maintains a columnar in-memory store (``repro.core.columnar``) with
optional on-disk persistence.  Detectors can be attached for streaming
evaluation on ingest.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.core.columnar import ColumnarMetricStore
from repro.core.schema import MetricRecord, parse_line
from repro.core.transport import TailReader


class MetricStore(ColumnarMetricStore):
    """Time-ordered, columnar metric store (back-compat name).

    The seed kept a flat ``records`` list; that survives as a
    materializing property — dashboards/detectors/splunklite now run on
    the column arrays instead.
    """


class Aggregator:
    """Tails inbox files into a :class:`MetricStore` (or a shard set).

    ``inbox_dir`` receives one or more ``*.log`` stream files (one per
    shipper uplink).  ``store_dir`` is the durable on-disk index (the
    "Splunk index"; the paper keeps unlimited retention — so do we):
    sealed columnar segments plus a write-ahead log, memory-mapped back
    on restart without re-parsing wire lines — see
    ``repro.core.segmentio``.  ``shards``/``shard_policy`` back the
    aggregator with a :class:`~repro.core.shards.ShardedAggregator`
    instead of one store: inserts route to N shards and fleet queries
    run through the scatter/gather planner (``store_dir`` then holds a
    ``shards.json`` manifest plus one standalone store directory per
    shard).  ``remote_workers=True`` additionally moves each shard into
    its own worker process
    (:class:`~repro.core.remote.RemoteShardedAggregator`, the PerSyst
    agent-tree shape — docs/remote.md); watches, dashboards, and
    detectors run unchanged over the wire.  ``persist_path`` is the
    legacy consolidated line archive,
    kept as a *fallback*: writing it is deprecated, but
    :meth:`load_archive` still reads old archives (e.g. to migrate one
    into a ``store_dir``).  Pass a pre-configured ``store`` instead to
    control sealing / dedup-eviction / durability.

    ``self_monitor`` turns on fleet self-ingestion
    (docs/observability.md): registry snapshots from the store's
    telemetry are pumped as ``kind=fleet`` records into a dedicated
    in-memory ``_telemetry`` store (:attr:`telemetry_store`), so
    splunklite queries, dashboards, and the telemetry detectors run
    over the monitor's own vitals.  Pass ``True`` for the default 5 s
    cadence, a float for a custom interval, or a pre-built
    :class:`~repro.core.telemetry.SelfMonitor` (its sink becomes
    :attr:`telemetry_store`).  :meth:`pump` piggybacks an
    interval-gated snapshot; :meth:`close` stops any background pump.

    ``query_service`` routes :meth:`watch` refreshes through a
    :class:`~repro.core.service.QueryService` (docs/service.md) so
    concurrent dashboards share executions and back off under load.
    Pass ``True`` to build one over the store with defaults (closed by
    :meth:`close`), or a pre-configured instance (caller closes it).

    ``compaction_policy`` turns on background index maintenance (the
    Splunk bucket-aging analog — docs/storage.md): after any pump that
    ingested data, once ``every_seals`` new sealed segments have
    accumulated since the last run, the store is compacted (small
    sealed segments merged into large compressed ones) and, when the
    policy carries a ``retention`` sub-dict, retention/rollup tiers are
    applied.  Keys: ``every_seals`` (default 16) plus any of
    ``small_rows``/``target_rows``/``min_run``/``compress`` forwarded
    to compaction, and ``retention`` forwarded to
    ``apply_retention`` (e.g. ``{"rollups": [(60.0, 3600.0)],
    "raw_max_age_s": 86400.0}``).
    """

    def __init__(self, inbox_dir: os.PathLike,
                 persist_path: Optional[os.PathLike] = None,
                 store=None,
                 store_dir: Optional[os.PathLike] = None,
                 wal_fsync: bool = False,
                 shards: Optional[int] = None,
                 shard_policy="hash",
                 remote_workers: bool = False,
                 replicas: int = 1,
                 hedge: bool = True,
                 hedge_delay_s: Optional[float] = None,
                 compaction_policy: Optional[Dict] = None,
                 query_service=None,
                 self_monitor=None) -> None:
        self.inbox_dir = Path(inbox_dir)
        self.inbox_dir.mkdir(parents=True, exist_ok=True)
        if remote_workers and store is None and shards is None:
            raise ValueError("remote_workers=True requires shards=N")
        if replicas > 1 and not remote_workers:
            raise ValueError("replicas > 1 requires remote_workers=True "
                             "(replication lives in the worker fleet)")
        if store is not None:
            self.store = store
        elif shards is not None and remote_workers:
            from repro.core.remote import RemoteShardedAggregator
            self.store = RemoteShardedAggregator(num_shards=shards,
                                                 policy=shard_policy,
                                                 directory=store_dir,
                                                 wal_fsync=wal_fsync,
                                                 replicas=replicas,
                                                 hedge=hedge,
                                                 hedge_delay_s=hedge_delay_s)
        elif shards is not None:
            from repro.core.shards import ShardedAggregator
            self.store = ShardedAggregator(num_shards=shards,
                                           policy=shard_policy,
                                           directory=store_dir,
                                           wal_fsync=wal_fsync)
        elif store_dir is not None:
            self.store = MetricStore(directory=store_dir,
                                     wal_fsync=wal_fsync)
        else:
            self.store = MetricStore()
        if query_service is True:
            from repro.core.service import QueryService
            self.query_service = QueryService(self.store)
            self._owns_service = True
        else:
            self.query_service = query_service
            self._owns_service = False
        self._readers: Dict[str, TailReader] = {}
        self.persist_path = Path(persist_path) if persist_path else None
        self._on_record: List[Callable[[MetricRecord], None]] = []
        self.watches: List = []
        self.compaction_policy = (dict(compaction_policy)
                                  if compaction_policy else None)
        self.last_maintenance: Optional[Dict] = None
        self._last_compact_seals = (self._seal_count()
                                    if self.compaction_policy else 0)
        self.telemetry_store = None
        self.self_monitor = None
        if self_monitor is not None and self_monitor is not False:
            from repro.core.telemetry import SelfMonitor, Telemetry
            if isinstance(self_monitor, SelfMonitor):
                self.self_monitor = self_monitor
                self.telemetry_store = self_monitor.sink
            else:
                interval = (5.0 if self_monitor is True
                            else float(self_monitor))
                tel = getattr(self.store, "telemetry", None)
                if tel is None:
                    # plain single-store aggregator: mint a registry and
                    # hook the store's storage/cache collector into it
                    tel = Telemetry()
                    attach = getattr(self.store, "attach_telemetry", None)
                    if attach is not None:
                        attach(tel)
                self.telemetry_store = MetricStore()
                self.self_monitor = SelfMonitor(tel, self.telemetry_store,
                                                interval_s=interval)

    def on_record(self, cb: Callable[[MetricRecord], None]) -> None:
        """Attach a streaming consumer (e.g. a detector bank)."""
        self._on_record.append(cb)

    def watch(self, q: str) -> "QueryHandle":
        """Register a continuously-refreshed query over the store.

        The paper's dashboards re-run the same Splunk queries as new
        samples stream in; a watch makes that loop incremental: call
        :meth:`pump`, then ``handle.refresh()`` — sealed segments come
        from the store's segment-keyed partial-aggregate cache, so a
        refresh pays only for the unsealed buffer and segments sealed
        since the last pump (docs/incremental.md).  The handle is also
        kept in :attr:`watches` for :meth:`refresh_watches`.

        With a ``query_service`` configured, refreshes are submitted
        through it as tenant ``"watch"`` with ``shed_ok=True``: many
        watches on the same query coalesce into one execution, and at
        saturation a refresh is shed (the handle keeps its previous
        rows) instead of piling onto the backlog — docs/service.md.
        Drop a watch with :meth:`unwatch` (or ``handle.close()``) when
        its dashboard goes away; :attr:`watches` would otherwise grow,
        and refresh, forever.
        """
        from repro.core.splunklite import QueryHandle
        handle = QueryHandle(self.store, q, service=self.query_service,
                             shed_ok=self.query_service is not None)
        self.watches.append(handle)
        return handle

    def unwatch(self, handle) -> bool:
        """Close and deregister a watch; ``True`` if it was registered.

        Closing is what matters (``refresh_watches`` skips closed
        handles); deregistering keeps :attr:`watches` from accumulating
        dead entries in long-lived processes.
        """
        handle.close()
        try:
            self.watches.remove(handle)
            return True
        except ValueError:
            return False

    def refresh_watches(self) -> Dict[str, List[Dict]]:
        """Refresh every open watch; ``{query: current rows}``.

        Closed handles are skipped and dropped from :attr:`watches`.
        """
        live = [h for h in self.watches if not h.closed]
        if len(live) != len(self.watches):
            self.watches = live
        return {h.q: h.refresh() for h in live}

    def pump(self) -> int:
        """Batch-ingest all new lines from all inbox files.

        Lines are parsed and appended to the store's columnar buffer in
        one pass per file.  The archive is opened once per pump (not
        once per record as in the seed), but each accepted line is
        written *before* its callbacks run, so a crashing consumer
        never loses already-ingested records from the archive.
        """
        n = 0
        archive = (open(self.persist_path, "a", encoding="utf-8")
                   if self.persist_path is not None else None)
        try:
            for path in sorted(self.inbox_dir.glob("*.log")):
                reader = self._readers.get(path.name)
                if reader is None:
                    reader = self._readers[path.name] = TailReader(path)
                for line in reader.read_new_lines():
                    rec = parse_line(line)
                    if rec is None or not self.store.insert(rec):
                        continue
                    n += 1
                    if archive is not None:
                        archive.write(line + "\n")
                    for cb in self._on_record:
                        cb(rec)
        finally:
            if archive is not None:
                archive.close()
        if n and self.compaction_policy is not None:
            self.maybe_compact()
        if self.self_monitor is not None:
            self.self_monitor.maybe_pump()
        return n

    # ------------------------------------------------ index maintenance --
    def _seal_count(self) -> int:
        """Sealed-segment count across the backing store (any shape)."""
        st = self.store
        if hasattr(st, "_sealed"):
            return len(st._sealed)
        shards = getattr(st, "shards", None)
        if shards is not None and all(hasattr(s, "_sealed")
                                      for s in shards):
            return sum(len(s._sealed) for s in shards)
        return int(st.storage_stats().get("segments", 0))

    def maybe_compact(self, force: bool = False) -> Optional[Dict]:
        """Run the configured maintenance pass if it is due.

        Due means at least ``every_seals`` segments sealed since the
        last pass (``force=True`` skips the check).  Returns the stats
        dict (also kept as :attr:`last_maintenance`) or ``None`` when
        nothing ran.  :meth:`pump` calls this after every ingesting
        batch, so steady-state operation keeps the index compacted
        without an external scheduler — the Splunk index aging the
        paper leans on (§4.3) as a managed service.
        """
        pol = self.compaction_policy
        if pol is None:
            return None
        every = max(1, int(pol.get("every_seals", 16)))
        if not force and self._seal_count() - self._last_compact_seals < every:
            return None
        kw = {k: pol[k] for k in ("small_rows", "target_rows", "min_run",
                                  "compress") if k in pol}
        compact = getattr(self.store, "compact", None)
        if compact is None:
            compact = self.store.compact_all
        stats: Dict = {"compact": compact(**kw)}
        retention = pol.get("retention")
        if retention:
            stats["retention"] = self.store.apply_retention(**retention)
        self._last_compact_seals = self._seal_count()
        self.last_maintenance = stats
        return stats

    def load_archive(self, path: os.PathLike) -> int:
        """Fallback reader: replay a legacy consolidated line archive.

        Durable stores (``store_dir``) restore themselves on
        construction via mmap + WAL replay; this full re-parse remains
        only for archives written through ``persist_path``, and for
        migrating such an archive into a durable store (replaying into
        a store with a ``directory`` persists every accepted record).
        """
        try:
            with open(path, encoding="utf-8") as f:
                return self.store.ingest_lines(f)
        except OSError:
            return 0

    def close(self) -> None:
        """Release the store's WAL handle (durable stores)."""
        if self.self_monitor is not None:
            self.self_monitor.stop()
        if self._owns_service and self.query_service is not None:
            self.query_service.close()
        self.store.close()
