"""Central aggregation — the Splunk-ingest analog (paper §4.3).

The aggregator tails inbox stream files (fed by shippers/relays), parses
wire lines into records, deduplicates (transport is at-least-once), and
maintains an indexed in-memory store with optional on-disk persistence.
Detectors can be attached for streaming evaluation on ingest.
"""

from __future__ import annotations

import hashlib
import os
from collections import defaultdict
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set

from repro.core.schema import MetricRecord, encode_line, parse_line
from repro.core.transport import TailReader


class MetricStore:
    """Time-ordered, job/kind-indexed record store."""

    def __init__(self) -> None:
        self.records: List[MetricRecord] = []
        self._by_job: Dict[str, List[int]] = defaultdict(list)
        self._by_kind: Dict[str, List[int]] = defaultdict(list)
        self._seen: Set[bytes] = set()
        self.duplicates_dropped = 0

    def __len__(self) -> int:
        return len(self.records)

    def insert(self, rec: MetricRecord) -> bool:
        key = hashlib.blake2b(encode_line(rec).encode(), digest_size=12).digest()
        if key in self._seen:
            self.duplicates_dropped += 1
            return False
        self._seen.add(key)
        idx = len(self.records)
        self.records.append(rec)
        self._by_job[rec.job].append(idx)
        self._by_kind[rec.kind].append(idx)
        return True

    def ingest_lines(self, lines: Iterable[str]) -> int:
        n = 0
        for line in lines:
            rec = parse_line(line)
            if rec is not None and self.insert(rec):
                n += 1
        return n

    # ---------------------------------------------------------------- query
    def jobs(self) -> List[str]:
        return sorted(self._by_job)

    def kinds(self) -> List[str]:
        return sorted(self._by_kind)

    def select(self, job: Optional[str] = None, kind: Optional[str] = None,
               since: Optional[float] = None,
               until: Optional[float] = None) -> Iterator[MetricRecord]:
        if job is not None and kind is not None:
            idxs = sorted(set(self._by_job.get(job, ()))
                          & set(self._by_kind.get(kind, ())))
        elif job is not None:
            idxs = self._by_job.get(job, [])
        elif kind is not None:
            idxs = self._by_kind.get(kind, [])
        else:
            idxs = range(len(self.records))
        for i in idxs:
            rec = self.records[i]
            if since is not None and rec.ts < since:
                continue
            if until is not None and rec.ts >= until:
                continue
            yield rec

    def hosts(self, job: Optional[str] = None) -> List[str]:
        return sorted({r.host for r in self.select(job=job)})


class Aggregator:
    """Tails inbox files into a :class:`MetricStore`.

    ``inbox_dir`` receives one or more ``*.log`` stream files (one per
    shipper uplink).  ``persist_path`` optionally appends every accepted
    record to a consolidated archive (the "Splunk index" on disk; the
    paper keeps unlimited retention — so do we).
    """

    def __init__(self, inbox_dir: os.PathLike,
                 persist_path: Optional[os.PathLike] = None) -> None:
        self.inbox_dir = Path(inbox_dir)
        self.inbox_dir.mkdir(parents=True, exist_ok=True)
        self.store = MetricStore()
        self._readers: Dict[str, TailReader] = {}
        self.persist_path = Path(persist_path) if persist_path else None
        self._on_record: List[Callable[[MetricRecord], None]] = []

    def on_record(self, cb: Callable[[MetricRecord], None]) -> None:
        """Attach a streaming consumer (e.g. a detector bank)."""
        self._on_record.append(cb)

    def pump(self) -> int:
        """Ingest all new lines from all inbox files. Returns #records."""
        n = 0
        for path in sorted(self.inbox_dir.glob("*.log")):
            reader = self._readers.get(path.name)
            if reader is None:
                reader = self._readers[path.name] = TailReader(path)
            for line in reader.read_new_lines():
                rec = parse_line(line)
                if rec is None or not self.store.insert(rec):
                    continue
                n += 1
                if self.persist_path is not None:
                    with open(self.persist_path, "a", encoding="utf-8") as f:
                        f.write(line + "\n")
                for cb in self._on_record:
                    cb(rec)
        return n

    def load_archive(self, path: os.PathLike) -> int:
        """Replay a persisted archive into the store (restart path)."""
        try:
            with open(path, encoding="utf-8") as f:
                return self.store.ingest_lines(f)
        except OSError:
            return 0
