import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` must succeed for the 16x16 production mesh AND the
2x16x16 multi-pod mesh for every cell; ``memory_analysis()`` proves the
per-device footprint fits, ``cost_analysis()`` + HLO collective parsing
feed EXPERIMENTS.md §Dry-run / §Roofline.

Resumable: one JSON per cell under experiments/dryrun/<mesh>/; existing
cells are skipped unless --force.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
        --shape train_4k [--multi-pod] [--variant optimized]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, applicable, get_arch,
                           get_shape, skip_reason)
from repro.core import hlo as hlo_mod
from repro.core import hlo_cost as hlo_cost_mod
from repro.core.derived import TPU_V5E, roofline_terms
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh, mesh_num_chips
from repro.models.modality import batch_specs
from repro.models.transformer import Model, ModelOptions
from repro.optim.optimizer import AdamW
from repro.train.sharding import ShardingCtx, param_shardings
from repro.train.step import StepConfig, make_train_step
from repro.train.serve import make_serve_step

OUT_ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# Per-cell knobs for the §Perf hillclimb variants.  "baseline" is the
# paper-faithful configuration; named variants apply one optimization at a
# time (EXPERIMENTS.md §Perf documents hypothesis/result for each).
_BASE = dict(remat_policy="full", moe_group_size=2048, attn_chunk=2048,
             attn_q_chunk=2048, num_microbatches=4, ssm_chunk=0,
             seq_rule=("model",))

VARIANTS = {
    # Production default: full remat, 4 microbatches, Megatron-style
    # sequence-parallel residual stream (seq sharded over the model axis
    # between blocks — without it the per-layer saved activations are
    # replicated 16x over the model axis and big archs do not fit HBM;
    # the "no_seqpar" variant quantifies exactly that).
    "baseline": dict(_BASE),
    "no_seqpar": dict(_BASE, seq_rule=()),
    # §Perf hillclimb levers (one change each vs baseline):
    "remat_dots": dict(_BASE, remat_policy="dots"),
    "remat_none": dict(_BASE, remat_policy="none"),
    "microbatch1": dict(_BASE, num_microbatches=1),
    "microbatch2": dict(_BASE, num_microbatches=2),
    "microbatch8": dict(_BASE, num_microbatches=8),
    "moe_groups_8k": dict(_BASE, moe_group_size=8192),
    "moe_groups_512": dict(_BASE, moe_group_size=512),
    "attn_chunk_4k": dict(_BASE, attn_chunk=4096),
    "attn_chunk_1k": dict(_BASE, attn_chunk=1024),
    "ssm_chunk_128": dict(_BASE, ssm_chunk=128),
    "ssm_chunk_64": dict(_BASE, ssm_chunk=64),
}


def build_cell(arch_id: str, shape_id: str, multi_pod: bool,
               variant: str = "baseline"):
    """Lower + compile one cell; returns the result record dict."""
    arch = get_arch(arch_id)
    shape = get_shape(shape_id)
    knobs = VARIANTS[variant]
    if knobs.get("ssm_chunk"):
        import dataclasses
        arch = dataclasses.replace(arch, ssm_chunk=knobs["ssm_chunk"])
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_chips(mesh)
    ctx = ShardingCtx(mesh=mesh)
    seq_rule = knobs.get("seq_rule", ())
    ctx = ctx.with_rules(seq=tuple(seq_rule))
    model = Model(arch, ctx=ctx, options=ModelOptions(
        use_pallas=False,
        remat_policy=knobs["remat_policy"],
        attn_chunk=knobs["attn_chunk"],
        attn_q_chunk=knobs.get("attn_q_chunk", 4096),
        moe_group_size=knobs["moe_group_size"]))
    in_specs = specs_mod.input_specs(arch, shape)
    in_sh = specs_mod.input_shardings(ctx, in_specs)
    params_shape, _ = specs_mod.abstract_state(model)
    params_sh = param_shardings(params_shape, ctx)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            optimizer = AdamW()
            opt_shape = jax.eval_shape(optimizer.init, params_shape)
            opt_sh = specs_mod.opt_state_shardings(ctx, params_sh,
                                                   opt_shape)
            step = make_train_step(
                model, optimizer,
                StepConfig(num_microbatches=knobs["num_microbatches"]),
                grad_shardings=params_sh)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, None, in_sh),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_shape, opt_shape, None, in_specs)
            tokens_per_step = shape.global_batch * shape.seq_len
            model_flops = 6.0 * arch.active_param_count() * tokens_per_step
        elif shape.kind == "prefill":
            def prefill(params, batch):
                return model.prefill(params, batch)
            jitted = jax.jit(prefill, in_shardings=(params_sh, in_sh))
            lowered = jitted.lower(params_shape, in_specs)
            tokens_per_step = shape.global_batch * shape.seq_len
            model_flops = 2.0 * arch.active_param_count() * tokens_per_step
        else:  # decode
            serve = make_serve_step(model)
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            cache_sh = specs_mod.cache_shardings(ctx, model, cache_shape)
            jitted = jax.jit(serve,
                             in_shardings=(params_sh, in_sh, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_shape, in_specs, cache_shape)
            tokens_per_step = shape.global_batch
            model_flops = 2.0 * arch.active_param_count() * tokens_per_step
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    xla_cost = hlo_mod.cost_figures(compiled)      # per-device, loop-naive
    mem = hlo_mod.memory_figures(compiled)         # per-device
    try:
        text = compiled.as_text()
    except Exception:  # noqa: BLE001
        text = ""
    # loop-aware static analysis (scan bodies x trip counts) — see
    # core/hlo_cost.py; xla_cost counts while bodies once and is kept
    # for reference only.  Traffic tags attribute HBM bytes to the
    # attention-score / SSD-decay tensors that the Pallas kernels keep in
    # VMEM on real TPUs (XLA fallback materializes them).
    attn_chunk = knobs["attn_chunk"]
    ssm_q = arch.ssm_chunk

    q_chunk = knobs.get("attn_q_chunk", 4096)
    seq_like = {shape.seq_len, shape.seq_len + arch.num_meta_tokens,
                attn_chunk, q_chunk}

    def tag(result_type: str) -> str:
        shapes = hlo_cost_mod._shape_dims(result_type)
        for _, dims in shapes:
            if len(dims) >= 2:
                a, b = dims[-2], dims[-1]
                if (arch.has_attention and a in seq_like and b in seq_like
                        and a * b >= 1 << 20):
                    return "attn_scores"
                if (arch.ssm_state and a == ssm_q and b == ssm_q):
                    return "ssd_decay"
        return ""

    cost = hlo_cost_mod.analyze_hlo(text, tag_fn=tag)  # per-device program
    terms = roofline_terms(cost.flops * chips, cost.traffic_bytes * chips,
                           cost.collective_bytes * chips, chips,
                           TPU_V5E)
    # Pallas-kernel-adjusted memory term: score/decay tensors stay in VMEM
    kernel_saved = sum(cost.traffic_by_tag.values())
    memory_s_flash = max(cost.traffic_bytes - kernel_saved, 0.0) \
        / TPU_V5E.hbm_bw
    hbm_frac = mem["total_bytes_per_device"] / TPU_V5E.hbm_bytes
    rec = {
        "arch": arch_id,
        "shape": shape_id,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "variant": variant,
        "knobs": knobs,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # per-device figures from the loop-aware HLO analysis
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.traffic_bytes,
        "collective_bytes_per_device": cost.collective_bytes,
        "collective_counts": dict(cost.collective_counts),
        "collective_bytes_by_kind": dict(cost.collective_bytes_by_kind),
        "loop_trips": dict(cost.loop_trips),
        "xla_cost_analysis_raw": xla_cost,  # loop-naive, reference only
        "memory": mem,
        "hbm_frac_used": hbm_frac,
        "fits_hbm": hbm_frac <= 1.0,
        # roofline (§Roofline)
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "memory_s_flash": memory_s_flash,
        "traffic_by_tag": dict(cost.traffic_by_tag),
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "bound_step_s": terms.bound_s,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops
                               / max(cost.flops * chips, 1.0)),
        "tokens_per_step": tokens_per_step,
        "params_total": arch.param_count(),
        "params_active": arch.active_param_count(),
    }
    return rec


def out_path(arch_id, shape_id, multi_pod, variant) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    d = OUT_ROOT / mesh
    d.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    return d / f"{arch_id}__{shape_id}{suffix}.json"


def run_cell(arch_id, shape_id, multi_pod, variant="baseline",
             force=False) -> dict:
    path = out_path(arch_id, shape_id, multi_pod, variant)
    if path.exists() and not force:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    arch = get_arch(arch_id)
    shape = get_shape(shape_id)
    reason = skip_reason(arch, shape)
    if reason:
        rec = {"arch": arch_id, "shape": shape_id, "ok": False,
               "skipped": True, "reason": reason,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "variant": variant}
    else:
        try:
            rec = build_cell(arch_id, shape_id, multi_pod, variant)
        except Exception as exc:  # noqa: BLE001
            rec = {"arch": arch_id, "shape": shape_id, "ok": False,
                   "skipped": False,
                   "mesh": "2x16x16" if multi_pod else "16x16",
                   "variant": variant,
                   "error": f"{type(exc).__name__}: {exc}",
                   "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell on both meshes")
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in sorted(SHAPES):
                for mp in ((False, True) if not args.multi_pod
                           else (True,)):
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = ((False, True) if args.both_meshes
                  else ((args.multi_pod),))
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    for arch_id, shape_id, mp in cells:
        t0 = time.time()
        rec = run_cell(arch_id, shape_id, mp, args.variant, args.force)
        mesh = rec.get("mesh")
        if rec.get("skipped"):
            status = "SKIP (" + rec["reason"][:50] + "...)"
        elif rec.get("ok"):
            status = (f"ok  dom={rec['dominant']:<10} "
                      f"bound={rec['bound_step_s'] * 1e3:8.2f}ms "
                      f"hbm={rec['hbm_frac_used'] * 100:5.1f}% "
                      f"compile={rec.get('compile_s', 0):6.1f}s")
        else:
            status = "FAIL " + rec.get("error", "?")[:80]
            failures += 1
        print(f"[dryrun] {arch_id:26s} {shape_id:12s} {mesh:8s} "
              f"{rec.get('variant', ''):12s} {status} "
              f"({time.time() - t0:.1f}s)", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
