"""Device meshes.

``make_production_mesh`` is the target topology: one TPU v5e pod is a
16x16 = 256-chip ("data", "model") mesh; the multi-pod variant adds a
leading "pod" axis (2 pods = 512 chips).  Defined as functions so that
importing this module never touches jax device state (the dry-run must
set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.4.35 exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(model_axis: int = 1) -> Mesh:
    """Whatever this process actually has (CPU smoke / examples)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return _mk((n // model_axis, model_axis), ("data", "model"))


def mesh_num_chips(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
