"""Serving launcher: batched greedy decoding with monitoring + report.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --requests 8 --max-new 16 --workdir /tmp/serve-job --report
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import Aggregator, JobManifest, TrainMonitor, query
from repro.core.report import generate_report
from repro.core.transport import Shipper, StreamFileSink
from repro.launch.mesh import make_local_mesh, mesh_num_chips
from repro.models import Model, ModelOptions
from repro.train.serve import ServeEngine, ServeRequest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--workdir", default="/tmp/repro-serve")
    ap.add_argument("--monitor-interval", type=float, default=0.25)
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args(argv)

    workdir = Path(args.workdir)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_local_mesh()
    model = Model(cfg, options=ModelOptions(
        use_pallas=args.use_pallas, attn_chunk=256))
    params = model.init(jax.random.PRNGKey(0))
    job_id = f"serve.{cfg.name}.{os.getpid()}"
    manifest = JobManifest(job_id=job_id, app=cfg.name, shape="decode",
                           num_hosts=1, num_chips=mesh_num_chips(mesh),
                           started_ts=time.time())
    monitor = TrainMonitor(workdir, manifest,
                           interval_s=args.monitor_interval,
                           align_to_clock=False)
    engine = ServeEngine(model, params, batch_size=args.requests,
                         max_len=args.prompt_len + args.max_new + 8,
                         monitor=monitor)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        engine.submit(ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int64).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    monitor.stop()
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s)", flush=True)

    inbox = workdir / "inbox"
    Shipper(monitor.daemon.spool.root,
            StreamFileSink(inbox / "host0.log")).ship_once()
    if args.report:
        agg = Aggregator(inbox)
        agg.pump()
        out = generate_report(agg.store, job_id,
                              workdir / "reports" / job_id,
                              {job_id: manifest})
        rows = query(agg.store, f"search kind=perf job={job_id} "
                                "| stats max(steps_per_s)")
        print(f"[serve] report: {out}; {rows}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
