"""Production training launcher with integrated monitoring.

Runs a real (CPU-sized here, mesh-agnostic by construction) training job:
data pipeline -> jit'd train step -> checkpointing -> hpcmd monitoring ->
per-job report.  This is the end-to-end driver used by the examples and
by the elastic supervisor (launch/elastic.py), which restarts this
process on failure and relies on --resume auto-restore.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 100 --seq-len 128 --batch 8 --workdir /tmp/job --resume
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced
from repro.configs.base import ArchConfig
from repro.core import (Aggregator, JobManifest, TrainMonitor, query)
from repro.core.report import generate_report
from repro.core.transport import Shipper, StreamFileSink
from repro.data import Pipeline, SyntheticSource
from repro.data.pipeline import MemmapSource
from repro.models import Model, ModelOptions
from repro.optim import AdamW, OptimizerConfig
from repro.optim.optimizer import OptState
from repro.train import StepConfig, make_train_step
from repro.train.sharding import ShardingCtx, param_shardings
from repro.launch.mesh import make_local_mesh, mesh_num_chips


PRESET_100M = dict(num_layers=12, d_model=768, num_heads=12,
                   num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768)


def build_config(args) -> ArchConfig:
    cfg = get_arch(args.arch)
    if args.preset_100m:
        cfg = dataclasses.replace(cfg, **PRESET_100M,
                                  name=cfg.name + "-100m", dtype="float32")
    elif args.reduced:
        cfg = reduced(cfg)
    return cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny smoke-size variant of the arch")
    ap.add_argument("--preset-100m", action="store_true",
                    help="~100M-param variant of the arch family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/repro-train")
    ap.add_argument("--job-id", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--monitor-interval", type=float, default=2.0)
    ap.add_argument("--no-monitor", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots", "dots_no_batch"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--corpus", default=None,
                    help="binary uint32 token corpus (else synthetic)")
    ap.add_argument("--num-hosts", type=int, default=1,
                    help="simulated host count for pipeline sharding")
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--report", action="store_true",
                    help="generate the per-job report at the end")
    ap.add_argument("--fail-at-step", type=int, default=0,
                    help="crash deliberately (fault-tolerance demos)")
    args = ap.parse_args(argv)

    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    cfg = build_config(args)
    mesh = make_local_mesh(args.model_axis)
    ctx = ShardingCtx(mesh=mesh) if mesh_num_chips(mesh) > 1 else None
    model = Model(cfg, ctx=ctx, options=ModelOptions(
        use_pallas=args.use_pallas, remat_policy=args.remat,
        attn_chunk=max(256, args.seq_len // 2)))
    optimizer = AdamW(OptimizerConfig(lr=args.lr, warmup_steps=10,
                                      total_steps=max(args.steps, 11)))
    job_id = args.job_id or f"train.{cfg.name}.{os.getpid()}"
    manifest = JobManifest(
        job_id=job_id, user=os.environ.get("USER", "user"),
        app=cfg.name, shape=f"seq{args.seq_len}xb{args.batch}",
        num_hosts=args.num_hosts, num_chips=mesh_num_chips(mesh),
        mesh_shape=str(dict(mesh.shape)), started_ts=time.time())
    monitor = TrainMonitor(workdir, manifest,
                           host=f"host{args.host_id:04d}",
                           interval_s=args.monitor_interval,
                           enabled=not args.no_monitor)

    # ---- state init / resume ------------------------------------------
    ckpt = CheckpointManager(workdir / "ckpt", keep=3,
                             host_id=args.host_id)
    start_step = 0
    params = opt_state = None
    if args.resume:
        restored = ckpt.restore_latest()
        if restored is not None:
            start_step, tree, meta = restored
            params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
            o = tree["opt"]
            opt_state = OptState(count=jnp.asarray(o["count"]),
                                 mu=jax.tree_util.tree_map(
                                     jnp.asarray, o["mu"]),
                                 nu=jax.tree_util.tree_map(
                                     jnp.asarray, o["nu"]))
            print(f"[train] resumed from step {start_step}", flush=True)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = optimizer.init(params)

    # ---- data -----------------------------------------------------------
    if args.corpus:
        source = MemmapSource(args.corpus, cfg, args.seq_len, args.batch,
                              host_id=args.host_id,
                              num_hosts=args.num_hosts)
    else:
        source = SyntheticSource(cfg, args.seq_len, args.batch,
                                 host_id=args.host_id,
                                 num_hosts=args.num_hosts)
    pipe = Pipeline(source, stats=monitor.pipeline_stats,
                    start_step=start_step)

    # ---- compile + register with the monitor ---------------------------
    step_fn = make_train_step(model, optimizer, StepConfig(
        num_microbatches=args.microbatches,
        compress_grads=args.compress_grads))
    sample = {k: jnp.asarray(v) for k, v in source.get(start_step).items()}
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    lowered = jitted.lower(params, opt_state, None, sample)
    compiled = lowered.compile()
    figures = monitor.register_compiled(
        compiled, tokens_per_step=args.batch * args.seq_len)
    print(f"[train] compiled: {figures['flops']:.3e} flops/step/dev, "
          f"dominant={figures['dominant']}", flush=True)

    # ---- loop -----------------------------------------------------------
    t_last = time.time()
    for step in range(start_step, args.steps):
        if (args.fail_at_step and step == args.fail_at_step
                and start_step == 0):
            # transient fault: only the fresh (non-resumed) incarnation
            # crashes — restarted-from-checkpoint runs proceed
            print(f"[train] injected failure at step {step}", flush=True)
            os._exit(17)
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        wait = time.perf_counter() - t0
        params, opt_state, _, metrics = compiled(params, opt_state, None,
                                                 batch)
        loss = float(metrics["loss"])
        monitor.on_step(step + 1, loss=loss,
                        tokens=args.batch * args.seq_len)
        if (step + 1) % args.checkpoint_every == 0 \
                or step + 1 == args.steps:
            ckpt.save(step + 1, {
                "params": jax.tree_util.tree_map(np.asarray, params),
                "opt": {"count": np.asarray(opt_state.count),
                        "mu": jax.tree_util.tree_map(np.asarray,
                                                     opt_state.mu),
                        "nu": jax.tree_util.tree_map(np.asarray,
                                                     opt_state.nu)}})
        if (step + 1) % 10 == 0 or step == start_step:
            dt = time.time() - t_last
            t_last = time.time()
            print(f"[train] step {step + 1}/{args.steps} "
                  f"loss={loss:.4f} ({dt:.1f}s/10 steps)", flush=True)
    pipe.close()
    monitor.stop()

    # ---- ship logs + report --------------------------------------------
    inbox = workdir / "inbox"
    sink = StreamFileSink(inbox / f"host{args.host_id:04d}.log")
    Shipper(monitor.daemon.spool.root, sink,
            delete_shipped=False).ship_once()
    if args.report:
        agg = Aggregator(inbox)
        agg.pump()
        out = generate_report(agg.store, job_id, workdir / "reports" /
                              job_id, {job_id: manifest})
        rows = query(agg.store,
                     f"search kind=perf job={job_id} gflops>0 "
                     "| stats avg(gflops) avg(mfu) count")
        print(f"[train] report: {out}; perf summary: {rows}", flush=True)
    print("[train] done", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
