"""Abstract inputs + shardings for every (arch × shape × mesh) cell.

This is the glue the dry-run and the launcher share: ShapeDtypeStruct
stand-ins for all step arguments (no device allocation) plus the
NamedShardings that place them on the mesh.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models.modality import batch_specs
from repro.models.transformer import Model
from repro.optim.optimizer import AdamW
from repro.train.sharding import ShardingCtx, param_shardings


def batch_axes(ctx: ShardingCtx) -> Tuple[str, ...]:
    return tuple(a for a in ctx.rules.get("batch", ())
                 if ctx.mesh is not None and a in ctx.mesh.axis_names)


def data_shard_size(ctx: ShardingCtx) -> int:
    n = 1
    for a in batch_axes(ctx):
        n *= ctx.mesh.shape[a]
    return n


def input_specs(arch: ArchConfig, shape: ShapeConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of this cell."""
    return batch_specs(arch, shape.seq_len, shape.global_batch, shape.kind)


def input_shardings(ctx: ShardingCtx,
                    specs: Dict[str, jax.ShapeDtypeStruct]
                    ) -> Dict[str, NamedSharding]:
    """Batch dim over the data axes (replicated if not divisible)."""
    out = {}
    dsz = data_shard_size(ctx)
    baxes = batch_axes(ctx)
    spec_batch = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    for name, s in specs.items():
        if s.shape and s.shape[0] % max(dsz, 1) == 0 and dsz > 1:
            parts = (spec_batch,) + (None,) * (len(s.shape) - 1)
        else:
            parts = (None,) * len(s.shape)
        out[name] = NamedSharding(ctx.mesh, P(*parts))
    return out


def cache_shardings(ctx: ShardingCtx, model: Model, cache_shapes
                    ) -> Any:
    """Shardings for the decode-cache pytree.

    KV caches [L, B, S, KV, D]: batch over the data axes when divisible;
    otherwise (long-context, batch=1) the *sequence* is sharded over the
    data axes (flash-decoding style — XLA inserts the partial-softmax
    combines).  KV heads go over "model" when they divide.
    """
    mesh = ctx.mesh
    dsz = data_shard_size(ctx)
    baxes = batch_axes(ctx)
    spec_b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    msz = mesh.shape.get("model", 1)

    def leaf(path_key: str, s) -> NamedSharding:
        shp = s.shape
        if path_key == "pos" or not shp:
            return NamedSharding(mesh, P())
        if path_key in ("k", "v"):
            l, b, seq, kv, d = shp
            kv_ax = "model" if kv % msz == 0 and msz > 1 else None
            # when KV heads don't divide the model axis, shard the cache
            # SEQUENCE over it instead (flash-decoding style: partial
            # softmax stats combine via the collectives XLA inserts)
            seq_ax = None
            if kv_ax is None and msz > 1 and seq % msz == 0:
                seq_ax = "model"
            if b % max(dsz, 1) == 0 and dsz > 1:
                return NamedSharding(mesh, P(None, spec_b, seq_ax, kv_ax,
                                             None))
            # batch unshardable (long-context, B=1): sequence takes both
            # the data and (if free) the model axes
            if seq_ax is None:
                return NamedSharding(mesh, P(None, None, spec_b, kv_ax,
                                             None))
            both = tuple([a for a in (baxes if isinstance(
                baxes, tuple) else ((baxes,) if baxes else ()))] +
                ["model"])
            total = 1
            for a in both:
                total *= mesh.shape[a]
            if seq % total == 0:
                return NamedSharding(mesh, P(None, None, both, None, None))
            return NamedSharding(mesh, P(None, None, "model", kv_ax,
                                         None))
        if path_key == "ssm":
            l, b, h, p_, n = shp
            h_ax = "model" if h % msz == 0 and msz > 1 else None
            if b % max(dsz, 1) == 0 and dsz > 1:
                return NamedSharding(mesh, P(None, spec_b, h_ax, None,
                                             None))
            return NamedSharding(mesh, P(None, None, h_ax, None, None))
        if path_key == "conv":
            l, b, w, c = shp
            c_ax = "model" if c % msz == 0 and msz > 1 else None
            if b % max(dsz, 1) == 0 and dsz > 1:
                return NamedSharding(mesh, P(None, spec_b, None, c_ax))
            return NamedSharding(mesh, P(None, None, None, c_ax))
        if path_key in ("xk", "xv"):
            n, b, t, kv, d = shp
            kv_ax = "model" if kv % msz == 0 and msz > 1 else None
            if b % max(dsz, 1) == 0 and dsz > 1:
                return NamedSharding(mesh, P(None, spec_b, None, kv_ax,
                                             None))
            return NamedSharding(mesh, P(None, None, None, kv_ax, None))
        return NamedSharding(mesh, P())

    return {k: leaf(k, v) for k, v in cache_shapes.items()}


def abstract_state(model: Model, optimizer: Optional[AdamW] = None):
    """eval_shape the params (and optimizer state) — no allocation."""
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if optimizer is None:
        return params, None
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state


def opt_state_shardings(ctx: ShardingCtx, params_sh, opt_state_shape):
    """Optimizer state mirrors params (count replicated)."""
    from repro.optim.optimizer import OptState
    mesh = ctx.mesh
    return OptState(
        count=NamedSharding(mesh, P()),
        mu=params_sh,
        nu=params_sh,
    )
