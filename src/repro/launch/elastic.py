"""Elastic supervisor: fault-tolerant, monitor-driven job control.

The paper's §4.6 automation closed-loop, applied to training:

* runs the training launcher as a child process;
* restarts it (``--resume``: auto-restore from the latest committed
  checkpoint) on crashes, up to ``max_restarts``;
* tails the monitoring inbox while the job runs; a **hang** event from the
  streaming detector kills and restarts the child (the paper's
  hanging-job case study, but automated);
* supports elastic downscaling: on repeated failures the next incarnation
  can run with fewer simulated hosts (``--shrink-on-failure``), mirroring
  re-meshing around dead nodes.

This is a control-plane simulation: one host process stands in for the
fleet, but every code path (checkpoint restore, manifest rewrite, detector
-> restart wiring) is the real implementation.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import time
from pathlib import Path
from typing import List, Optional

from repro.core.aggregator import Aggregator
from repro.core.detectors import DetectorBank


class Supervisor:
    def __init__(self, train_args: List[str], workdir: Path,
                 max_restarts: int = 3, hang_poll_s: float = 1.0,
                 shrink_on_failure: bool = False,
                 num_hosts: int = 1) -> None:
        self.train_args = train_args
        self.workdir = Path(workdir)
        self.max_restarts = max_restarts
        self.hang_poll_s = hang_poll_s
        self.shrink_on_failure = shrink_on_failure
        self.num_hosts = num_hosts
        self.restarts = 0
        self.events: List[str] = []

    def _spawn(self) -> subprocess.Popen:
        cmd = [sys.executable, "-m", "repro.launch.train",
               *self.train_args,
               "--workdir", str(self.workdir),
               "--num-hosts", str(self.num_hosts),
               "--resume"]
        print(f"[elastic] spawn (restart {self.restarts}): "
              f"{' '.join(cmd[-8:])}", flush=True)
        return subprocess.Popen(cmd)

    def run(self) -> int:
        from repro.core.anomaly import AnomalyBank
        agg = Aggregator(self.workdir / "inbox")
        bank = DetectorBank()
        anomalies = AnomalyBank()
        agg.on_record(bank.feed)
        agg.on_record(lambda rec: [
            print(f"[elastic] anomaly: {e.message}", flush=True)
            for e in anomalies.feed(rec)])
        while True:
            child = self._spawn()
            killed_for_hang = False
            while child.poll() is None:
                time.sleep(self.hang_poll_s)
                agg.pump()
                hang_events = [e for e in bank.events
                               if e.detector == "hang"]
                if hang_events:
                    self.events.append("hang->restart")
                    print("[elastic] hang detected by monitor — "
                          "restarting child", flush=True)
                    child.kill()
                    child.wait()
                    killed_for_hang = True
                    bank.events.clear()
                    break
            rc = child.returncode if not killed_for_hang else -9
            if rc == 0:
                print("[elastic] job completed", flush=True)
                return 0
            self.restarts += 1
            self.events.append(f"exit({rc})")
            if self.restarts > self.max_restarts:
                print("[elastic] restart budget exhausted", flush=True)
                return 1
            if self.shrink_on_failure and self.num_hosts > 1:
                self.num_hosts -= 1
                print(f"[elastic] downscaling to {self.num_hosts} hosts",
                      flush=True)
            print(f"[elastic] child exited rc={rc}; restarting from "
                  "latest checkpoint", flush=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--shrink-on-failure", action="store_true")
    ap.add_argument("train_args", nargs=argparse.REMAINDER,
                    help="arguments forwarded to repro.launch.train "
                         "(prefix with --)")
    args = ap.parse_args(argv)
    extra = [a for a in args.train_args if a != "--"]
    sup = Supervisor(extra, Path(args.workdir),
                     max_restarts=args.max_restarts,
                     num_hosts=args.num_hosts,
                     shrink_on_failure=args.shrink_on_failure)
    return sup.run()


if __name__ == "__main__":
    sys.exit(main())
