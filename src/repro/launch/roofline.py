"""Aggregate dry-run cell JSONs into the §Roofline / §Dry-run tables.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16]
                                                   [--variants]

Reads experiments/dryrun/<mesh>/*.json, prints a markdown table with the
three roofline terms per (arch x shape), dominant bottleneck, MODEL_FLOPS
ratio, HBM fit, and the one-line "what would move the dominant term".
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, include_variants: bool = False) -> List[Dict]:
    out = []
    d = ROOT / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        try:
            rec = json.load(open(p, encoding="utf-8"))
        except ValueError:
            continue
        if not include_variants and rec.get("variant",
                                            "baseline") != "baseline":
            continue
        out.append(rec)
    out.sort(key=lambda r: (r.get("arch", ""), SHAPE_ORDER.index(
        r["shape"]) if r.get("shape") in SHAPE_ORDER else 9,
        r.get("variant", "")))
    return out


def advice(rec: Dict) -> str:
    dom = rec.get("dominant")
    tags = rec.get("traffic_by_tag", {})
    if dom == "memory":
        if tags.get("attn_scores", 0) > 0.2 * rec.get(
                "bytes_per_device", 1) :
            return "flash kernel keeps scores in VMEM"
        if tags.get("ssd_decay", 0) > 0.1 * rec.get("bytes_per_device", 1):
            return "SSD Pallas kernel keeps decay tiles in VMEM"
        return "smaller tiles / fewer saved buffers"
    if dom == "collective":
        kinds = rec.get("collective_bytes_by_kind", {})
        if kinds:
            top = max(kinds, key=kinds.get)
            return f"reduce {top} volume (sharding/overlap)"
        return "resharding"
    return "near roofline; overlap comm"


def fmt_row(rec: Dict) -> str:
    if rec.get("skipped"):
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | skip |"
                f" {rec.get('reason', '')[:48]} |")
    if not rec.get("ok"):
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | FAIL |"
                f" {rec.get('error', '')[:48]} |")
    c, m, k = rec["compute_s"], rec["memory_s"], rec["collective_s"]
    mf = rec.get("memory_s_flash", m)
    fit = f"{rec['hbm_frac_used'] * 100:.0f}%"
    ratio = rec.get("useful_flops_ratio", 0.0)
    return (f"| {rec['arch']} | {rec['shape']} | {c * 1e3:.1f} | "
            f"{m * 1e3:.1f} ({mf * 1e3:.1f}) | {k * 1e3:.1f} | "
            f"{ratio:.2f} | {rec['dominant'][:4]} {fit} | "
            f"{advice(rec)} |")


def table(mesh: str, include_variants: bool = False) -> str:
    recs = load(mesh, include_variants)
    lines = [
        f"### Mesh {mesh} ({recs[0]['chips'] if recs and recs[0].get('chips') else '?'} chips)",
        "",
        "| arch | shape | compute ms | memory ms (flash-adj) | "
        "collective ms | 6ND/HLO | dominant, HBM | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if include_variants or rec.get("variant", "baseline") == "baseline":
            name = rec["arch"]
            if rec.get("variant", "baseline") != "baseline":
                name += f" [{rec['variant']}]"
                rec = dict(rec, arch=name)
            lines.append(fmt_row(rec))
    return "\n".join(lines) + "\n"


def variant_table(arch: str, shape: str) -> str:
    """All variants for one cell — the §Perf iteration log rows."""
    rows = []
    for mesh in ("16x16",):
        d = ROOT / mesh
        for p in sorted(d.glob(f"{arch}__{shape}*.json")):
            try:
                rec = json.load(open(p, encoding="utf-8"))
            except ValueError:
                continue
            if rec.get("ok"):
                rows.append(rec)
    rows.sort(key=lambda r: r.get("bound_step_s", 9e9))
    lines = [f"#### {arch} × {shape} — variants by bound step time",
             "",
             "| variant | compute ms | memory ms | collective ms | "
             "bound ms | HBM |",
             "|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r.get('variant', 'baseline')} | {r['compute_s'] * 1e3:.1f} "
            f"| {r['memory_s'] * 1e3:.1f} | {r['collective_s'] * 1e3:.1f} "
            f"| {r['bound_step_s'] * 1e3:.1f} "
            f"| {r['hbm_frac_used'] * 100:.0f}% |")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--variants", action="store_true")
    ap.add_argument("--cell", nargs=2, metavar=("ARCH", "SHAPE"))
    args = ap.parse_args()
    if args.cell:
        print(variant_table(*args.cell))
        return
    meshes = [args.mesh] if args.mesh else ["16x16", "2x16x16"]
    for mesh in meshes:
        print(table(mesh, args.variants))


if __name__ == "__main__":
    main()
