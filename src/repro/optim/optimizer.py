"""AdamW with warmup-cosine schedule and global-norm clipping.

Self-contained (no optax): state is a pytree mirroring params (f32 m/v),
so the parameter sharding rules apply unchanged to optimizer state —
ZeRO-style sharded optimizer comes for free from the FSDP param specs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: OptimizerConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


class AdamW:
    def __init__(self, cfg: Optional[OptimizerConfig] = None):
        self.cfg = cfg or OptimizerConfig()

    def init(self, params) -> OptState:
        zeros = lambda t: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return OptState(count=jnp.zeros((), jnp.int32),
                        mu=zeros(params), nu=zeros(params))

    def update(self, grads, state: OptState, params
               ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        count = state.count + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = lr_at(cfg, count)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mh = m / (1 - cfg.b1 ** count.astype(jnp.float32))
            vh = v / (1 - cfg.b2 ** count.astype(jnp.float32))
            step = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = jax.tree_util.tree_leaves(state.mu)
        flat_v = jax.tree_util.tree_leaves(state.nu)
        flat_p = jax.tree_util.tree_leaves(params)
        new_p, new_m, new_v = [], [], []
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            np_, nm, nv = upd(g, m, v, p)
            new_p.append(np_)
            new_m.append(nm)
            new_v.append(nv)
        unflatten = treedef.unflatten
        metrics = {"grad_norm": gnorm, "lr": lr}
        return (unflatten(new_p),
                OptState(count=count, mu=unflatten(new_m),
                         nu=unflatten(new_v)),
                metrics)
