"""Gradient compression for cross-pod data parallelism (beyond-paper).

Cross-pod links (DCN class) are the scarcest bandwidth in a multi-pod
mesh.  We provide int8 error-feedback quantization:

* :func:`quantize` / :func:`dequantize` — per-tensor symmetric int8 with a
  f32 scale; the quantization residual is carried in an error-feedback
  buffer so the compression bias vanishes over steps (1-bit-Adam lineage).
* :func:`compressed_psum` — a ``shard_map``-compatible mean-reduction that
  sums int8 payloads (as int32 to avoid overflow) over a named axis; on a
  real fabric only the int8 payload + scale crosses the link (4x fewer
  bytes than f32, 2x fewer than bf16).

The trainer applies this to the *pod* axis only; within-pod reductions
stay full precision (ICI is plentiful).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray, err: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization with error feedback.

    Returns (q int8, scale f32 scalar, new_err f32)."""
    xf = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean-reduce ``x`` over ``axis_name`` with int8 payloads.

    Must run inside shard_map with ``axis_name`` bound.  The scale is
    max-reduced first so all participants share one grid; payload sums in
    int32."""
    xf = x.astype(jnp.float32) + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(xf)) / 127.0, 1e-12)
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    return mean.astype(x.dtype), new_err


def init_error_buffers(tree) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def compress_tree(grads, errors) -> Tuple[Any, Any]:
    """Quantize-dequantize every leaf with error feedback (single-process
    simulation of the wire format; bit-exact with the sharded path when
    the axis has one participant)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize(g, e)
        out_g.append(dequantize(q, s).astype(g.dtype))
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)
