"""Optimizer substrate: AdamW + schedules + gradient compression."""
from repro.optim.optimizer import AdamW, OptimizerConfig, OptState, lr_at
