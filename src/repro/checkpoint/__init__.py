"""Fault-tolerant checkpointing."""
from repro.checkpoint.checkpoint import CheckpointManager
