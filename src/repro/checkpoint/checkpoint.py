"""Fault-tolerant checkpointing: sharded .npz + manifest, atomic commit,
retention, auto-resume.

Layout::

    <root>/step_000000400/
        manifest.json            # tree structure, shapes, dtypes, step
        shard_00000.npz          # flat {leaf_path: array} for this host
        COMMITTED                # atomic completion marker (written last)

Writes go to ``.tmp-step_*`` and are renamed into place only after the
marker file is in the directory, so a crash mid-save can never produce a
checkpoint that restore() would accept.  ``restore_latest`` walks
checkpoints newest-first and skips uncommitted/corrupt ones — the
restart path after a node failure (launch/elastic.py) leans on this.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MARKER = "COMMITTED"


def _flatten_with_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten_with_paths(
                tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten_with_paths(v, f"{prefix}/{i}"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for name in tree._fields:
            out.update(_flatten_with_paths(
                getattr(tree, name), f"{prefix}/{name}"))
    else:
        out[prefix] = tree
    return out


def _tree_structure(tree):
    if isinstance(tree, dict):
        return {k: _tree_structure(v) for k, v in sorted(tree.items())}
    if hasattr(tree, "_fields"):
        return {"__namedtuple__": type(tree).__name__,
                "fields": {n: _tree_structure(getattr(tree, n))
                           for n in tree._fields}}
    if isinstance(tree, (tuple, list)):
        return [_tree_structure(v) for v in tree]
    return None  # leaf


def _rebuild(structure, flat: Dict[str, np.ndarray], prefix="",
             namedtuple_types: Optional[Dict[str, Any]] = None):
    if isinstance(structure, dict):
        if "__namedtuple__" in structure:
            fields = structure["fields"]
            vals = {n: _rebuild(fields[n], flat,
                                f"{prefix}/{n}" if prefix else n,
                                namedtuple_types)
                    for n in fields}
            tname = structure["__namedtuple__"]
            if namedtuple_types and tname in namedtuple_types:
                return namedtuple_types[tname](**vals)
            import collections
            nt = collections.namedtuple(tname, list(vals))
            return nt(**vals)
        return {k: _rebuild(v, flat, f"{prefix}/{k}" if prefix else k,
                            namedtuple_types)
                for k, v in structure.items()}
    if isinstance(structure, list):
        return tuple(_rebuild(v, flat, f"{prefix}/{i}", namedtuple_types)
                     for i, v in enumerate(structure))
    return flat[prefix]


class CheckpointManager:
    def __init__(self, root: os.PathLike, keep: int = 3,
                 host_id: int = 0) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any,
             extra_meta: Optional[Dict] = None) -> Path:
        name = f"step_{step:09d}"
        tmp = self.root / f".tmp-{name}-{self.host_id}"
        final = self.root / name
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten_with_paths(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(tmp / f"shard_{self.host_id:05d}.npz", **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "structure": _tree_structure(tree),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
            "meta": extra_meta or {},
        }
        with open(tmp / "manifest.json", "w", encoding="utf-8") as f:
            json.dump(manifest, f)
        (tmp / MARKER).write_text(str(step))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        ckpts = self.list_steps()
        for step in ckpts[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{step:09d}",
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> List[int]:
        steps = []
        for p in self.root.glob("step_*"):
            if (p / MARKER).exists():
                try:
                    steps.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def restore(self, step: int,
                namedtuple_types: Optional[Dict[str, Any]] = None
                ) -> Tuple[int, Any, Dict]:
        path = self.root / f"step_{step:09d}"
        if not (path / MARKER).exists():
            raise FileNotFoundError(f"checkpoint {path} not committed")
        with open(path / "manifest.json", encoding="utf-8") as f:
            manifest = json.load(f)
        flat: Dict[str, np.ndarray] = {}
        for shard in sorted(path.glob("shard_*.npz")):
            with np.load(shard) as z:
                for k in z.files:
                    flat[k] = z[k]
        tree = _rebuild(manifest["structure"], flat,
                        namedtuple_types=namedtuple_types)
        return manifest["step"], tree, manifest.get("meta", {})

    def restore_latest(self,
                       namedtuple_types: Optional[Dict[str, Any]] = None
                       ) -> Optional[Tuple[int, Any, Dict]]:
        for step in reversed(self.list_steps()):
            try:
                return self.restore(step, namedtuple_types)
            except (OSError, KeyError, ValueError):
                continue  # corrupt — fall back to the previous one
        return None
