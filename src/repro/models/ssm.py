"""Mamba-2 SSD (state-space duality) blocks — attention-free sequence mixing.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; within a chunk the computation is a masked
matmul ("attention-like", MXU-friendly), across chunks a tiny recurrence
carries the [H, P, N] state.  This TPU-native formulation is exactly why
SSD exists — the quadratic-in-chunk part maps onto the systolic array, and
the recurrence is O(S/Q) sequential steps on small tensors.

Decode is the classic O(1) recurrent update.  The intra-chunk matmuls are
also available as a Pallas kernel (repro.kernels.ssd_scan).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm, trunc_normal


# ----------------------------------------------------------------- SSD core

def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] (i>=j),
    -inf elsewhere.  a: [..., Q] -> [..., Q, Q]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b_mat: jnp.ndarray, c_mat: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x: [B,S,H,P] inputs; dt: [B,S,H] (post-softplus); a_log: [H];
    b_mat/c_mat: [B,S,N] (single group, broadcast over heads);
    h0: optional initial state [B,H,P,N].
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    bsz, s_orig, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s_orig) % chunk
    if pad:
        # dt=0 padding is exact: decay exp(0)=1, contribution x*dt=0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    a = (-jnp.exp(a_log.astype(jnp.float32)))[None, None, :] * dt.astype(
        jnp.float32)                                   # [B,S,H] log-decay
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])

    # chunked views
    def chunked(t, trailing):
        return t.reshape((bsz, nc, chunk) + trailing)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)   # [B,H,C,Q]
    xc = chunked(xdt, (h, p))                                  # [B,C,Q,H,P]
    bc = chunked(b_mat.astype(jnp.float32), (n,))              # [B,C,Q,N]
    cc = chunked(c_mat.astype(jnp.float32), (n,))              # [B,C,Q,N]

    a_cs = jnp.cumsum(ac, axis=-1)                             # [B,H,C,Q]

    # 1. intra-chunk ("diagonal block") — quadratic in Q, matmul-shaped
    l_mat = jnp.exp(segsum(ac))                                # [B,H,C,Q,Q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, l_mat, xc)

    # 2. per-chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)              # [B,H,C,Q]
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence (tiny sequential scan over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])                       # [B,H,C]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    def step(carry, xs):
        st, dec = xs                                           # [B,H,P,N],[B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit state ENTERING chunk

    (h_final, prev_states) = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4),                      # [C,B,H,P,N]
         chunk_decay.transpose(2, 0, 1)))                      # [C,B,H]
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [B,C,H,P,N]

    # 4. inter-chunk output
    state_decay_out = jnp.exp(a_cs)                            # [B,H,C,Q]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), h_final


def ssd_decode_step(h: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    a_log: jnp.ndarray, b_mat: jnp.ndarray,
                    c_mat: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step.  h: [B,H,P,N]; x: [B,H,P]; dt: [B,H];
    b_mat/c_mat: [B,N].  Returns (y [B,H,P], h')."""
    h = h.astype(jnp.float32)
    dec = jnp.exp(-jnp.exp(a_log.astype(jnp.float32))[None, :]
                  * dt.astype(jnp.float32))                    # [B,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, b_mat.astype(jnp.float32))
    h_new = h * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_mat.astype(jnp.float32))
    return y.astype(x.dtype), h_new


# ------------------------------------------------------------------ conv1d

def conv1d_causal(x: jnp.ndarray, w: jnp.ndarray,
                  hist: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  x: [B,S,C]; w: [W,C]; hist: [B,W-1,C]
    (carried decode/prefill state; zeros when None)."""
    width = w.shape[0]
    if hist is None:
        hist = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out


def conv1d_step(x: jnp.ndarray, w: jnp.ndarray, hist: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step.  x: [B,C]; hist: [B,W-1,C]."""
    width = w.shape[0]
    xp = jnp.concatenate([hist, x[:, None, :]], axis=1)        # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", xp, w)
    return y, xp[:, 1:]


# ------------------------------------------------------------- mamba2 block

def init_ssm_params(key, cfg, dtype) -> Dict[str, jnp.ndarray]:
    """Parameters for one Mamba-2 mixer (pre-norm included)."""
    d, di = cfg.d_model, cfg.d_inner
    n, nh = cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    proj_out = 2 * di + 2 * n + nh   # z, xBC, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm_scale": jnp.zeros((d,), dtype),
        "in_proj": dense_init(k1, (d, proj_out), dtype),
        "conv_w": trunc_normal(k2, (cfg.conv_width, conv_ch),
                               1.0 / math.sqrt(cfg.conv_width), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm_scale": jnp.zeros((di,), dtype),
        "out_proj": dense_init(k3, (di, d), dtype),
    }


def _split_proj(cfg, proj):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    x_bc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, x_bc, dt


def _split_xbc(cfg, x_bc):
    di, n = cfg.d_inner, cfg.ssm_state
    return x_bc[..., :di], x_bc[..., di:di + n], x_bc[..., di + n:]


def apply_ssm_mixer(params, cfg, u: jnp.ndarray,
                    state: Optional[Dict[str, jnp.ndarray]] = None,
                    return_state: bool = False,
                    use_pallas: bool = False):
    """Sequence-mode Mamba-2 mixer (train/prefill).

    u: [B,S,d_model] (already pre-normed by caller or not — this function
    applies its own pre-norm).  Returns y [B,S,d_model] (+ state dict).
    """
    bsz, s, _ = u.shape
    nh, p = cfg.ssm_heads, cfg.ssm_headdim
    x_in = rms_norm(u, params["norm_scale"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", x_in, params["in_proj"])
    z, x_bc_pre, dt_raw = _split_proj(cfg, proj)
    hist0 = state["conv"] if state is not None else None
    x_bc = conv1d_causal(x_bc_pre, params["conv_w"], hist0)
    x_bc = jax.nn.silu(x_bc)
    x, b_mat, c_mat = _split_xbc(cfg, x_bc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    xh = x.reshape(bsz, s, nh, p)
    h0 = state["ssm"] if state is not None else None
    if use_pallas:
        from repro.kernels.ops import ssd_op
        y, h_final = ssd_op(xh, dt, params["a_log"], b_mat, c_mat,
                            chunk=min(cfg.ssm_chunk, s), h0=h0)
    else:
        y, h_final = ssd_chunked(xh, dt, params["a_log"], b_mat, c_mat,
                                 min(cfg.ssm_chunk, s), h0)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] \
        * xh
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if not return_state:
        return out
    width = cfg.conv_width
    pad = jnp.zeros((bsz, width - 1, x_bc_pre.shape[-1]), x_bc_pre.dtype)
    if hist0 is not None:
        pad = hist0
    conv_hist = jnp.concatenate([pad, x_bc_pre], axis=1)[:, -(width - 1):]
    return out, {"ssm": h_final, "conv": conv_hist}


def apply_ssm_decode(params, cfg, u: jnp.ndarray,
                     state: Dict[str, jnp.ndarray]):
    """One-token decode.  u: [B,1,d_model]; state: {ssm:[B,H,P,N],
    conv:[B,W-1,C]}.  Returns (y [B,1,d_model], new_state)."""
    bsz = u.shape[0]
    nh, p = cfg.ssm_heads, cfg.ssm_headdim
    x_in = rms_norm(u[:, 0], params["norm_scale"], cfg.norm_eps)
    proj = jnp.einsum("bd,de->be", x_in, params["in_proj"])
    z, x_bc, dt_raw = _split_proj(cfg, proj)
    x_bc, conv_hist = conv1d_step(x_bc, params["conv_w"], state["conv"])
    x_bc = jax.nn.silu(x_bc)
    x, b_mat, c_mat = _split_xbc(cfg, x_bc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, :])
    xh = x.reshape(bsz, nh, p)
    y, h_new = ssd_decode_step(state["ssm"], xh, dt, params["a_log"],
                               b_mat, c_mat)
    y = y + params["d_skip"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(bsz, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm_scale"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, {"ssm": h_new, "conv": conv_hist}


def init_ssm_state(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }
