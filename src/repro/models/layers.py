"""Shared building blocks: norms, rotary embeddings, gated MLP, embedding."""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap*tanh(x/cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: jnp.ndarray | float = 10_000.0) -> jnp.ndarray:
    """Rotary position embedding.

    x: [..., S, H, D]; positions: [..., S] (broadcastable).  ``theta`` may
    be a traced scalar (per-layer theta inside a scanned stack).
    """
    d_half = x.shape[-1] // 2
    freq_exp = jnp.arange(d_half, dtype=jnp.float32) / d_half
    theta = jnp.asarray(theta, dtype=jnp.float32)
    inv_freq = theta ** (-freq_exp)                     # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # [...,S,D/2]
    angles = angles[..., :, None, :]                    # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :d_half], x[..., d_half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gated_mlp(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
              w_down: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """SwiGLU / GeGLU feed-forward."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    if act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        g = jax.nn.silu(g)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 scale_by_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0)
    if scale_by_dim:
        out = out * jnp.asarray(math.sqrt(table.shape[1]), out.dtype)
    return out


def unembed(x: jnp.ndarray, table_or_head: jnp.ndarray, tied: bool,
            final_cap: float = 0.0) -> jnp.ndarray:
    """Project to vocabulary logits (in f32 for loss stability)."""
    x = x.astype(jnp.float32)
    w = table_or_head.astype(jnp.float32)
    if tied:
        logits = jnp.einsum("...d,vd->...v", x, w)
    else:
        logits = jnp.einsum("...d,dv->...v", x, w)
    return softcap(logits, final_cap)


# ------------------------------------------------------------------- inits --

def trunc_normal(key, shape, std: float, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return trunc_normal(key, shape, 1.0 / math.sqrt(max(fan, 1)), dtype)
