"""Attention: GQA with causal/sliding-window masks, softcap, online-softmax
chunking, and decode against global or ring (sliding-window) KV caches.

Two execution paths:

* **direct** — one einsum, for short sequences (and smoke tests);
* **chunked** — ``lax.scan`` over KV blocks with online softmax (running
  max / normalizer), the XLA-level flash-attention formulation.  This is
  what keeps prefill_32k temp memory bounded, and its Pallas twin in
  ``repro.kernels.flash_attention`` is the TPU fast path.

The sliding window is a *traced* scalar so that gemma-style local/global
alternation can live inside one scanned layer stack (global layers simply
pass window = 2^30).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import softcap as _softcap

NEG_INF = -1e30
GLOBAL_WINDOW = jnp.int32(1 << 30)


def _mask(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, causal: bool,
          window) -> jnp.ndarray:
    """[..., Sq, Skv] boolean validity mask from positions.

    kv_pos < 0 marks invalid (padded / not-yet-filled) slots.
    """
    q = q_pos[..., :, None].astype(jnp.int32)
    k = kv_pos[..., None, :].astype(jnp.int32)
    valid = k >= 0
    if causal:
        valid &= k <= q
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        valid &= (q - k) < w
    return valid


def _direct_attend(q, k, v, q_pos, kv_pos, *, causal, window, cap, scale):
    b, sq, n_kv, g, d = q.shape
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, cap)
    mask = _mask(q_pos, kv_pos, causal, window)          # [b?, sq, skv]
    while mask.ndim < logits.ndim:
        mask = mask[..., None, :, :] if mask.ndim >= 2 else mask
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out


def _chunk_kv(k, v, kv_pos, chunk):
    b, skv, n_kv, d = k.shape
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad),), constant_values=-1)
    k = k.reshape(b, n_chunks, chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    kv_pos = kv_pos.reshape(n_chunks, chunk)
    return k, v, kv_pos, pad


def _chunk_logits(q, kc, kp, q_pos, causal, window, cap, scale):
    """[b, n_kv, g, sq, chunk] masked (soft-capped) logits for one chunk.
    Also returns the pre-cap scores (needed for the softcap derivative)."""
    raw = jnp.einsum("bqhgd,bkhd->bhgqk", q, kc,
                     preferred_element_type=jnp.float32) * scale
    capped = _softcap(raw, cap)
    mask = _mask(q_pos, kp, causal, window)              # [sq, chunk]
    logits = jnp.where(mask[None, None, None], capped, NEG_INF)
    return logits, capped, mask


def _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, cap, scale, chunk):
    """Online-softmax forward.  Returns (out [b,h,g,sq,d], lse)."""
    b, sq, n_kv, g, d = q.shape
    kcs, vcs, kps, _ = _chunk_kv(k, v, kv_pos, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        logits, _, _ = _chunk_logits(q, kc, kp, q_pos, causal, window,
                                     cap, scale)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kcs, vcs, kps))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)                            # [b,h,g,sq]
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _flash_attend(q, k, v, q_pos, kv_pos, window, causal, cap, scale,
                  chunk):
    """Memory-bounded chunked attention with a flash-style custom VJP.

    Without this, ``jax.lax.scan`` AD saves the per-chunk probability
    tensors for the backward pass — O(Sq x Skv) per layer.  The custom
    backward recomputes each chunk's logits from (q, k, lse) instead,
    exactly like the Pallas/TPU flash backward.  ``window`` is an int32
    scalar array (may be traced; 2^30 disables), gradient None.
    """
    out, _ = _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, cap,
                        scale, chunk)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def _flash_attend_fwd(q, k, v, q_pos, kv_pos, window, causal, cap, scale,
                      chunk):
    out, lse = _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, cap,
                          scale, chunk)
    out_t = out.transpose(0, 3, 1, 2, 4).astype(q.dtype)
    return out_t, (q, k, v, q_pos, kv_pos, window, out, lse)


def _flash_attend_bwd(causal, cap, scale, chunk, res, g_out):
    q, k, v, q_pos, kv_pos, window, out, lse = res
    b, sq, n_kv, gq, d = q.shape
    skv = k.shape[1]
    do = g_out.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # [b,h,g,sq,d]
    delta = jnp.sum(do * out, axis=-1)                       # [b,h,g,sq]
    kcs, vcs, kps, _ = _chunk_kv(k, v, kv_pos, chunk)
    qf = q.astype(jnp.float32)

    def body(dq_acc, xs):
        kc, vc, kp = xs
        logits, capped, mask = _chunk_logits(qf, kc, kp, q_pos, causal,
                                             window, cap, scale)
        p = jnp.exp(logits - lse[..., None])                 # [b,h,g,sq,c]
        dv_c = jnp.einsum("bhgqk,bhgqd->bkhd", p, do)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", do,
                        vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None])                     # d wrt capped
        if cap:
            ds = ds * (1.0 - jnp.square(capped / cap))
        ds = jnp.where(mask[None, None, None], ds, 0.0) * scale
        dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                          kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
        return dq_acc + dq_c, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, n_kv, gq, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kcs, vcs, kps))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, -1, n_kv, d)[:, :skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, -1, n_kv, d)[:, :skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


_flash_attend.defvjp(_flash_attend_fwd, _flash_attend_bwd)


def _chunked_attend(q, k, v, q_pos, kv_pos, *, causal, window, cap, scale,
                    chunk: int, q_chunk: int = 4096):
    """Online-softmax attention, blocked over BOTH q and kv, with the
    flash-style custom VJP.

    KV blocking bounds the per-iteration logits tile; q blocking bounds it
    again for long prefills (without it a 32k-query prefill materializes a
    [B,H,32k,chunk] tile per kv step)."""
    b, sq, n_kv, g, d = q.shape
    window_arr = (GLOBAL_WINDOW if window is None
                  else jnp.asarray(window, jnp.int32))

    if sq > q_chunk:
        nq = -(-sq // q_chunk)
        pad_q = nq * q_chunk - sq
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, ((0, pad_q),), constant_values=-1)
        qb = q.reshape(b, nq, q_chunk, n_kv, g, d).transpose(
            1, 0, 2, 3, 4, 5)
        qp = q_pos.reshape(nq, q_chunk)

        def qstep(_, xs):
            qc, qpc = xs
            out = _flash_attend(qc, k, v, qpc, kv_pos, window_arr, causal,
                                cap, scale, chunk)
            return None, out

        _, outs = jax.lax.scan(qstep, None, (qb, qp))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(
            b, nq * q_chunk, n_kv, g, d)
        return out[:, :sq]
    return _flash_attend(q, k, v, q_pos, kv_pos, window_arr, causal, cap,
                         scale, chunk)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, kv_pos: jnp.ndarray, *,
           causal: bool = True, window=None, cap: float = 0.0,
           scale: Optional[float] = None, chunk: int = 0,
           q_chunk: int = 4096) -> jnp.ndarray:
    """Grouped-query attention.

    q: [B, Sq, Hq, D];  k/v: [B, Skv, Hkv, D];  q_pos: [Sq]; kv_pos: [Skv]
    (position < 0 == invalid slot).  Returns [B, Sq, Hq, D].
    """
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, n_kv, g, d)
    if chunk and k.shape[1] > chunk:
        out = _chunked_attend(qg, k, v, q_pos, kv_pos, causal=causal,
                              window=window, cap=cap, scale=scale,
                              chunk=chunk, q_chunk=q_chunk)
    else:
        out = _direct_attend(qg, k, v, q_pos, kv_pos, causal=causal,
                             window=window, cap=cap, scale=scale)
    return out.reshape(b, sq, hq, d)


# ---------------------------------------------------------------- caches ----

def ring_slot_positions(pos, width: int) -> jnp.ndarray:
    """Token position stored in each ring-buffer slot after writing
    position ``pos`` (traced scalar); -1 when the slot is still empty.

    Slot s holds the most recent position p <= pos with p % width == s.
    """
    s = jnp.arange(width, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    p = pos - jnp.mod(pos - s, width)
    return jnp.where(p >= 0, p, -1)


def ring_gather_indices(seq_len: int, width: int) -> jnp.ndarray:
    """Indices into a [S] sequence whose last ``width`` tokens fill the
    ring buffer slots (static version, used by prefill).  Invalid -> 0 with
    positions marked -1 separately."""
    s = jnp.arange(width, dtype=jnp.int32)
    last = seq_len - 1
    p = last - jnp.mod(last - s, width)
    return p  # may be negative if seq_len < width


def build_ring_cache(k: jnp.ndarray, v: jnp.ndarray, width: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fill a ring cache from a full prefill sequence [B, S, Hkv, D]."""
    seq_len = k.shape[1]
    idx = ring_gather_indices(seq_len, width)
    safe = jnp.clip(idx, 0, seq_len - 1)
    kc = jnp.take(k, safe, axis=1)
    vc = jnp.take(v, safe, axis=1)
    return kc, vc
