"""Model substrate: family-generic transformer covering all assigned
architectures."""

from repro.models.modality import batch_specs, make_batch
from repro.models.transformer import Model, ModelOptions

__all__ = ["Model", "ModelOptions", "batch_specs", "make_batch"]
