"""Mixture-of-Experts FFN with GShard-style dense dispatch.

Top-k routing with per-expert capacity; dispatch/combine are one-hot
einsums, which is the TPU-native formulation (dense matmuls on the MXU,
no scatter).  Experts are sharded over the ``model`` mesh axis (expert
parallelism); the dispatched activations [groups, E, capacity, d] carry an
explicit sharding constraint on E so XLA partitions the expert computation
instead of replicating it.

Covers both assigned MoE archs: Llama-4-Scout (16e top-1 + shared expert)
and Granite (40e top-8, fine-grained).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe_params(key, cfg, dtype) -> Dict[str, jnp.ndarray]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 6)
    params = {
        "norm_scale": jnp.zeros((d,), dtype),  # pre-FFN norm
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype, fan_in=ff),
    }
    if cfg.shared_expert:
        params["shared_gate"] = dense_init(ks[4], (d, ff), dtype)
        params["shared_up"] = dense_init(ks[5], (d, ff), dtype)
        params["shared_down"] = dense_init(
            jax.random.fold_in(key, 7), (ff, d), dtype, fan_in=ff)
    return params


def _capacity(group_size: int, num_experts: int, k: int, factor: float
              ) -> int:
    cap = int(math.ceil(group_size * k / num_experts * factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def apply_moe(params, cfg, x: jnp.ndarray, ctx=None,
              group_size: int = 2048) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """MoE FFN.  x: [B, S, d].  Returns (y, aux_losses).

    Tokens are processed in groups (capacity is per-group), following
    GShard; group boundaries follow the batch*seq layout so groups stay
    aligned with the data shards.
    """
    bsz, seq, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = bsz * seq
    g_sz = min(group_size, tokens)
    n_groups = tokens // g_sz
    assert n_groups * g_sz == tokens, (tokens, g_sz)
    cap = _capacity(g_sz, e, k, cfg.moe_capacity_factor)

    xt = x.reshape(n_groups, g_sz, d)
    if ctx is not None:
        xt = ctx.act(xt, "batch", None, "embed")

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"])                      # [g,s,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                # [g,s,k]
    top_w = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's capacity buffer
    dispatch = jnp.zeros((n_groups, g_sz, e, cap), x.dtype)
    combine = jnp.zeros((n_groups, g_sz, e, cap), jnp.float32)
    prior = jnp.zeros((n_groups, 1, e), jnp.int32)
    for slot in range(k):
        onehot = jax.nn.one_hot(top_idx[..., slot], e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) - 1 + prior           # [g,s,E]
        prior = prior + onehot.sum(axis=1, keepdims=True)
        within = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(within, pos, -1), cap,
                                dtype=x.dtype)                 # [g,s,E,cap]
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh.astype(jnp.float32) \
            * top_w[..., slot][..., None, None]

    if ctx is not None:
        dispatch = ctx.act(dispatch, "batch", None, "experts", None)
        combine = ctx.act(combine, "batch", None, "experts", None)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt)            # [g,E,cap,d]
    if ctx is not None:
        xe = ctx.act(xe, "batch", "experts", None, "embed")
    h_gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    h_up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h_gate * h_up, params["w_down"])
    if ctx is not None:
        ye = ctx.act(ye, "batch", "experts", None, "embed")
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    if cfg.shared_expert:
        sh = jax.nn.silu(jnp.einsum("gsd,df->gsf", xt, params["shared_gate"]))
        sh = sh * jnp.einsum("gsd,df->gsf", xt, params["shared_up"])
        y = y + jnp.einsum("gsf,fd->gsd", sh, params["shared_down"])

    # aux losses: load balance (Switch) + router z-loss
    me = probs.mean(axis=1)                                    # [g,E]
    ce = jax.nn.one_hot(top_idx[..., 0], e).mean(axis=1)       # [g,E]
    lb_loss = (me * ce).sum(-1).mean() * e
    z_loss = jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean()
    # fraction of tokens dropped (capacity overflow) — a monitoring metric
    routed = dispatch.sum(axis=(2, 3))                         # [g,s]
    dropped = 1.0 - (routed.astype(jnp.float32).mean() / k)
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return y.reshape(bsz, seq, d), aux
