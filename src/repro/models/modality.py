"""Modality frontends (stubs, per task spec) and batch construction.

``[audio]``/``[vlm]`` architectures specify the transformer BACKBONE only;
the EnCodec/vision towers are stubs: ``batch_specs`` (and the synthetic
``make_batch``) provide precomputed frame/patch embeddings directly.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig


def batch_specs(cfg: ArchConfig, seq_len: int, batch: int, kind: str
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern:
    weak-type-correct, shardable, no device allocation)."""
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    s = seq_len if kind != "decode" else 1
    if cfg.frontend == "audio_frames":
        specs["embeds"] = jax.ShapeDtypeStruct((batch, s, cfg.d_model), dt)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((batch, s), jnp.float32)
    if cfg.frontend == "image_patches" and kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.d_model), dt)
    return specs


def make_batch(cfg: ArchConfig, seq_len: int, batch: int, kind: str,
               seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Synthetic batch matching :func:`batch_specs` (smoke tests/examples)."""
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, jnp.ndarray] = {}
    s = seq_len if kind != "decode" else 1
    if cfg.frontend == "audio_frames":
        out["embeds"] = jnp.asarray(
            rng.standard_normal((batch, s, cfg.d_model), np.float32) * 0.1,
            dtype=dt)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, s)), dtype=jnp.int32)
    if kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, s)), dtype=jnp.int32)
        out["loss_mask"] = jnp.ones((batch, s), jnp.float32)
    if cfg.frontend == "image_patches" and kind != "decode":
        out["image_embeds"] = jnp.asarray(
            rng.standard_normal(
                (batch, cfg.num_image_tokens, cfg.d_model),
                np.float32) * 0.1, dtype=dt)
    return out
