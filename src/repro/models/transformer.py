"""The family-generic model: one scanned layer stack covering all ten
assigned architectures (dense / MoE / SSM / hybrid / audio / VLM).

Key structural decisions (see DESIGN.md §5):

* **scan over stacked layer params** — per-layer weights carry a leading
  ``[L]`` dim and run under ``jax.lax.scan``, keeping HLO size and compile
  time O(1) in depth.  Per-layer *statics* that differ inside a stack
  (gemma local/global window, per-layer rope theta) are passed as traced
  scan inputs, so one traced body serves every layer.
* **caches as scan xs/ys** — KV/SSM state is stacked ``[L, ...]`` and
  flows through the scan as per-layer slices, giving natural donation.
* **VLM grouping** — cross-attention blocks every k layers are handled by
  an outer scan over groups (inner scan over k self layers + one gated
  cross block), so cross params exist only where they are used.
* **remat** — each block body can be wrapped in ``jax.checkpoint`` with a
  selectable policy (a §Perf lever).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_lookup, gated_mlp, rope,
                                 rms_norm, unembed)

Params = Dict[str, Any]

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


@dataclass
class ModelOptions:
    use_pallas: bool = False
    remat_policy: str = "full"        # applied to train forward only
    remat_prevent_cse: bool = True    # keep saved residuals in model dtype
    attn_chunk: int = 2048            # online-softmax KV blocking threshold
    attn_q_chunk: int = 4096          # query blocking for long prefills
    moe_group_size: int = 2048


class Model:
    """Functional model: ``init`` -> params; ``forward`` (train),
    ``prefill`` and ``decode_step`` (serving).  ``ctx`` is an optional
    ShardingCtx."""

    def __init__(self, cfg: ArchConfig, ctx=None,
                 options: Optional[ModelOptions] = None) -> None:
        self.cfg = cfg
        self.ctx = ctx
        self.opt = options or ModelOptions()
        self.dtype = jnp.dtype(cfg.dtype)
        kinds = cfg.layer_kinds()
        self.windows = jnp.array(
            [cfg.window_size if k == "local" else (1 << 30) for k in kinds],
            jnp.int32)
        theta_g = cfg.rope_theta_global or cfg.rope_theta
        self.thetas = jnp.array(
            [cfg.rope_theta if k == "local" else theta_g for k in kinds],
            jnp.float32)
        # cross-attention bookkeeping (VLM)
        cross_set = set(cfg.cross_attn_layers())
        self.n_cross = len(cross_set)
        slots, c = [], 0
        for i in range(cfg.num_layers):
            slots.append(c)
            if i in cross_set:
                c += 1
        self.cross_flags = jnp.array(
            [1 if i in cross_set else 0 for i in range(cfg.num_layers)],
            jnp.int32)
        self.cross_slots = jnp.array(slots, jnp.int32)

    # ------------------------------------------------------------------ init
    def _init_attn(self, key, n_layers: int) -> Params:
        cfg, dt = self.cfg, self.dtype
        d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.resolved_head_dim)
        ks = jax.random.split(key, 4)
        L = (n_layers,)
        p = {
            "norm_scale": jnp.zeros(L + (d,), dt),
            "wq": dense_init(ks[0], L + (d, hq, hd), dt, fan_in=d),
            "wk": dense_init(ks[1], L + (d, hkv, hd), dt, fan_in=d),
            "wv": dense_init(ks[2], L + (d, hkv, hd), dt, fan_in=d),
            "wo": dense_init(ks[3], L + (hq, hd, d), dt, fan_in=hq * hd),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros(L + (hd,), dt)
            p["k_norm"] = jnp.zeros(L + (hd,), dt)
        if cfg.post_norms:
            p["post_norm_scale"] = jnp.zeros(L + (d,), dt)
        return p

    def _init_mlp(self, key, n_layers: int) -> Params:
        cfg, dt = self.cfg, self.dtype
        d, ff = cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 3)
        L = (n_layers,)
        p = {
            "norm_scale": jnp.zeros(L + (d,), dt),
            "w_gate": dense_init(ks[0], L + (d, ff), dt, fan_in=d),
            "w_up": dense_init(ks[1], L + (d, ff), dt, fan_in=d),
            "w_down": dense_init(ks[2], L + (ff, d), dt, fan_in=ff),
        }
        if cfg.post_norms:
            p["post_norm_scale"] = jnp.zeros(L + (d,), dt)
        return p

    def _init_stacked(self, init_one, key, n_layers: int) -> Params:
        keys = jax.random.split(key, n_layers)
        return jax.vmap(init_one)(keys)

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        kE, kH, kB, kX, kM = jax.random.split(key, 5)
        params: Params = {
            "embed": {"table": (jax.random.normal(
                kE, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02).astype(dt)},
            "final_norm_scale": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kH, (cfg.d_model, cfg.vocab_size),
                                           dt)
        blocks: Params = {}
        L = cfg.num_layers
        if cfg.has_attention:
            blocks["attn"] = self._init_attn(jax.random.fold_in(kB, 0), L)
        if cfg.family in ("ssm", "hybrid"):
            blocks["ssm"] = self._init_stacked(
                lambda k: ssm_mod.init_ssm_params(k, cfg, dt),
                jax.random.fold_in(kB, 1), L)
        if cfg.family == "hybrid":
            blocks["fuse"] = {
                "attn_norm": jnp.zeros((L, cfg.d_model), dt),
                "ssm_norm": jnp.zeros((L, cfg.d_model), dt),
                "beta_attn": jnp.ones((L,), jnp.float32),
                "beta_ssm": jnp.ones((L,), jnp.float32),
            }
        if cfg.is_moe:
            blocks["moe"] = self._init_stacked(
                lambda k: moe_mod.init_moe_params(k, cfg, dt),
                jax.random.fold_in(kB, 2), L)
        elif cfg.d_ff:
            blocks["mlp"] = self._init_mlp(jax.random.fold_in(kB, 3), L)
        params["blocks"] = blocks
        if self.n_cross:
            params["xblocks"] = {
                "attn": self._init_attn(jax.random.fold_in(kX, 0),
                                        self.n_cross),
                "mlp": self._init_mlp(jax.random.fold_in(kX, 1),
                                      self.n_cross),
                "gate_attn": jnp.zeros((self.n_cross,), jnp.float32),
                "gate_mlp": jnp.zeros((self.n_cross,), jnp.float32),
            }
        if cfg.num_meta_tokens:
            params["meta_tokens"] = (jax.random.normal(
                kM, (cfg.num_meta_tokens, cfg.d_model), jnp.float32)
                * 0.02).astype(dt)
        return params

    # -------------------------------------------------------------- helpers
    def _constrain(self, x, *logicals):
        if self.ctx is not None:
            return self.ctx.act(x, *logicals)
        return x

    def _scale(self) -> float:
        cfg = self.cfg
        return cfg.query_scale or cfg.resolved_head_dim ** -0.5

    def _qkv(self, p, h, positions, theta):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
        q = self._constrain(q, "batch", None, "heads", None)
        k = self._constrain(k, "batch", None, "kv_heads", None)
        v = self._constrain(v, "batch", None, "kv_heads", None)
        return q, k, v

    def _attn_out(self, p, out):
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        if "post_norm_scale" in p:
            y = rms_norm(y, p["post_norm_scale"], self.cfg.norm_eps)
        return y

    def _attend_seq(self, q, k, v, positions, window):
        """Sequence attention: Pallas flash kernel (TPU fast path) or the
        XLA online-softmax fallback.  ``window`` is a traced per-layer
        scalar; under Pallas, mixed local/global stacks branch with
        ``lax.cond`` over the two static window values."""
        cfg = self.cfg
        if self.opt.use_pallas:
            from repro.kernels.ops import flash_attention_op

            def call(win: int):
                return flash_attention_op(
                    q, k, v, causal=True, window=win,
                    softcap=cfg.attn_logit_softcap, scale=self._scale(),
                    block_q=min(128, q.shape[1]),
                    block_k=min(128, k.shape[1]))
            kinds = set(cfg.layer_kinds())
            if "local" in kinds and "global" in kinds:
                return jax.lax.cond(window < (1 << 30),
                                    lambda: call(cfg.window_size),
                                    lambda: call(0))
            if "local" in kinds:
                return call(cfg.window_size)
            return call(0)
        return attn_mod.attend(
            q, k, v, positions, positions, causal=True, window=window,
            cap=cfg.attn_logit_softcap, scale=self._scale(),
            chunk=self.opt.attn_chunk, q_chunk=self.opt.attn_q_chunk)

    def _self_attention(self, p, x, positions, window, theta):
        """Pre-norm self attention over the fresh sequence (train/prefill).
        Returns (block output, (k, v)) — k/v feed the prefill cache."""
        cfg = self.cfg
        h = rms_norm(x, p["norm_scale"], cfg.norm_eps)
        h = self._constrain(h, "batch", "seq", "embed")
        q, k, v = self._qkv(p, h, positions, theta)
        out = self._attend_seq(q, k, v, positions, window)
        return self._attn_out(p, out), (k, v)

    def _mlp(self, p, x):
        cfg = self.cfg
        h = rms_norm(x, p["norm_scale"], cfg.norm_eps)
        h = self._constrain(h, "batch", "seq", "embed")
        act = "gelu" if cfg.scale_embed else "silu"   # gemma family: gelu
        y = gated_mlp(h, p["w_gate"], p["w_up"], p["w_down"], act=act)
        if "post_norm_scale" in p:
            y = rms_norm(y, p["post_norm_scale"], cfg.norm_eps)
        return y

    def _hybrid_mix(self, fuse, attn_out, ssm_out):
        cfg = self.cfg
        return (rms_norm(attn_out, fuse["attn_norm"], cfg.norm_eps)
                * fuse["beta_attn"].astype(attn_out.dtype)
                + rms_norm(ssm_out, fuse["ssm_norm"], cfg.norm_eps)
                * fuse["beta_ssm"].astype(ssm_out.dtype)) * 0.5

    def _moe(self, bp, x, group_size=None):
        y, aux = moe_mod.apply_moe(
            bp["moe"], self.cfg,
            rms_norm(x, bp["moe"]["norm_scale"], self.cfg.norm_eps),
            self.ctx, group_size or self.opt.moe_group_size)
        return y, aux

    # ------------------------------------------------------------ VLM bits
    def _image_kv(self, params, batch):
        """Per-cross-block K/V projections of the stub patch embeddings.
        Returns (k, v): [n_cross, B, T, Hkv, D]."""
        img = batch["image_embeds"].astype(self.dtype)
        xp = params["xblocks"]["attn"]
        k = jnp.einsum("btd,ndhk->nbthk", img, xp["wk"])
        v = jnp.einsum("btd,ndhk->nbthk", img, xp["wv"])
        return k, v

    def _cross_block(self, xp, idx, x, img_kv):
        """Gated cross-attention block; idx is a traced slot index."""
        cfg = self.cfg
        p = jax.tree_util.tree_map(lambda a: a[idx], xp["attn"])
        h = rms_norm(x, p["norm_scale"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        k, v = img_kv[0][idx], img_kv[1][idx]
        sq, skv = q.shape[1], k.shape[1]
        out = attn_mod.attend(
            q, k, v, jnp.zeros((sq,), jnp.int32),
            jnp.zeros((skv,), jnp.int32), causal=False, window=None,
            cap=0.0, scale=self._scale(), chunk=self.opt.attn_chunk)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        x = x + jnp.tanh(xp["gate_attn"][idx]).astype(x.dtype) * y
        mp = jax.tree_util.tree_map(lambda a: a[idx], xp["mlp"])
        x = x + jnp.tanh(xp["gate_mlp"][idx]).astype(x.dtype) \
            * self._mlp(mp, x)
        return x

    # -------------------------------------------------------------- embed
    def embed_inputs(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            x = batch["embeds"].astype(self.dtype)
        else:
            # constraining the table keeps its gather-backward (scatter)
            # gradient vocab-sharded instead of replicated
            table = self._constrain(params["embed"]["table"],
                                    "vocab", "fsdp")
            x = embed_lookup(table, batch["tokens"],
                             scale_by_dim=cfg.scale_embed)
        if cfg.num_meta_tokens:
            meta = jnp.broadcast_to(
                params["meta_tokens"][None],
                (x.shape[0],) + params["meta_tokens"].shape).astype(x.dtype)
            x = jnp.concatenate([meta, x], axis=1)
        return self._constrain(x, "batch", "seq", "embed")

    def _logits(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        x = rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["lm_head"])
        table = self._constrain(
            table, *(("vocab", "fsdp") if cfg.tie_embeddings
                     else ("fsdp", "vocab")))
        logits = unembed(x, table, cfg.tie_embeddings,
                         cfg.final_logit_softcap)
        return self._constrain(logits, "batch", "seq", "vocab")

    def forward_hidden(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        """Training forward up to (but excluding) the unembedding.
        Used by the chunked cross-entropy path (train/step.py), which
        never materializes the full [B, S, V] logits."""
        return self._forward_trunk(params, batch)

    # ------------------------------------------------------- train forward
    def _block_train(self, bp, x, window, theta, positions, aux):
        cfg = self.cfg
        if cfg.family == "ssm":
            return x + ssm_mod.apply_ssm_mixer(bp["ssm"], cfg, x, use_pallas=self.opt.use_pallas), aux
        if cfg.family == "hybrid":
            attn_out, _ = self._self_attention(bp["attn"], x, positions,
                                               window, theta)
            ssm_out = ssm_mod.apply_ssm_mixer(bp["ssm"], cfg, x, use_pallas=self.opt.use_pallas)
            x = x + self._hybrid_mix(bp["fuse"], attn_out, ssm_out)
            return x + self._mlp(bp["mlp"], x), aux
        attn_out, _ = self._self_attention(bp["attn"], x, positions,
                                           window, theta)
        x = x + attn_out
        if cfg.is_moe:
            y, a = self._moe(bp, x)
            x = x + y
            aux = {k: aux[k] + a[k] for k in aux}
        elif cfg.d_ff:
            x = x + self._mlp(bp["mlp"], x)
        return x, aux

    def forward(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        """Full-sequence forward (training).  Returns (logits, aux)."""
        x, aux = self._forward_trunk(params, batch)
        return self._logits(params, x), aux

    def _forward_trunk(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        seq = x.shape[1]
        positions = jnp.arange(seq, dtype=jnp.int32)
        aux0 = ({"moe_lb_loss": jnp.float32(0.0),
                 "moe_z_loss": jnp.float32(0.0),
                 "moe_drop_frac": jnp.float32(0.0)} if cfg.is_moe else {})
        policy = REMAT_POLICIES.get(self.opt.remat_policy)
        remat = self.opt.remat_policy != "none"

        def body(carry, xs):
            x, aux = carry
            bp, window, theta = xs
            x, aux = self._block_train(bp, x, window, theta, positions, aux)
            x = self._constrain(x, "batch", "seq", "embed")
            return (x, aux), None

        if self.n_cross:
            img_kv = self._image_kv(params, batch)
            every = cfg.cross_attn_every
            n_groups = cfg.num_layers // every
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape((n_groups, every) + a.shape[1:]),
                params["blocks"])
            windows = self.windows.reshape(n_groups, every)
            thetas = self.thetas.reshape(n_groups, every)

            # nested remat: inner per-layer body AND the outer group are
            # checkpointed, so bwd of a group recomputes one layer at a
            # time instead of holding 5 layers of intermediates.
            inner = (jax.checkpoint(body, policy=policy,
                                   prevent_cse=self.opt.remat_prevent_cse)
                     if remat else body)

            def group_body(carry, xs):
                bp, window, theta, idx = xs
                (x, aux), _ = jax.lax.scan(inner, carry,
                                           (bp, window, theta))
                x = self._cross_block(params["xblocks"], idx, x, img_kv)
                x = self._constrain(x, "batch", "seq", "embed")
                return (x, aux), None

            if remat:
                group_body = jax.checkpoint(group_body, policy=policy,
                                            prevent_cse=self.opt.remat_prevent_cse)
            (x, aux), _ = jax.lax.scan(
                group_body, (x, aux0),
                (grouped, windows, thetas,
                 jnp.arange(n_groups, dtype=jnp.int32)))
        else:
            scanned = (jax.checkpoint(body, policy=policy,
                                   prevent_cse=self.opt.remat_prevent_cse)
                       if remat else body)
            (x, aux), _ = jax.lax.scan(scanned, (x, aux0),
                                       (params["blocks"], self.windows,
                                        self.thetas))
        if cfg.num_meta_tokens:
            x = x[:, cfg.num_meta_tokens:]
        return x, aux

    # ------------------------------------------------------------- serving
    def prefill(self, params, batch, extra_slots: int = 0
                ) -> Tuple[jnp.ndarray, Params]:
        """Process the full prompt.  Returns (last-position logits, cache).
        ``extra_slots`` pre-allocates room for subsequent decode steps."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        total = x.shape[1]
        positions = jnp.arange(total, dtype=jnp.int32)
        img_kv = self._image_kv(params, batch) if self.n_cross else None

        def body(x, xs):
            bp, window, theta, is_cross, slot = xs
            cache_out = {}
            if cfg.family == "ssm":
                y, st = ssm_mod.apply_ssm_mixer(bp["ssm"], cfg, x, use_pallas=self.opt.use_pallas,
                                                return_state=True)
                x = x + y
                cache_out.update(st)
            elif cfg.family == "hybrid":
                attn_out, (k, v) = self._self_attention(
                    bp["attn"], x, positions, window, theta)
                ssm_out, st = ssm_mod.apply_ssm_mixer(bp["ssm"], cfg, x, use_pallas=self.opt.use_pallas,
                                                      return_state=True)
                x = x + self._hybrid_mix(bp["fuse"], attn_out, ssm_out)
                x = x + self._mlp(bp["mlp"], x)
                cache_out.update(st)
                cache_out["k"], cache_out["v"] = k, v
            else:
                attn_out, (k, v) = self._self_attention(
                    bp["attn"], x, positions, window, theta)
                x = x + attn_out
                if cfg.is_moe:
                    y, _ = self._moe(bp, x)
                    x = x + y
                elif cfg.d_ff:
                    x = x + self._mlp(bp["mlp"], x)
                cache_out["k"], cache_out["v"] = k, v
            if self.n_cross:
                x = jax.lax.cond(
                    is_cross > 0,
                    lambda x: self._cross_block(params["xblocks"], slot, x,
                                                img_kv),
                    lambda x: x, x)
            x = self._constrain(x, "batch", "seq", "embed")
            return x, cache_out

        x, layer_caches = jax.lax.scan(
            body, x, (params["blocks"], self.windows, self.thetas,
                      self.cross_flags, self.cross_slots))
        cache: Params = {"pos": jnp.asarray(total, jnp.int32)}
        if cfg.has_attention:
            k, v = layer_caches["k"], layer_caches["v"]
            if extra_slots:
                pad = ((0, 0), (0, 0), (0, extra_slots), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            cache["k"], cache["v"] = k, v
        if cfg.family in ("ssm", "hybrid"):
            cache["ssm"] = layer_caches["ssm"]
            cache["conv"] = layer_caches["conv"]
        if self.n_cross:
            cache["xk"], cache["xv"] = img_kv
        logits = self._logits(params, x[:, -1:])
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int) -> Params:
        """Allocate an empty decode cache (for cost analysis / cold decode).
        ``max_len`` includes room for tokens to be decoded; meta tokens are
        added on top."""
        cfg, dt = self.cfg, self.dtype
        L = cfg.num_layers
        total = max_len + cfg.num_meta_tokens
        cache: Params = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.has_attention:
            kvshape = (L, batch_size, total, cfg.num_kv_heads,
                       cfg.resolved_head_dim)
            cache["k"] = jnp.zeros(kvshape, dt)
            cache["v"] = jnp.zeros(kvshape, dt)
        if cfg.family in ("ssm", "hybrid"):
            conv_ch = cfg.d_inner + 2 * cfg.ssm_state
            cache["ssm"] = jnp.zeros((L, batch_size, cfg.ssm_heads,
                                      cfg.ssm_headdim, cfg.ssm_state),
                                     jnp.float32)
            cache["conv"] = jnp.zeros((L, batch_size, cfg.conv_width - 1,
                                       conv_ch), dt)
        if self.n_cross:
            cache["xk"] = jnp.zeros((self.n_cross, batch_size,
                                     cfg.num_image_tokens, cfg.num_kv_heads,
                                     cfg.resolved_head_dim), dt)
            cache["xv"] = jnp.zeros_like(cache["xk"])
        return cache

    def decode_step(self, params, batch, cache) -> Tuple[jnp.ndarray, Params]:
        """One-token decode.  batch: {"tokens": [B,1]} or {"embeds":
        [B,1,d]}.  Returns (logits [B,1,V], new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        if cfg.frontend == "audio_frames":
            x = batch["embeds"].astype(self.dtype)
        else:
            x = embed_lookup(params["embed"]["table"], batch["tokens"],
                             scale_by_dim=cfg.scale_embed)
        positions = pos[None]
        max_total = cache["k"].shape[2] if cfg.has_attention else 0

        def attn_decode(bp, x, window, theta, k_cache, v_cache):
            h = rms_norm(x, bp["norm_scale"], cfg.norm_eps)
            q, k_new, v_new = self._qkv(bp, h, positions, theta)
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
            kv_pos = jnp.arange(max_total, dtype=jnp.int32)
            kv_pos = jnp.where(kv_pos <= pos, kv_pos, -1)
            out = attn_mod.attend(
                q, k_cache, v_cache, positions, kv_pos, causal=True,
                window=window, cap=cfg.attn_logit_softcap,
                scale=self._scale(), chunk=self.opt.attn_chunk)
            return self._attn_out(bp, out), k_cache, v_cache

        def body(x, xs):
            (bp, window, theta, is_cross, slot, kc, vc, ssm_st,
             conv_st) = xs
            out_cache = {}
            if cfg.family == "ssm":
                y, st = ssm_mod.apply_ssm_decode(
                    bp["ssm"], cfg, x, {"ssm": ssm_st, "conv": conv_st})
                x = x + y
                out_cache["ssm"], out_cache["conv"] = st["ssm"], st["conv"]
            elif cfg.family == "hybrid":
                attn_out, kc, vc = attn_decode(bp["attn"], x, window,
                                               theta, kc, vc)
                ssm_out, st = ssm_mod.apply_ssm_decode(
                    bp["ssm"], cfg, x, {"ssm": ssm_st, "conv": conv_st})
                x = x + self._hybrid_mix(bp["fuse"], attn_out, ssm_out)
                x = x + self._mlp(bp["mlp"], x)
                out_cache.update({"ssm": st["ssm"], "conv": st["conv"],
                                  "k": kc, "v": vc})
            else:
                attn_out, kc, vc = attn_decode(bp["attn"], x, window,
                                               theta, kc, vc)
                x = x + attn_out
                if cfg.is_moe:
                    y, _ = self._moe(bp, x,
                                     group_size=x.shape[0] * x.shape[1])
                    x = x + y
                elif cfg.d_ff:
                    x = x + self._mlp(bp["mlp"], x)
                out_cache["k"], out_cache["v"] = kc, vc
            if self.n_cross:
                x = jax.lax.cond(
                    is_cross > 0,
                    lambda x: self._cross_block(
                        params["xblocks"], slot, x,
                        (cache["xk"], cache["xv"])),
                    lambda x: x, x)
            return x, out_cache

        L = cfg.num_layers
        dummy = jnp.zeros((L, 1), self.dtype)
        xs = (params["blocks"], self.windows, self.thetas,
              self.cross_flags, self.cross_slots,
              cache.get("k", dummy), cache.get("v", dummy),
              cache.get("ssm", dummy), cache.get("conv", dummy))
        x, layer_caches = jax.lax.scan(body, x, xs)
        new_cache: Params = {"pos": pos + 1}
        for key in ("k", "v", "ssm", "conv"):
            if key in cache:
                new_cache[key] = layer_caches[key]
        for key in ("xk", "xv"):
            if key in cache:
                new_cache[key] = cache[key]
        logits = self._logits(params, x)
        return logits, new_cache
