"""IBM Granite MoE 3b-a800m: fine-grained MoE, 40 experts, top-8 routing.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  32L d_model=1536 24H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 40e top-8.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
