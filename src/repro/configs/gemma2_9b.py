"""Gemma-2 9B: dense, alternating local(SWA)/global attention, logit softcaps.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.  head_dim=256.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_pattern="local_global_1_1",
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    source="arXiv:2408.00118; hf",
))
