"""Llama-4 Scout 17B-active / 16 experts: MoE with top-1 routing and an
always-on shared expert (early fusion backbone; text path only here).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 (per expert) vocab=202048, MoE 16e top-1.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    shared_expert=True,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
