"""Architecture configuration schema and registry.

Every assigned architecture lives in its own module (``configs/<id>.py``)
holding the exact published configuration, registered under its public id
(e.g. ``gemma2-27b``).  ``reduced()`` derives a family-preserving small
variant used by the per-arch CPU smoke tests; the full configs are only
ever lowered abstractly via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    """A complete, family-generic model description.

    The ``family`` tag selects the block structure in
    ``repro.models.transformer``; unused fields are zero/None for
    families that do not need them.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int  # 0 for attention-free (SSM) architectures
    num_kv_heads: int
    d_ff: int  # per-expert FFN dim for MoE archs
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention features -------------------------------------------------
    # layer pattern: "global" | "local_global_1_1" | "local_global_5_1"
    #              | "swa_mostly" (hybrid: global only at a few anchor layers)
    attn_pattern: str = "global"
    window_size: int = 4096
    attn_logit_softcap: float = 0.0  # 0 -> disabled
    final_logit_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # 0 -> same as rope_theta

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    shared_expert: bool = False  # Llama-4 style always-on shared expert

    # --- SSM (Mamba-2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (Hymba) ------------------------------------------------------
    parallel_ssm: bool = False  # attention + SSM heads fused in one block
    num_meta_tokens: int = 0

    # --- modality frontends (stubs per task spec) ----------------------------
    frontend: str = "tokens"  # tokens | audio_frames | image_patches
    cross_attn_every: int = 0  # vlm: every k-th layer is a cross-attn layer
    num_image_tokens: int = 0

    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    scale_embed: bool = False     # gemma-style sqrt(d_model) embedding scale
    query_scale: float = 0.0      # 0 -> head_dim**-0.5
    post_norms: bool = False      # gemma-2/3 sandwich (post-block) norms
    source: str = ""  # provenance note from the assignment table

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k is runnable: SSM/hybrid or sliding-window
        local layers dominate (gemma-style local:global alternation)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_pattern.startswith("local_global")

    def layer_kinds(self) -> List[str]:
        """Per-layer attention kind: 'global' | 'local' | 'ssm' | 'hybrid'."""
        n = self.num_layers
        if self.family == "ssm":
            return ["ssm"] * n
        if self.family == "hybrid":
            return ["hybrid"] * n
        if self.attn_pattern == "global":
            return ["global"] * n
        if self.attn_pattern == "local_global_1_1":
            # gemma-2: alternate local, global, local, global, ...
            return ["local" if i % 2 == 0 else "global" for i in range(n)]
        if self.attn_pattern == "local_global_5_1":
            # gemma-3: every 6th layer is global
            return ["global" if (i + 1) % 6 == 0 else "local" for i in range(n)]
        if self.attn_pattern == "swa_mostly":
            anchors = {0, n // 2, n - 1}
            return ["global" if i in anchors else "local" for i in range(n)]
        raise ValueError(f"unknown attn_pattern {self.attn_pattern!r}")

    def cross_attn_layers(self) -> List[int]:
        if not self.cross_attn_every:
            return []
        return [i for i in range(self.num_layers)
                if (i + 1) % self.cross_attn_every == 0]

    def param_count(self) -> int:
        """Exact parameter count of the model as built by models/transformer.py."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm
        per_layer = 0
        if self.has_attention:
            attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.qk_norm:
                attn += 2 * hd
            per_layer += attn + d  # + input norm
        if self.family in ("ssm", "hybrid"):
            di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj -> (z, x, B, C, dt) ; conv on (x,B,C); out_proj
            ssm = d * (2 * di + 2 * st + nh)
            ssm += self.conv_width * (di + 2 * st)
            ssm += nh * 2  # A_log, D
            ssm += di * d  # out_proj
            ssm += d  # norm
            per_layer += ssm
        if self.is_moe:
            per_layer += d * self.num_experts  # router
            per_layer += self.num_experts * 3 * d * self.d_ff
            if self.shared_expert:
                per_layer += 3 * d * self.d_ff
            per_layer += d  # pre-FFN norm
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff + d  # gated MLP + norm
        total += per_layer * self.num_layers
        if self.cross_attn_every:
            xattn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d + d
            total += xattn * len(self.cross_attn_layers())
        if self.num_meta_tokens:
            total += self.num_meta_tokens * d
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        dense_like = self.param_count()
        skipped = (self.num_experts - self.experts_per_token)
        per_layer_expert = 3 * self.d_model * self.d_ff
        return dense_like - skipped * per_layer_expert * self.num_layers


# --------------------------------------------------------------------------
_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from repro import configs  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving tiny variant for CPU smoke tests."""
    n_q = min(cfg.num_heads, 4) if cfg.num_heads else 0
    n_kv = 0
    if n_q:
        n_kv = max(1, min(cfg.num_kv_heads, 2))
        while n_q % n_kv:
            n_kv -= 1
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=n_q,
        num_kv_heads=n_kv,
        head_dim=32 if n_q else 0,
        d_ff=(64 if cfg.is_moe else 256) if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=32,
        num_meta_tokens=min(cfg.num_meta_tokens, 8),
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        num_image_tokens=min(cfg.num_image_tokens, 16) if cfg.num_image_tokens else 0,
        window_size=min(cfg.window_size, 16),
        dtype="float32",
    )
    return dataclasses.replace(cfg, **updates)
