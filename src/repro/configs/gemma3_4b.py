"""Gemma-3 4B: dense, 5:1 local:global attention, qk-norm, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  head_dim=256; global layers use rope theta 1M.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    attn_pattern="local_global_5_1",
    window_size=1024,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    scale_embed=True,
    post_norms=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
