"""Llama-3.2 Vision 11B: dense text backbone with cross-attention image
layers every 5th layer.  The vision tower is a stub per the task spec:
``input_specs()`` provides precomputed patch embeddings already projected
to d_model.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attn_pattern="global",
    rope_theta=500_000.0,
    frontend="image_patches",
    cross_attn_every=5,
    num_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
