"""Hymba 1.5B: hybrid-head blocks — attention and Mamba(SSM) heads run in
PARALLEL inside every layer; 128 learnable meta tokens prepended; sliding-
window attention everywhere except a few global anchor layers.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  head_dim=64.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_pattern="swa_mostly",
    window_size=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    conv_width=4,
    parallel_ssm=True,
    num_meta_tokens=128,
    source="arXiv:2411.13676; hf",
))
