"""Assigned input-shape set for the LM-family architectures.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers the serving prefill
pass; ``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against
a KV/SSM state of length ``seq_len``).  ``long_500k`` requires sub-quadratic
attention and is skipped (per task spec, documented in DESIGN.md §4) for
pure full-attention architectures.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """Shape applicability per DESIGN.md §4."""
    if shape.name == "long_500k":
        return arch.sub_quadratic
    return True


def skip_reason(arch: ArchConfig, shape: ShapeConfig) -> str:
    if applicable(arch, shape):
        return ""
    return (f"{arch.name} is pure full-attention; long_500k needs "
            "sub-quadratic attention (DESIGN.md §4)")


def cells(archs: List[ArchConfig]) -> List[tuple]:
    """All (arch, shape) cells, including inapplicable ones (with reason)."""
    out = []
    for a in archs:
        for s in SHAPES.values():
            out.append((a, s, skip_reason(a, s)))
    return out
