"""MusicGen-medium: decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144
vocab=2048.  The EnCodec frontend is a stub per the task spec:
``input_specs()`` provides precomputed frame embeddings.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    attn_pattern="global",
    frontend="audio_frames",
    source="arXiv:2306.05284; hf",
))
