"""Mamba-2 780m: attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060; unverified]  48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128.  expand=2 -> d_inner=3072, headdim=64 -> 48 SSD heads.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
