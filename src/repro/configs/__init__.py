"""Architecture + shape registry.  Importing this package registers all
assigned architectures."""

from repro.configs.base import ArchConfig, get_arch, list_archs, reduced, register
from repro.configs.shapes import (SHAPES, ShapeConfig, applicable, cells,
                                  get_shape, skip_reason)

# Register every assigned architecture (import side effect).
from repro.configs import (  # noqa: F401  isort: skip
    musicgen_medium,
    mamba2_780m,
    llama4_scout_17b_a16e,
    granite_moe_3b_a800m,
    gemma2_27b,
    gemma3_4b,
    gemma2_9b,
    qwen3_8b,
    hymba_1_5b,
    llama_3_2_vision_11b,
)

ARCH_IDS = list_archs()

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "get_arch", "get_shape", "list_archs", "reduced", "register",
    "applicable", "cells", "skip_reason",
]
