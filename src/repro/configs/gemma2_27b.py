"""Gemma-2 27B: dense, alternating local(SWA)/global attention, logit softcaps.

[arXiv:2408.00118; hf]  46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  head_dim=128 (decoupled from d_model/num_heads).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    attn_pattern="local_global_1_1",
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    scale_embed=True,
    query_scale=0.08838834764831845,  # (d_model/num_heads)**-0.5 = 144**-0.5
    post_norms=True,
    source="arXiv:2408.00118; hf",
))
