"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0, softcap: float = 0.0,
                  scale: Optional[float] = None, q_offset: int = 0,
                  kv_len: Optional[int] = None) -> jnp.ndarray:
    """Naive GQA attention.  q [B,Sq,Hq,D]; k/v [B,Skv,Hkv,D]."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else skv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def ref_ssd_intra_chunk(xdt: jnp.ndarray, a_cs: jnp.ndarray,
                        b_mat: jnp.ndarray, c_mat: jnp.ndarray, chunk: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.ssd_scan.ssd_intra_chunk (same signature)."""
    bsz, s, h, p = xdt.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    xc = xdt.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    ac = a_cs.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    seg = ac[:, :, :, None, :] - ac[:, :, None, :, :]     # [B,C,Q,Q,H]
    idx = jnp.arange(chunk)
    tril = idx[:, None] >= idx[None, :]
    l_mat = jnp.where(tril[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)        # [B,C,Q,Q]
    y = jnp.einsum("bcls,bclsh,bcshp->bclhp", scores,
                   l_mat, xc)
    decay_st = jnp.exp(ac[:, :, -1:, :] - ac)             # [B,C,Q,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", bc, decay_st, xc)
    return (y.reshape(bsz, s, h, p), states)
