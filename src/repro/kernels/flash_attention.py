"""Pallas TPU flash attention: tiled online-softmax with GQA, causal +
sliding-window masking, and gemma-style logit softcap.

TPU adaptation notes (DESIGN.md §2): the tiling is chosen for the
HBM→VMEM→MXU hierarchy — Q tiles of ``block_q`` rows stay resident in
VMEM while K/V stream through in ``block_k`` tiles on the sequentially-
iterated last grid axis; running max/normalizer live in VMEM scratch
(lane-replicated, [block_q, 128]) so the MXU sees back-to-back
[block_q, d] × [d, block_k] matmuls.  Causally-dead K/V tiles are skipped
with ``pl.when`` (and the index maps never fetch them twice).

Layout: q [B, Sq, Hq, D], k/v [B, Skv, Hkv, D] — grid
(B, Hq, Sq/block_q, Skv/block_k), last axis "arbitrary" (sequential).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, q_offset: int, kv_len: int):
    b, h, qi, kj = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                    pl.program_id(3))
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # tile-level skip: entirely-masked K/V tiles do no work
    q_max = q_offset + qi * block_q + block_q - 1
    q_min = q_offset + qi * block_q
    tile_dead = False
    if causal:
        tile_dead = kj * block_k > q_max
    if window > 0:
        tile_dead = jnp.logical_or(
            tile_dead, (kj + 1) * block_k - 1 < q_min - window + 1)

    @pl.when(jnp.logical_not(tile_dead))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]                               # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # [bq, bk]
        l_new = l_scr[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    kv_len: Optional[int] = None) -> jnp.ndarray:
    """Tiled attention.  window=0 disables the sliding window; GQA is
    expressed through the index maps (no K/V materialization per q-head).
    ``kv_len`` masks trailing cache padding (defaults to k.shape[1])."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kv_len = kv_len if kv_len is not None else skv

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk

    grid = (b, hq, nq, nk)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, q_offset=q_offset,
        kv_len=kv_len)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :sq]
    return out
