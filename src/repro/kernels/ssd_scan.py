"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

The SSD algorithm's hot spot is the per-chunk quadratic part:
``y_diag = (C Bᵀ ∘ L) X`` plus the per-chunk state contribution
``S_c = (B ∘ decay)ᵀ X`` — three [Q,·]×[·,Q|P] matmuls per (batch, head,
chunk).  This kernel runs them on the MXU with all chunk operands resident
in VMEM; the cheap O(S) decay cumsums and the tiny inter-chunk recurrence
stay in XLA (see repro.models.ssm.ssd_chunked for the reference pipeline).

Grid: (B, H, n_chunks); blocks: one chunk per program instance.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _kernel(xdt_ref, acs_ref, b_ref, c_ref, y_ref, st_ref, *, chunk: int):
    # xdt: [1, Q, 1, P] (x*dt); acs: [1, Q, 1] cumsum of a within chunk;
    # b/c: [1, Q, N]
    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    acs = acs_ref[0, :, 0].astype(jnp.float32)           # [Q]
    bm = b_ref[0].astype(jnp.float32)                    # [Q, N]
    cm = c_ref[0].astype(jnp.float32)                    # [Q, N]

    seg = acs[:, None] - acs[None, :]                    # [Q, Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(iq >= jq, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * l_mat                              # [Q, Q]
    y = jax.lax.dot(scores, xdt,
                    preferred_element_type=jnp.float32)  # [Q, P]

    decay_st = jnp.exp(acs[-1] - acs)                    # [Q]
    b_dec = bm * decay_st[:, None]                       # [Q, N]
    states = jax.lax.dot_general(b_dec, xdt, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = states.astype(st_ref.dtype)        # [N, P]


def ssd_intra_chunk(xdt: jnp.ndarray, a_cs: jnp.ndarray, b_mat: jnp.ndarray,
                    c_mat: jnp.ndarray, chunk: int,
                    interpret: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Intra-chunk SSD.

    xdt: [B, S, H, P] (inputs pre-multiplied by dt);
    a_cs: [B, S, H] within-chunk cumulative log-decay;
    b_mat/c_mat: [B, S, N].
    Returns (y_diag [B, S, H, P], states [B, NC, H, N, P]).
    """
    bsz, s, h, p = xdt.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (bsz, h, nc)
    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda b, hh, c: (b, c, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, n, p), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xdt, a_cs, b_mat, c_mat)
    return y, st
