"""jit'd public wrappers around the Pallas kernels.

``interpret=None`` auto-selects: real lowering on TPU backends, interpret
mode elsewhere (this container is CPU-only; kernels are TPU-target and
validated in interpret mode per the task spec).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_offset", "block_q",
    "block_k", "interpret", "kv_len"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       softcap: float = 0.0, scale: Optional[float] = None,
                       q_offset: int = 0, block_q: int = 128,
                       block_k: int = 128,
                       interpret: Optional[bool] = None,
                       kv_len: Optional[int] = None) -> jnp.ndarray:
    return fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret), kv_len=kv_len)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_op(x, dt, a_log, b_mat, c_mat, *, chunk: int = 256,
           h0: Optional[jnp.ndarray] = None,
           interpret: Optional[bool] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full SSD scan with the Pallas intra-chunk kernel + XLA recurrence.

    Same contract as repro.models.ssm.ssd_chunked:
    x [B,S,H,P], dt [B,S,H] (post-softplus), a_log [H], b/c [B,S,N].
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    bsz, s_orig, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s_orig) % chunk
    if pad:  # dt=0 padding is exact (no decay, no contribution)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // chunk
    a = (-jnp.exp(a_log.astype(jnp.float32)))[None, None, :] * dt.astype(
        jnp.float32)                                     # [B,S,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    # within-chunk cumsum of log-decay
    a_c = a.reshape(bsz, nc, chunk, h)
    a_cs = jnp.cumsum(a_c, axis=2).reshape(bsz, s, h)

    y_diag, states_np = ssd.ssd_intra_chunk(
        xdt, a_cs, b_mat, c_mat, chunk,
        interpret=_auto_interpret(interpret))
    states = states_np.transpose(0, 1, 2, 4, 3)          # [B,C,H,P,N]

    chunk_decay = jnp.exp(a_cs.reshape(bsz, nc, chunk, h)[:, :, -1]
                          ).transpose(0, 2, 1)           # [B,H,C]
    h0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))

    def step(carry, xs):
        st, dec = xs
        new = carry * dec[..., None, None] + st
        return new, carry

    h_final, prev_states = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [B,C,H,P,N]

    cc = c_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    state_decay_out = jnp.exp(a_cs.reshape(bsz, nc, chunk, h))
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", cc, prev_states,
                       state_decay_out)
    y = y_diag.reshape(bsz, nc, chunk, h, p) + y_off
    return (y.reshape(bsz, s, h, p)[:, :s_orig].astype(x.dtype), h_final)
