"""Training/serving steps and sharding rules."""
from repro.train.sharding import NULL_CTX, ShardingCtx, param_shardings, param_specs
from repro.train.step import StepConfig, make_eval_step, make_loss_fn, make_train_step
