"""Training step: loss, gradient accumulation, optimizer apply — the
function the dry-run lowers and the launcher runs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim import compression
from repro.optim.optimizer import AdamW, OptState


@dataclass(frozen=True)
class StepConfig:
    num_microbatches: int = 1
    moe_lb_weight: float = 0.01
    moe_z_weight: float = 1e-3
    compress_grads: bool = False   # int8 EF quantization (cross-pod sim)
    ce_seq_chunk: int = 512        # chunked CE: logits never materialize
                                   # beyond [B, chunk, V]; 0 disables


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray, ctx=None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean masked token CE, vocab-shard-friendly.

    The gold logit is extracted with a one-hot contraction (sharded like
    the logits) instead of ``take_along_axis``/``argmax`` — the latter
    lower to gathers over the *unsharded* vocab axis and materialize a
    [B, S, V] iota (16+ GB for 256k vocabs).  logsumexp/max reduce over
    the sharded axis via cheap all-reduces."""
    logits = logits.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    if ctx is not None:
        onehot = ctx.act(onehot, "batch", "seq", "vocab")
    gold = jnp.sum(logits * onehot.astype(jnp.float32), axis=-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = lse - gold
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    max_logit = jnp.max(logits, axis=-1)
    acc = ((gold >= max_logit) * mask).sum() / denom
    return loss, acc


def chunked_cross_entropy(model: Model, params, hidden, labels, mask,
                          seq_chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CE over sequence chunks with a hand-written VJP.

    Forward never materializes more than one [B, chunk, V] logits tile;
    backward recomputes each tile and accumulates the unembedding-table
    gradient in a carry that is explicitly *vocab-sharded* each iteration.
    (Plain autodiff through either a scan or an unrolled loop leaves that
    accumulator — V x d in f32, 4-5 GB for 200k+ vocabs — unsharded or
    alive once per chunk.)  This is what makes huge-vocab training fit;
    see EXPERIMENTS.md §Perf.
    """
    cfg = model.cfg
    from repro.models.layers import rms_norm, softcap as softcap_fn
    y = rms_norm(hidden, params["final_norm_scale"], cfg.norm_eps)
    tied = cfg.tie_embeddings
    table = (params["embed"]["table"] if tied else params["lm_head"])
    cap = cfg.final_logit_softcap

    b, s, d = y.shape
    n = max(s // seq_chunk, 1)
    chunk = s // n
    assert chunk * n == s, (s, seq_chunk)

    def chunked(t, trail):
        return t.reshape((b, n, chunk) + trail).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(trail))))

    def constrain_dtable(dt):
        if model.ctx is None:
            return dt
        logical = ("vocab", "fsdp") if tied else ("fsdp", "vocab")
        return model.ctx.act(dt, *logical)

    def logits_of(y_c, w):
        if tied:
            pre = jnp.einsum("bcd,vd->bcv", y_c.astype(jnp.float32),
                             w.astype(jnp.float32))
        else:
            pre = jnp.einsum("bcd,dv->bcv", y_c.astype(jnp.float32),
                             w.astype(jnp.float32))
        return softcap_fn(pre, cap), pre

    def chunk_sums(y_c, w, l_c, m_c):
        logits, _ = logits_of(y_c, w)
        onehot = jax.nn.one_hot(l_c, logits.shape[-1], dtype=jnp.bfloat16)
        if model.ctx is not None:
            onehot = model.ctx.act(onehot, "batch", "seq", "vocab")
        gold = jnp.sum(logits * onehot.astype(jnp.float32), axis=-1)
        lse = jax.nn.logsumexp(logits, axis=-1)
        nll = ((lse - gold) * m_c).sum()
        correct = ((gold >= jnp.max(logits, axis=-1)) * m_c).sum()
        return nll, correct, onehot, lse

    @jax.custom_vjp
    def ce_sums(y, w, labels, mask):
        def body(carry, xs):
            nll, cor = carry
            y_c, l_c, m_c = xs
            pn, pc, _, _ = chunk_sums(y_c, w, l_c, m_c)
            return (nll + pn, cor + pc), None
        (nll, cor), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)),
            (chunked(y, (d,)), chunked(labels, ()), chunked(mask, ())))
        return nll, cor

    def ce_sums_fwd(y, w, labels, mask):
        out = ce_sums(y, w, labels, mask)
        return out, (y, w, labels, mask)

    def ce_sums_bwd(res, g):
        y, w, labels, mask = res
        dnll = g[0].astype(jnp.float32)

        def body(dtable, xs):
            y_c, l_c, m_c = xs
            logits, pre = logits_of(y_c, w)
            onehot = jax.nn.one_hot(l_c, logits.shape[-1],
                                    dtype=jnp.bfloat16)
            if model.ctx is not None:
                onehot = model.ctx.act(onehot, "batch", "seq", "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            p = jnp.exp(logits - lse[..., None])
            dlogits = (p - onehot.astype(jnp.float32)) \
                * (m_c[..., None] * dnll)
            if cap:
                dlogits = dlogits * (1.0 - jnp.square(logits / cap))
            dl16 = dlogits.astype(jnp.bfloat16)
            if tied:
                dy_c = jnp.einsum("bcv,vd->bcd", dl16,
                                  w.astype(jnp.bfloat16))
                dw_c = jnp.einsum("bcv,bcd->vd", dl16,
                                  y_c.astype(jnp.bfloat16))
            else:
                dy_c = jnp.einsum("bcv,dv->bcd", dl16,
                                  w.astype(jnp.bfloat16))
                dw_c = jnp.einsum("bcd,bcv->dv", y_c.astype(jnp.bfloat16),
                                  dl16)
            dtable = constrain_dtable(dtable + dw_c.astype(jnp.float32))
            return dtable, dy_c

        dt0 = constrain_dtable(jnp.zeros(w.shape, jnp.float32))
        dtable, dy_chunks = jax.lax.scan(
            body, dt0,
            (chunked(y, (d,)), chunked(labels, ()), chunked(mask, ())))
        dy = dy_chunks.transpose(1, 0, 2, 3).reshape(b, s, d)
        return (dy.astype(y.dtype), dtable.astype(w.dtype), None, None)

    ce_sums.defvjp(ce_sums_fwd, ce_sums_bwd)

    nll, correct = ce_sums(y, table, labels, mask)
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll / denom, correct / denom


def make_loss_fn(model: Model, step_cfg: StepConfig):
    def loss_fn(params, batch):
        if step_cfg.ce_seq_chunk:
            hidden, aux = model.forward_hidden(params, batch)
            loss, acc = chunked_cross_entropy(
                model, params, hidden, batch["labels"],
                batch["loss_mask"], step_cfg.ce_seq_chunk)
        else:
            logits, aux = model.forward(params, batch)
            loss, acc = cross_entropy(logits, batch["labels"],
                                      batch["loss_mask"], ctx=model.ctx)
        total = loss
        metrics = {"ce_loss": loss, "accuracy": acc}
        if aux:
            total = (total + step_cfg.moe_lb_weight * aux["moe_lb_loss"]
                     + step_cfg.moe_z_weight * aux["moe_z_loss"])
            metrics.update(aux)
        metrics["loss"] = total
        return total, metrics
    return loss_fn


def make_train_step(model: Model, optimizer: AdamW,
                    step_cfg: Optional[StepConfig] = None,
                    grad_shardings=None):
    """Returns ``train_step(params, opt_state, err_state, batch)`` ->
    (params, opt_state, err_state, metrics).

    ``err_state`` is the error-feedback buffer tree (zeros unless
    ``compress_grads``; pass None to disable entirely).
    With ``num_microbatches > 1`` the batch's leading dim is split and
    gradients accumulate in f32 before a single optimizer apply — the
    deferred-all-reduce pattern (collectives fire once per step, not once
    per microbatch).

    ``grad_shardings``: optional NamedSharding tree matching params;
    gradients are constrained to it (keeps e.g. the embedding-scatter
    gradient vocab-sharded instead of replicated)."""
    step_cfg = step_cfg or StepConfig()
    loss_fn = make_loss_fn(model, step_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_shardings)

    def compute_grads(params, batch):
        n_mb = step_cfg.num_microbatches
        if n_mb <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return constrain_grads(grads), metrics
        split = lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                    + x.shape[1:])
        mb_batch = jax.tree_util.tree_map(split, batch)

        def body(acc, mb):
            (_, metrics), grads = grad_fn(params, mb)
            grads = constrain_grads(grads)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics

        def zero_like(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return z

        zeros = constrain_grads(jax.tree_util.tree_map(zero_like, params))
        acc, metrics_stack = jax.lax.scan(body, zeros, mb_batch)
        metrics = jax.tree_util.tree_map(lambda m: m.mean(0),
                                         metrics_stack)
        grads = jax.tree_util.tree_map(lambda a: a / n_mb, acc)
        return grads, metrics

    def train_step(params, opt_state: OptState, err_state, batch):
        grads, metrics = compute_grads(params, batch)
        if step_cfg.compress_grads and err_state is not None:
            grads, err_state = compression.compress_tree(grads, err_state)
        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, err_state, metrics

    return train_step


def make_eval_step(model: Model, step_cfg: Optional[StepConfig] = None):
    loss_fn = make_loss_fn(model, step_cfg or StepConfig())

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
