"""Batched serving: prefill + decode engine with monitoring hooks.

``serve_step`` (one decode token for the whole batch against the KV/SSM
state) is what the ``decode_*`` / ``long_*`` dry-run cells lower.
:class:`ServeEngine` is the runnable engine used by the serving example:
continuous batched greedy decode with per-step monitor callbacks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


def make_prefill_step(model: Model, extra_slots: int = 0):
    def prefill_step(params, batch):
        return model.prefill(params, batch, extra_slots=extra_slots)
    return prefill_step


def make_serve_step(model: Model):
    """One batched greedy decode step: (params, tokens, cache) ->
    (next_tokens, cache)."""
    def serve_step(params, batch, cache):
        logits, cache = model.decode_step(params, batch, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, cache
    return serve_step


@dataclass
class ServeRequest:
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    """Minimal batched engine: collects requests into a fixed batch,
    prefills once, then decodes greedily; reports steps to the monitor."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_len: int, monitor=None) -> None:
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.monitor = monitor
        self._step = jax.jit(make_serve_step(model))
        self.requests: List[ServeRequest] = []
        self.steps_done = 0

    def submit(self, req: ServeRequest) -> None:
        if len(self.requests) >= self.batch_size:
            raise RuntimeError("batch full")
        self.requests.append(req)

    def run(self) -> List[ServeRequest]:
        assert self.requests, "no requests"
        b = len(self.requests)
        plen = max(len(r.prompt) for r in self.requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(self.requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        max_new = max(r.max_new_tokens for r in self.requests)
        batch = {"tokens": jnp.asarray(toks)}
        if self.model.cfg.frontend == "image_patches":
            batch["image_embeds"] = jnp.zeros(
                (b, self.model.cfg.num_image_tokens,
                 self.model.cfg.d_model), self.model.dtype)
        prefill = jax.jit(make_prefill_step(self.model,
                                            extra_slots=max_new))
        logits, cache = prefill(self.params, batch)
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        outs = [[] for _ in range(b)]
        t_start = time.time()
        for step in range(max_new):
            for i in range(b):
                outs[i].append(int(nxt[i]))
            nxt, cache = self._step(
                self.params, {"tokens": nxt[:, None]}, cache)
            self.steps_done += 1
            if self.monitor is not None:
                self.monitor.on_step(self.steps_done, tokens=b)
        for i, r in enumerate(self.requests):
            r.out = np.asarray(outs[i][: r.max_new_tokens], np.int32)
        done, self.requests = self.requests, []
        return done
