"""Logical-axis sharding: how every tensor maps onto the production mesh.

Models annotate tensors with *logical* axes ("batch", "heads", "vocab",
"experts", ...).  :class:`ShardingCtx` resolves logical axes to mesh axes
given the actual mesh — including the multi-pod case, where "batch" maps
to the combined ("pod", "data") axes, and the degenerate cases where an
axis does not divide (resolved to replication or handled by GSPMD uneven-
shard padding).

Parameter specs are derived from leaf *paths* by pattern rules
(:func:`param_logical`), so model code never mentions mesh axes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Optional[str]

# Default logical->mesh mapping.  ``batch`` spreads over the pure-data axes
# (pod+data); ``model-ish`` axes go to the tensor axis.  A rule value may be
# a tuple of mesh axes (tried in order, combined).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),          # ZeRO-style parameter sharding dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "inner": ("model",),        # SSM expanded dim
    "seq_shard": ("data",),     # long-context KV/sequence sharding
    "embed": (),                # d_model stays replicated by default
    "seq": (),
}


@dataclass(frozen=True)
class ShardingCtx:
    """Resolves logical axes against a concrete mesh.

    ``strict_divisibility``: when a logical axis size is known and does not
    divide the mesh axis product, fall back to replication for that axis
    (GSPMD could pad, but padded weight shards waste memory & compute; for
    activations we prefer explicitness).
    """

    mesh: Optional[Mesh] = None
    rules: Dict[str, Tuple[str, ...]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))

    # ------------------------------------------------------------ resolve
    def mesh_axes(self, logical: Logical, dim_size: Optional[int] = None
                  ) -> Union[None, str, Tuple[str, ...]]:
        if logical is None or self.mesh is None:
            return None
        axes = tuple(a for a in self.rules.get(logical, ())
                     if a in self.mesh.axis_names)
        if not axes:
            return None
        if dim_size is not None:
            total = 1
            kept = []
            for a in axes:
                n = self.mesh.shape[a]
                if dim_size % (total * n) == 0:
                    kept.append(a)
                    total *= n
                else:
                    break
            axes = tuple(kept)
            if not axes:
                return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, logicals: Sequence[Logical],
             shape: Optional[Sequence[int]] = None) -> P:
        parts = []
        used: set = set()
        for i, lg in enumerate(logicals):
            dim = shape[i] if shape is not None else None
            ax = self.mesh_axes(lg, dim)
            # one mesh axis may shard only one dim
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a not in used) or None
                if isinstance(ax, tuple) and len(ax) == 1:
                    ax = ax[0]
            if isinstance(ax, str) and ax in used:
                ax = None
            if isinstance(ax, tuple):
                used.update(ax)
            elif isinstance(ax, str):
                used.add(ax)
            parts.append(ax)
        return P(*parts)

    def sharding(self, logicals: Sequence[Logical],
                 shape: Optional[Sequence[int]] = None
                 ) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logicals, shape))

    # --------------------------------------------------------- activations
    def act(self, x, *logicals: Logical):
        """Apply a sharding constraint to an activation (no-op w/o mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(logicals, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def with_rules(self, **updates: Tuple[str, ...]) -> "ShardingCtx":
        rules = dict(self.rules)
        rules.update(updates)
        return replace(self, rules=rules)


NULL_CTX = ShardingCtx(mesh=None)


# ------------------------------------------------------------- param rules --

# (path regex, logical axes per dim) — first match wins.  Paths look like
# "embed/table", "blocks/attn/wq", "blocks/moe/experts_in", ...
_PARAM_RULES: Tuple[Tuple[str, Tuple[Logical, ...]], ...] = (
    (r"(^|/)embed/table$", ("vocab", "fsdp")),
    (r"(^|/)lm_head$", ("fsdp", "vocab")),
    (r"(^|/)meta_tokens$", (None, None)),
    # attention — stacked per-layer leading dim
    (r"/attn[^/]*/wq$", (None, "fsdp", "heads", None)),
    (r"/attn[^/]*/wk$", (None, "fsdp", "kv_heads", None)),
    (r"/attn[^/]*/wv$", (None, "fsdp", "kv_heads", None)),
    (r"/attn[^/]*/wo$", (None, "heads", None, "fsdp")),
    (r"/attn[^/]*/(q_norm|k_norm)$", (None, None)),
    # mlp
    (r"/mlp/w_gate$", (None, "fsdp", "d_ff")),
    (r"/mlp/w_up$", (None, "fsdp", "d_ff")),
    (r"/mlp/w_down$", (None, "d_ff", "fsdp")),
    # moe
    (r"/moe/router$", (None, "fsdp", "experts")),
    (r"/moe/w_gate$", (None, "experts", "fsdp", None)),
    (r"/moe/w_up$", (None, "experts", "fsdp", None)),
    (r"/moe/w_down$", (None, "experts", None, "fsdp")),
    (r"/moe/shared_(gate|up)$", (None, "fsdp", "d_ff")),
    (r"/moe/shared_down$", (None, "d_ff", "fsdp")),
    # ssm
    (r"/ssm/in_proj$", (None, "fsdp", "inner")),
    (r"/ssm/conv_w$", (None, None, "inner")),
    (r"/ssm/out_proj$", (None, "inner", "fsdp")),
    (r"/ssm/(a_log|d_skip|dt_bias)$", (None, "inner")),
    (r"/ssm/norm_scale$", (None, "inner")),
    # norms and everything small: replicated
    (r".*(norm|scale|bias).*", None),
)


def param_logical(path: str, ndim: int) -> Tuple[Logical, ...]:
    for pattern, logicals in _PARAM_RULES:
        if re.search(pattern, path):
            if logicals is None:
                return (None,) * ndim
            if len(logicals) == ndim:
                return logicals
            if len(logicals) == ndim + 1 and logicals[0] is None:
                return logicals[1:]   # non-stacked variant of a stacked rule
            if len(logicals) == ndim - 1:
                return (None,) + logicals  # extra stacking dim
    return (None,) * ndim


def tree_paths(tree, prefix: str = ""):
    """Yield (path, leaf) with '/'-joined dict keys."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from tree_paths(tree[k], f"{prefix}/{k}" if prefix else k)
    else:
        yield prefix, tree


def param_specs(params, ctx: ShardingCtx):
    """PartitionSpec pytree matching ``params`` (dict-of-dict-of-arrays)."""
    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        logicals = param_logical(prefix, tree.ndim)
        return ctx.spec(logicals, tree.shape)
    return build(params)


def param_shardings(params, ctx: ShardingCtx):
    if ctx.mesh is None:
        return None
    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in tree.items()}
        logicals = param_logical(prefix, tree.ndim)
        return NamedSharding(ctx.mesh, ctx.spec(logicals, tree.shape))
    return build(params)
