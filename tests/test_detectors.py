"""Detector tests: each paper case study fires exactly when it should."""

from repro.core.aggregator import MetricStore
from repro.core.daemon import JobManifest
from repro.core.detectors import (DetectorBank, HangDetector,
                                  IdleAcceleratorDetector,
                                  LowMfuDetector,
                                  LowParticipationDetector,
                                  MemoryUnderuseDetector,
                                  StragglerDetector)
from repro.core.schema import MetricRecord


def perf(ts, host, job, **f):
    base = {"gflops": 100.0, "steps_per_s": 1.0, "mfu": 0.4,
            "step_time_s": 1.0}
    base.update(f)
    return MetricRecord(ts, host, job, "perf", base)


def device(ts, host, job, frac):
    return MetricRecord(ts, host, job, "device",
                        {"hbm_frac_used": frac, "local_devices": 4})


def test_hang_detector_fires_after_patience():
    store = MetricStore()
    for i in range(3):
        store.insert(perf(float(i), "n0", "j1"))
    for i in range(3, 8):
        store.insert(perf(float(i), "n0", "j1", gflops=0.0,
                          steps_per_s=0.0))
    events = HangDetector(patience=3).scan(store)
    assert len(events) == 1
    assert events[0].detector == "hang" and events[0].severity == "critical"


def test_hang_detector_resets_on_progress():
    store = MetricStore()
    for i in range(10):
        # alternating stall/progress never reaches patience=3
        store.insert(perf(float(i), "n0", "j1",
                          gflops=0.0 if i % 2 else 50.0,
                          steps_per_s=0.0 if i % 2 else 1.0))
    assert HangDetector(patience=3).scan(store) == []


def test_idle_accelerator():
    store = MetricStore()
    for i in range(4):
        store.insert(device(float(i), "n0", "jidle", 0.01))
        store.insert(device(float(i), "n0", "jbusy", 0.8))
    events = IdleAcceleratorDetector().scan(store)
    assert [e.job for e in events] == ["jidle"]


def test_memory_underuse_requires_large_memory_flag():
    store = MetricStore()
    for i in range(3):
        store.insert(device(float(i), "n0", "j1", 0.05))
    man_small = {"j1": JobManifest(job_id="j1")}
    man_large = {"j1": JobManifest(job_id="j1",
                                   extra={"large_memory": "1"})}
    assert MemoryUnderuseDetector().scan(store, man_small) == []
    events = MemoryUnderuseDetector().scan(store, man_large)
    assert len(events) == 1 and events[0].detector == "memory_underuse"


def test_low_participation():
    store = MetricStore()
    for i in range(3):
        store.insert(perf(float(i), "n0", "j1"))  # only 1 of 8 hosts works
    man = {"j1": JobManifest(job_id="j1", num_hosts=8)}
    events = LowParticipationDetector().scan(store, man)
    assert len(events) == 1
    assert events[0].fields["active_hosts"] == 1


def test_low_mfu():
    store = MetricStore()
    for i in range(4):
        store.insert(perf(float(i), "n0", "jslow", mfu=0.02))
        store.insert(perf(float(i), "n0", "jfast", mfu=0.5))
    events = LowMfuDetector().scan(store)
    assert [e.job for e in events] == ["jslow"]


def test_straggler():
    store = MetricStore()
    for i in range(5):
        for h in ("n0", "n1", "n2", "n3"):
            dt = 3.0 if h == "n3" else 1.0
            store.insert(perf(float(i), h, "j1", step_time_s=dt))
    events = StragglerDetector(ratio=1.5).scan(store)
    assert len(events) == 1 and events[0].fields["host"] == "n3"


def test_bank_streaming_and_write_back():
    bank = DetectorBank()
    store = MetricStore()
    evs = []
    for i in range(5):
        rec = perf(float(i), "n0", "j1", gflops=0.0, steps_per_s=0.0)
        store.insert(rec)
        evs.extend(bank.feed(rec))
    assert any(e.detector == "hang" for e in evs)
    DetectorBank.write_back(store, evs)
    assert any(r.kind == "event" for r in store.records)
