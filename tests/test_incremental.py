"""Incremental query engine: segment-keyed partial-aggregate caches.

The contract under test (docs/incremental.md): caching per-segment
partial aggregation states keyed by ``(segment uid, plan fingerprint)``
must never change query results — cold (empty cache), warm (all sealed
segments cached), and every mixed state in between return
**byte-identical** rows, across append→seal transitions, restart from
disk, and whole-segment adoption/migration, on single stores and
sharded stores alike.  ``explain()`` counters prove that a warm re-run
recomputes only the unsealed buffer plus newly sealed segments.
"""

import math

import pytest

from conftest import assert_rows_equal, random_records, random_store
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES

from repro.core.aggregator import Aggregator, MetricStore
from repro.core.columnar import (SCAN_MEMO_MAX, PartialAggregateCache,
                                 segment_uid)
from repro.core.schema import MetricRecord
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import (QueryHandle, _split_pipeline,
                                   compile_scatter_plan, query)

RECORDS = random_records(seed=11, n=420)
ALL_QUERIES = SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES
MERGEABLE = [q for q in ALL_QUERIES
             if compile_scatter_plan(_split_pipeline(q)) is not None]
NON_MERGEABLE = [q for q in ALL_QUERIES if q not in MERGEABLE]

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")


def rows_identical(got, want, q):
    """Byte-identical row lists: same order, keys, types and values
    (NaN compares equal to NaN; int 3 is NOT float 3.0)."""
    assert len(got) == len(want), \
        f"{q!r}: {len(got)} rows vs {len(want)}"
    for i, (g, w) in enumerate(zip(got, want)):
        assert set(g) == set(w), f"{q!r} row {i}: keys differ"
        for k in w:
            gv, wv = g[k], w[k]
            if isinstance(gv, float) and isinstance(wv, float) \
                    and math.isnan(gv) and math.isnan(wv):
                continue
            assert type(gv) is type(wv) and gv == wv, \
                f"{q!r} row {i} field {k}: {gv!r} != {wv!r}"


def clear_partial_caches(store):
    for shard in getattr(store, "shards", [store]):
        shard.partial_cache.clear()


def run_cached(store, q):
    """The cache-aware path for either store flavor."""
    if getattr(store, "is_sharded", False):
        return store.query(q)
    return query(store, q, engine="incremental")


# ------------------------------------------------------ cold/warm parity --

@pytest.fixture(scope="module")
def single():
    return random_store(records=RECORDS, seal_threshold=67)


@pytest.fixture(scope="module", params=[1, 2, 7])
def sharded(request):
    return random_store(records=RECORDS, shards=request.param,
                        seal_threshold=53)


@pytest.mark.parametrize("q", MERGEABLE)
def test_cached_vs_uncached_single_store(q, single):
    clear_partial_caches(single)
    cold = run_cached(single, q)
    warm = run_cached(single, q)
    warm2 = run_cached(single, q)
    rows_identical(warm, cold, q)
    rows_identical(warm2, cold, q)
    stats = single.last_query_stats
    # data can still defeat the partial kernels (e.g. an eval whose
    # row-engine result is non-float); the fallback must say so
    assert stats["mode"] in ("incremental", "full")
    if stats["mode"] == "incremental":
        assert stats["segments_computed"] == 0


@pytest.mark.parametrize("q", MERGEABLE)
def test_cached_vs_uncached_sharded(q, sharded):
    clear_partial_caches(sharded)
    cold = run_cached(sharded, q)
    warm = run_cached(sharded, q)
    rows_identical(warm, cold, q)
    stats = sharded.last_query_stats
    assert stats["mode"] in ("scatter_gather", "exact_gather")
    if stats["mode"] == "scatter_gather":
        assert stats["segments_computed"] == 0


@pytest.mark.parametrize("q", NON_MERGEABLE)
def test_non_mergeable_falls_back_exactly(q, single):
    got = query(single, q, engine="incremental")
    assert single.last_query_stats == {"mode": "full"}
    assert_rows_equal(got, query(single, q), q)


def test_incremental_vs_exact_engines_non_quantile(single):
    # without quantiles the partial algebra is exact: the incremental
    # path must agree with the fused columnar kernels and the row
    # oracle (within float-merge tolerance)
    q = ("search kind=perf | stats count avg(gflops) min(gflops) "
         "max(gflops) stdev(gflops) dc(host) by job")
    clear_partial_caches(single)
    inc = run_cached(single, q)
    assert_rows_equal(inc, query(single, q), q)
    assert_rows_equal(inc, query(single, q, engine="rows"), q)


# ---------------------------------------------------- append -> seal ------

def test_append_seal_transitions_single_store():
    store = MetricStore(seal_threshold=60)
    feed = iter(random_records(seed=12, n=400))
    for _ in range(150):
        store.insert(next(feed))
    q = FLEET_Q
    warm_prev = run_cached(store, q)
    fed = 150
    while fed < 400:
        # batches of 45 cross a seal boundary every other iteration
        for _ in range(min(45, 400 - fed)):
            store.insert(next(feed))
            fed += 1
        warm = run_cached(store, q)
        stats = dict(store.last_query_stats)
        clear_partial_caches(store)
        uncached = run_cached(store, q)
        rows_identical(warm, uncached, q)
        # at most one seal per 45-record batch at threshold 60, so the
        # warm pass recomputes at most one segment (plus the buffer)
        assert stats["segments_computed"] <= 1
        warm_prev = warm
    assert warm_prev  # data actually flowed


def test_requery_after_append_recomputes_only_buffer(single):
    clear_partial_caches(single)
    run_cached(single, FLEET_Q)
    n_sealed = len(single._sealed)
    # buffer-only append: no new seal at threshold 67
    single.insert(MetricRecord(99991.0, "n0", "alpha.1", "perf",
                               {"gflops": 123.0}))
    run_cached(single, FLEET_Q)
    stats = single.last_query_stats
    assert stats["segments_cached"] == n_sealed
    assert stats["segments_computed"] == 0
    assert stats["buffer_rows"] == len(single._buffer)


def test_tail_only_queries_share_cached_partials(single):
    clear_partial_caches(single)
    base = "search kind=perf | stats avg(gflops) count by job"
    run_cached(single, base)
    e1 = single.explain(base)
    # same partial prefix, different tails -> same fingerprint, all hits
    for tail in (" | sort -avg_gflops", " | where count>3 | head 2"):
        e2 = single.explain(base + tail)
        assert e2["fingerprint"] == e1["fingerprint"]
        run_cached(single, base + tail)
        assert single.last_query_stats["segments_computed"] == 0


# ----------------------------------------------------------- durability --

def test_restart_preserves_segment_uids_and_results(tmp_path):
    store = random_store(records=RECORDS, seal_threshold=37,
                         directory=tmp_path / "s")
    uids = [seg.uid for seg in store._sealed]
    assert all(uids) and len(set(uids)) == len(uids)
    before = run_cached(store, FLEET_Q)
    store.close()
    re = MetricStore(seal_threshold=37, directory=tmp_path / "s")
    assert [seg.uid for seg in re._sealed] == uids
    after_cold = run_cached(re, FLEET_Q)
    rows_identical(after_cold, before, FLEET_Q)
    # second run over the restarted store is fully cached
    rows_identical(run_cached(re, FLEET_Q), before, FLEET_Q)
    assert re.last_query_stats["segments_cached"] == len(uids)
    re.close()


def test_restart_sharded_parity(tmp_path):
    sh = random_store(records=RECORDS, shards=3, seal_threshold=37,
                      directory=tmp_path / "fleet")
    before = run_cached(sh, FLEET_Q)
    sh.close()
    re = ShardedAggregator(num_shards=3, seal_threshold=37,
                           directory=tmp_path / "fleet")
    rows_identical(run_cached(re, FLEET_Q), before, FLEET_Q)
    rows_identical(run_cached(re, FLEET_Q), before, FLEET_Q)
    assert re.last_query_stats["segments_computed"] == 0
    re.close()


def test_legacy_manifest_without_uid_gets_content_uid(tmp_path):
    import json
    store = random_store(records=RECORDS[:150], seal_threshold=40,
                         directory=tmp_path / "s")
    uids = [seg.uid for seg in store._sealed]
    store.close()
    # simulate a pre-uid manifest (earlier format revisions)
    for man in sorted((tmp_path / "s" / "segments").glob("seg-*.json")):
        doc = json.loads(man.read_text())
        doc.pop("uid")
        man.write_text(json.dumps(doc))
    re = MetricStore(seal_threshold=40, directory=tmp_path / "s")
    # uid is a pure function of content, so the fallback derivation
    # reproduces the original values
    assert [seg.uid for seg in re._sealed] == uids
    re.close()


# ------------------------------------------------- adoption / migration --

def test_adopted_segment_keeps_uid_and_cached_results(tmp_path):
    src = random_store(records=RECORDS[:200], seal_threshold=50,
                       directory=tmp_path / "src")
    src_uids = [seg.uid for seg in src._sealed]
    src.close()
    dst = MetricStore(seal_threshold=50, directory=tmp_path / "dst")
    for man in sorted((tmp_path / "src" / "segments").glob("seg-*.json")):
        dst.adopt_segment(man)
    assert [seg.uid for seg in dst._sealed] == src_uids
    cold = run_cached(dst, FLEET_Q)
    rows_identical(run_cached(dst, FLEET_Q), cold, FLEET_Q)
    assert dst.last_query_stats["segments_cached"] == len(src_uids)
    dst.close()


def test_migration_into_sharded_store_parity_and_cache_survival(tmp_path):
    src = random_store(records=RECORDS[:200], seal_threshold=40,
                       directory=tmp_path / "src")
    src.close()
    sh = random_store(records=RECORDS[200:], shards=3, policy="time",
                      seal_threshold=40)
    prime = run_cached(sh, FLEET_Q)
    assert prime is not None
    sealed_before = sum(len(s._sealed) for s in sh.shards)
    hits_before = sh.partial_cache_hits
    n = sh.adopt_store_dir(tmp_path / "src")
    assert n == 200
    warm = run_cached(sh, FLEET_Q)
    stats = sh.last_query_stats
    sealed_after = sum(len(s._sealed) for s in sh.shards)
    # pre-adoption segments still served from cache; only segments the
    # migration brought in (adopted whole or re-sealed from re-ingest)
    # were recomputed
    assert stats["segments_cached"] >= sealed_before
    assert stats["segments_computed"] == sealed_after - sealed_before
    assert sh.partial_cache_hits > hits_before
    clear_partial_caches(sh)
    rows_identical(run_cached(sh, FLEET_Q), warm, FLEET_Q)
    # and the merged data matches a single store over the same records
    single = random_store(records=RECORDS, seal_threshold=40)
    got = {r["job"]: r for r in warm}
    want = {r["job"]: r for r in query(single, FLEET_Q)}
    assert got.keys() == want.keys()
    for job, w in want.items():
        assert got[job]["count"] == w["count"]
        assert abs(got[job]["avg_gflops"] - w["avg_gflops"]) <= 1e-9
    sh.close()


# ------------------------------------------------------------- explain ---

def test_explain_reports_cache_state(single):
    clear_partial_caches(single)
    e0 = single.explain(FLEET_Q)
    assert e0["mode"] == "incremental"
    assert e0["segments"]["cached"] == 0
    assert e0["segments"]["sealed"] == len(single._sealed)
    run_cached(single, FLEET_Q)
    e1 = single.explain(FLEET_Q)
    assert e1["segments"]["cached"] == e1["segments"]["sealed"]
    assert e1["cache"]["entries"] >= e1["segments"]["sealed"]
    # explain is pure introspection: counters unchanged by explain
    assert single.explain(FLEET_Q)["cache"] == e1["cache"]
    e_full = single.explain("search kind=perf | sort -gflops | head 3")
    assert e_full["mode"] == "full"
    assert "cache" in e_full


def test_sharded_explain_reports_cache_state(sharded):
    clear_partial_caches(sharded)
    e0 = sharded.explain(FLEET_Q)
    assert e0["mode"] == "scatter_gather"
    assert e0["segments"]["cached"] == 0
    run_cached(sharded, FLEET_Q)
    e1 = sharded.explain(FLEET_Q)
    assert e1["segments"]["cached"] == e1["segments"]["sealed"] > 0
    assert e1["cache"]["entries"] == e1["segments"]["sealed"]
    assert e1["shards"] == sharded.num_shards


# ----------------------------------------------------- cache mechanics ---

def test_partial_cache_lru_bound_and_counters():
    cache = PartialAggregateCache(max_entries=3)
    for i in range(5):
        cache.put((f"seg{i}", "fp"), {("k",): {"count": i}})
    assert len(cache) == 3 and cache.evictions == 2
    assert cache.get(("seg0", "fp")) is None  # evicted (oldest)
    assert cache.get(("seg4", "fp"))[("k",)]["count"] == 4
    assert cache.misses == 1 and cache.hits == 1
    # peek neither counts nor reorders
    assert cache.peek(("seg4", "fp"))
    assert cache.hits == 1
    # drop_segment removes every plan's entry for that segment
    cache.put(("seg4", "fp2"), {})
    assert cache.drop_segment("seg4") == 2
    assert not cache.peek(("seg4", "fp"))


def test_partial_cache_entries_zero_disables_caching():
    store = MetricStore(seal_threshold=60, partial_cache_entries=0)
    for rec in RECORDS[:200]:
        store.insert(rec)
    a = run_cached(store, FLEET_Q)
    b = run_cached(store, FLEET_Q)  # must not crash on put-evict
    rows_identical(b, a, FLEET_Q)
    assert len(store.partial_cache) == 0
    assert store.last_query_stats["segments_cached"] == 0
    assert store.last_query_stats["segments_computed"] == \
        len(store._sealed)


def test_oversized_segment_sweep_bypasses_cache():
    # a plan sweeping more sealed segments than the cache can hold
    # would thrash the LRU (0% hits + collateral eviction), so the
    # sweep skips the cache and says so — results stay byte-identical
    store = MetricStore(seal_threshold=60, partial_cache_entries=2)
    for rec in RECORDS[:300]:
        store.insert(rec)
    assert len(store._sealed) == 5
    a = run_cached(store, FLEET_Q)
    b = run_cached(store, FLEET_Q)
    rows_identical(b, a, FLEET_Q)
    stats = store.last_query_stats
    assert stats["cache_bypassed"] and stats["segments_cached"] == 0
    assert stats["segments_computed"] == 5
    assert len(store.partial_cache) == 0  # nothing clobbered into it


def test_streaming_view_sees_postprocess_state_changes():
    # a manifests dict can gain a job with no new metric records; the
    # postprocess must re-run even though the store version (and thus
    # the query rows) did not change
    from repro.core.daemon import JobManifest
    from repro.core.dashboards import (streaming_specialized_views,
                                       view_low_participation)
    store = MetricStore(seal_threshold=25)
    for h in range(1):
        for s in range(10):
            store.insert(MetricRecord(1000.0 + s, f"n{h}", "jobQ", "perf",
                                      {"gflops": 10.0, "step": s}))
    manifests = {}
    views = streaming_specialized_views(store, manifests)
    assert views["low_participation"].refresh() == []
    r_empty = views["low_participation"].rendered()
    manifests["jobQ"] = JobManifest(job_id="jobQ", num_hosts=8)
    want = view_low_participation(store, manifests)
    assert want  # one host active out of 8 allocated
    assert views["low_participation"].refresh() == want
    assert views["low_participation"].rendered() is not r_empty


def test_store_partial_cache_bounded():
    store = MetricStore(seal_threshold=97, partial_cache_entries=6)
    for rec in RECORDS:
        store.insert(rec)
    queries = [f"search kind=perf | stats count avg(gflops) by {by}"
               for by in ("job", "host", "app", "kind")]
    for q in queries:
        run_cached(store, q)
    assert len(store.partial_cache) <= 6
    assert store.partial_cache.evictions > 0


def test_version_memos_evicted_on_write():
    store = MetricStore(seal_threshold=97)
    for rec in RECORDS[:120]:
        store.insert(rec)
    _ = store.records
    store.scan(kind="perf", fields=("gflops",))
    assert "records" in store._cache and "scans" in store._cache
    store.insert(RECORDS[200])
    assert not store._cache  # superseded memos are gone immediately
    # the partial cache is NOT version-scoped: prime then insert
    run_cached(store, FLEET_Q)
    entries = len(store.partial_cache)
    store.insert(RECORDS[201])
    assert len(store.partial_cache) == entries


def test_scan_memo_is_lru_bounded():
    store = MetricStore(seal_threshold=97)
    for rec in RECORDS[:120]:
        store.insert(rec)
    for i in range(SCAN_MEMO_MAX + 8):
        store.scan(since=float(i), fields=("gflops",))
    memo = store._cache["scans"][1]
    assert len(memo) == SCAN_MEMO_MAX
    # oldest keys evicted, newest retained
    assert float(SCAN_MEMO_MAX + 7) in {k[2] for k in memo}
    assert 0.0 not in {k[2] for k in memo}


def test_segment_uid_is_content_derived():
    keys = [b"b" * 12, b"a" * 12, b"c" * 12]
    assert segment_uid(keys) == segment_uid(reversed(keys))
    assert segment_uid(keys) != segment_uid(keys[:2])
    store = random_store(records=RECORDS[:150], seal_threshold=40)
    assert all(seg.uid for seg in store._sealed)
    buffer_units = [u for _s, u in store.segment_units() if u is None]
    assert len(buffer_units) == (1 if store._buffer else 0)


def test_incremental_transient_build_matches_full_rebuild():
    # interleave inserts with queries (each query snapshots the buffer
    # into a transient segment, extended incrementally on the next
    # build) and compare against a control store that never queried —
    # records, scans, and every engine's results must be identical
    from repro.core.columnar import columns_from_records
    from repro.core.schema import encode_line
    recs = random_records(seed=21, n=260)
    # shuffle timestamps so the buffer is NOT insertion-ordered and
    # duplicate some so the stable tie-break is exercised
    mixed = []
    for i, r in enumerate(recs):
        ts = float(recs[(i * 7) % len(recs)].ts)
        mixed.append(MetricRecord(ts if i % 3 else recs[0].ts, r.host,
                                  r.job, r.kind, dict(r.fields)))
    live = MetricStore(seal_threshold=500)    # everything stays buffered
    control = MetricStore(seal_threshold=500)
    queries = ["stats count avg(gflops) by job host",
               "search kind=perf | stats first(app) last(gflops) by job",
               "sort -gflops | head 5", "dedup job app"]
    for i, rec in enumerate(mixed):
        live.insert(rec)
        control.insert(rec)
        if i % 17 == 0:
            query(live, queries[i % len(queries)])  # builds transient
    assert [encode_line(r) for r in live.records] == \
        [encode_line(r) for r in control.records]
    full = columns_from_records(control._buffer)
    inc = live.segment_units()[-1][0]
    assert inc.n == full.n
    assert set(inc.field_names) == set(full.field_names)
    for q in queries + ["search app=gem* | stats dc(host) by job",
                        "timechart span=40 p90(gflops) by host"]:
        assert_rows_equal(query(live, q), query(control, q), q)
        assert_rows_equal(query(live, q, engine="rows"),
                          query(control, q, engine="rows"), q)


# -------------------------------------------------------- query handles --

def test_query_handle_memoizes_until_version_changes(single):
    h = QueryHandle(single, FLEET_Q)
    a = h.refresh()
    assert h.refresh() is a  # no new data: same rows object
    single.insert(MetricRecord(99992.0, "n1", "beta.2", "perf",
                               {"gflops": 321.0}))
    b = h.refresh()
    assert b is not a
    clear_partial_caches(single)
    rows_identical(run_cached(single, FLEET_Q), b, FLEET_Q)
    assert h.explain()["incremental"] and h.refreshes == 2


def test_query_handle_non_mergeable_and_plain_rows(single):
    h = QueryHandle(single, "search kind=perf | sort -gflops | head 4")
    assert_rows_equal(h.refresh(),
                      query(single, "search kind=perf | sort -gflops "
                                    "| head 4"), "handle-fallback")
    assert h.explain()["mode"] == "full"
    rows = [{"x": 1.0}, {"x": 2.0}]
    h2 = QueryHandle(rows, "stats sum(x)")
    assert h2.refresh() == [{"sum_x": 3.0}]


def test_query_handle_over_sharded_store(sharded):
    h = QueryHandle(sharded, FLEET_Q)
    a = h.refresh()
    assert h.refresh() is a
    assert h.last_stats["mode"] == "scatter_gather"
    rows_identical(a, query(sharded, FLEET_Q), FLEET_Q)


def test_aggregator_watch_refresh_loop(tmp_path):
    agg = Aggregator(tmp_path / "inbox", store=MetricStore(
        seal_threshold=30))
    h = agg.watch("search kind=perf | stats count by job")
    assert agg.refresh_watches()[h.q] == []
    for rec in RECORDS[:100]:
        agg.store.insert(rec)
    total = sum(r["count"] for r in h.refresh())
    want = len([r for r in RECORDS[:100] if r.kind == "perf"])
    assert total == want
    assert h.last_stats["mode"] == "incremental"


# ------------------------------------------------------ streaming views --

def test_streaming_views_match_one_shot_views():
    from repro.core.daemon import JobManifest
    from repro.core.dashboards import (streaming_specialized_views,
                                       view_idle_accelerators,
                                       view_low_participation,
                                       view_memory_underuse)
    store = MetricStore(seal_threshold=25)
    manifests = {}
    for j in range(4):
        job = f"jobA.{j}"
        manifests[job] = JobManifest(
            job_id=job, app="gemma", num_hosts=4,
            extra={"large_memory": "1"} if j == 1 else {})
        for h in range(4 if j != 2 else 1):
            for s in range(12):
                store.insert(MetricRecord(
                    1000.0 + s * 10.0, f"n{j}-{h}", job, "perf",
                    {"gflops": 100.0, "mfu": 0.4, "step": s}))
                store.insert(MetricRecord(
                    1000.0 + s * 10.0 + 0.5, f"n{j}-{h}", job, "device",
                    {"hbm_frac_used": 0.02 if j in (0, 1) else 0.6}))
    views = streaming_specialized_views(store, manifests)
    assert views["idle_accelerators"].refresh() == \
        view_idle_accelerators(store)
    assert views["memory_underuse"].refresh() == \
        view_memory_underuse(store, manifests)
    assert views["low_participation"].refresh() == \
        view_low_participation(store, manifests)
    # renders are memoized until the rows change
    r1 = views["idle_accelerators"].rendered()
    assert views["idle_accelerators"].rendered() is r1
    assert views["idle_accelerators"].renders == 1
    store.insert(MetricRecord(5000.0, "nZ", "jobA.0", "device",
                              {"hbm_frac_used": 0.01}))
    assert views["idle_accelerators"].refresh() == \
        view_idle_accelerators(store)
    assert views["idle_accelerators"].rendered() is not r1
    # idle + memory views share one cached aggregation prefix
    fp_idle = views["idle_accelerators"].explain().get("fingerprint")
    fp_mem = views["memory_underuse"].explain().get("fingerprint")
    assert fp_idle == fp_mem


# ------------------------------------------------- multi-key group-by -----

MULTI_KEY_QUERIES = [
    "stats count by job host",
    "stats count by job host app",           # app has missing rows
    "stats avg(gflops) min(step) by app job kind",
    "search kind=perf | stats dc(host) sum(gflops) by app job",
]


@pytest.mark.parametrize("q", MULTI_KEY_QUERIES)
def test_multi_key_string_group_by_parity(q, single):
    got = query(single, q)
    assert_rows_equal(got, query(single, q, engine="rows"), q)
    keys = [tuple(sorted(r.items())) for r in got]
    assert len(set(keys)) == len(keys)  # no duplicated groups


def test_multi_key_fast_path_engages():
    from repro.core.splunklite import _batch_from_store, _group_str_fast
    store = random_store(records=RECORDS, seal_threshold=67)
    batch = _batch_from_store(store, [])
    g = _group_str_fast(batch, ["job", "host"])
    assert g is not None and g.G == len(
        {(str(r.job), str(r.host)) for r in RECORDS})
    assert g.keys == sorted(g.keys)
    # numeric key columns are not dictionary-encoded: fast path declines
    assert _group_str_fast(batch, ["job", "step"]) is None
