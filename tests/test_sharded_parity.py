"""Shard fan-out parity: every splunklite pipeline must return the same
results through a :class:`ShardedAggregator` (scatter/gather over N
shards) as through the single ``ColumnarMetricStore`` and the legacy
row executor.

Exactness contract (docs/sharding.md): all aggregates merge exactly
except quantiles, whose distributed P²-summary merge carries a bounded
error — asserted here as containment in the field's value range plus
the 0.35·spread bound shared with ``test_sketches``.  Shard counts
{1, 2, 7} and skewed layouts (empty shard, single-record shard, all
data on one shard) all run the same workload as the other two parity
suites.
"""

import math

import numpy as np
import pytest

from conftest import (_value_eq, assert_rows_equal, random_records,
                      random_store)
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES

from repro.core.aggregator import Aggregator, MetricStore
from repro.core.schema import MetricRecord, encode_line
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import (QueryError, _parse_aggs, _split_pipeline,
                                   _stats_split, _timechart_split,
                                   compile_scatter_plan, query)

ALL_QUERIES = SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES
SHARD_COUNTS = [1, 2, 7]

RECORDS = random_records(seed=3, n=420)

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")


# ------------------------------------------------------------ comparators --

def quantile_fields(q):
    """{output column: aggregated field} for quantile aggregations — the
    only approximately-merged aggregates."""
    out = {}
    for toks in _split_pipeline(q):
        cmd, args = toks[0], toks[1:]
        if cmd == "stats":
            agg_tokens, _by = _stats_split(args)
        elif cmd == "timechart":
            _span, agg_tokens, _by = _timechart_split(args)
        else:
            continue
        for name, fieldname, outname in _parse_aggs(agg_tokens):
            if name == "median" or (name.startswith("p")
                                    and name[1:].isdigit()):
                out[outname] = fieldname
    return out


def _field_bounds(records, fname):
    vals = []
    for r in records:
        v = r.fields.get(fname)
        if isinstance(v, (int, float)) and not (
                isinstance(v, float) and math.isnan(v)):
            vals.append(float(v))
    if not vals:
        return (math.nan, math.nan, 0.0)
    lo, hi = min(vals), max(vals)
    return (lo, hi, hi - lo)


def assert_sharded_rows(got, want, q, records=RECORDS):
    """Exact equality, except quantile outputs which must obey the
    documented merge error bound."""
    approx = quantile_fields(q)
    assert len(got) == len(want), \
        f"{q!r}: {len(got)} rows (sharded) vs {len(want)} (single)"
    for i, (g, w) in enumerate(zip(got, want)):
        assert set(g) == set(w), f"{q!r} row {i}: keys {set(g)} != {set(w)}"
        for k in w:
            if k in approx and not _value_eq(g[k], w[k]):
                gv, wv = g[k], w[k]
                assert isinstance(gv, float) and isinstance(wv, float), \
                    f"{q!r} row {i} field {k}: {gv!r} vs {wv!r}"
                assert math.isnan(gv) == math.isnan(wv), \
                    f"{q!r} row {i} field {k}: {gv!r} vs {wv!r}"
                if math.isnan(wv):
                    continue
                lo, hi, spread = _field_bounds(records, approx[k])
                assert lo - 1e-9 <= gv <= hi + 1e-9, \
                    f"{q!r} row {i} field {k}: {gv} outside [{lo}, {hi}]"
                assert abs(gv - wv) <= 0.35 * spread + 1e-6, \
                    f"{q!r} row {i} field {k}: |{gv} - {wv}| > 0.35*{spread}"
            else:
                assert _value_eq(g[k], w[k]), \
                    f"{q!r} row {i} field {k}: {g[k]!r} != {w[k]!r}"


# ----------------------------------------------------------------- stores --

@pytest.fixture(scope="module")
def single():
    return random_store(records=RECORDS)


@pytest.fixture(scope="module", params=SHARD_COUNTS)
def sharded(request):
    return random_store(records=RECORDS, shards=request.param,
                        seal_threshold=53)


# ----------------------------------------------------------------- parity --

@pytest.mark.parametrize("q", ALL_QUERIES)
def test_sharded_parity(q, single, sharded):
    assert_sharded_rows(query(sharded, q), query(single, q), q)


def test_sharded_rows_engine_matches_single_rows_engine(single, sharded):
    # third leg of the three-way oracle: the sharded store's row
    # executor (canonically ordered gather) vs the single store's
    for q in ALL_QUERIES:
        assert_rows_equal(query(sharded, q, engine="rows"),
                          query(single, q, engine="rows"), q)


def test_sharded_empty_query_returns_all_records(single, sharded):
    got = query(sharded, "")
    want = query(single, "")
    assert_rows_equal(got, want, "<empty>")


def test_sharded_unknown_command_raises(sharded):
    with pytest.raises(QueryError):
        query(sharded, "stats count | bogus x")


# ------------------------------------------------------------ skew layouts --

def _route_all_on_last(rec, n):
    return n - 1


def _route_one_record_apart(rec, n):
    # exactly one record (the first ts) on shard 0, the rest on shard 1+
    return 0 if float(rec.ts) == float(RECORDS[0].ts) else 1


def _route_leave_last_empty(rec, n):
    return hash_route_stable(rec.host, max(n - 1, 1))


def hash_route_stable(host, n):
    from repro.core.shards import _hash_route
    return _hash_route(host, n)


SKEWS = {
    "all_on_one_shard": _route_all_on_last,
    "single_record_shard": _route_one_record_apart,
    "empty_shard": _route_leave_last_empty,
}

SKEW_QUERIES = [
    "search kind=perf | stats count",
    "search kind=perf | stats avg(gflops) sum(gflops) min(gflops) "
    "max(gflops) by host",
    "stats stdev(gflops) range(gflops) dc(host) dc(app) by kind",
    "stats median(gflops) p25(gflops) p90(gflops) p99(gflops) by job",
    "search kind=perf | timechart span=45 avg(gflops) count by job",
    "search kind=perf | sort -gflops | head 7",
    "search kind=perf | stats first(app) last(gflops)",
    "dedup job app",
]


@pytest.mark.parametrize("skew", sorted(SKEWS))
@pytest.mark.parametrize("shards", [2, 7])
def test_skewed_shard_parity(skew, shards, single):
    store = random_store(records=RECORDS, shards=shards,
                         policy=SKEWS[skew], seal_threshold=29)
    sizes = store.shard_sizes()
    if skew == "all_on_one_shard":
        assert sizes[-1] == len(RECORDS) and not any(sizes[:-1])
    elif skew == "single_record_shard":
        assert sizes[0] == 1
    else:
        assert sizes[-1] == 0  # at least one genuinely empty shard
    for q in SKEW_QUERIES:
        assert_sharded_rows(query(store, q), query(single, q), q)


# ------------------------------------------------------- routing golden --

def test_hash_route_golden_values():
    """`_hash_route` is a wire contract, not just an implementation
    detail: remote workers and coordinators (possibly on different
    platforms / Python versions) must agree on record placement, and
    durable shard sets must reopen with identical routing.  Pin the
    blake2b-64 host digests and the derived shard indices for a fixed
    host list — if this test ever fails, the hash changed and every
    persisted shard set on disk would silently mis-route."""
    import hashlib
    from repro.core.shards import _hash_route
    golden = {
        # host: (little-endian blake2b-64 digest, %2, %4, %7)
        "n0": (14278672310350874025, 1, 1, 5),
        "n1": (18235861091803621825, 1, 1, 6),
        "n2": (14616293611457783150, 0, 2, 3),
        "n3": (4982723058291715516, 0, 0, 3),
        "node000-0": (11489254741126860214, 0, 2, 6),
        "node042-7": (4320719588347712696, 0, 0, 6),
        "cobra-e01": (15046485132095626312, 0, 0, 5),
        "draco.17": (16332559337239019389, 1, 1, 4),
        "": (13020603013274838756, 0, 0, 5),
    }
    for host, (digest, m2, m4, m7) in golden.items():
        raw = int.from_bytes(
            hashlib.blake2b(host.encode("utf-8"), digest_size=8).digest(),
            "little")
        assert raw == digest, (host, raw)
        assert _hash_route(host, 2) == m2, host
        assert _hash_route(host, 4) == m4, host
        assert _hash_route(host, 7) == m7, host


# -------------------------------------------------------- close lifecycle --

def test_close_is_idempotent_and_guards_use_after_close():
    """Regression: a query() after close() used to silently recreate
    the shard thread pool over closed stores.  close() must be
    idempotent and later use must fail loudly."""
    store = random_store(records=RECORDS[:80], shards=2, seal_threshold=17)
    assert query(store, "stats count")[0]["count"] == 80
    store.close()
    store.close()  # idempotent
    assert store._pool is None
    for call in (lambda: store.query("stats count"),
                 lambda: store.insert(RECORDS[0]),
                 lambda: store.seal(),
                 lambda: store.scan(kind="perf")):
        with pytest.raises(RuntimeError, match="closed"):
            call()
    assert store._pool is None  # nothing revived the pool


# ------------------------------------------------------------ plan choice --

def test_scatter_plan_used_for_mergeable_aggregations(single):
    store = random_store(records=RECORDS, shards=3)
    q = ("search kind=perf | stats avg(gflops) p90(gflops) dc(host) "
         "count by job | sort -avg_gflops")
    assert_sharded_rows(query(store, q), query(single, q), q)
    assert store.scatter_queries == 1 and store.fallback_queries == 0
    plan = store.explain(q)
    assert plan["mode"] == "scatter_gather"
    assert set(plan["columns"]) == {"gflops", "host", "job"}
    # order-dependent aggregates must go to the exact gather instead
    q2 = "search kind=perf | stats first(app) by job"
    assert_sharded_rows(query(store, q2), query(single, q2), q2)
    assert store.fallback_queries == 1
    assert store.explain(q2)["mode"] == "exact_gather"


def test_non_mergeable_prefix_forces_exact_gather(single):
    store = random_store(records=RECORDS, shards=3)
    # a sort before stats is order-dependent -> no scatter plan
    q = "search kind=perf | sort -gflops | head 20 | stats avg(gflops)"
    assert compile_scatter_plan(_split_pipeline(q)) is None
    assert_sharded_rows(query(store, q), query(single, q), q)
    assert store.scatter_queries == 0 and store.fallback_queries == 1


def test_dc_regression_naive_sum_merge_would_overcount(single):
    """`stats dc(app)` must union per-shard label sets; summing the
    per-shard distinct counts (the latent bug class) over-counts any
    app seen on two shards."""
    store = random_store(records=RECORDS, shards=3)
    got = query(store, "stats dc(app)")[0]["dc_app"]
    want = query(single, "stats dc(app)")[0]["dc_app"]
    assert got == want
    naive = sum(query(s, "stats dc(app)")[0]["dc_app"]
                for s in store.shards if len(s))
    assert naive > want, "workload must make a sum-merge observable"
    assert store.scatter_queries >= 1  # dc went through the merge path


def test_mixed_type_column_falls_back_to_exact_gather(single):
    # an obj column (mixed str/num) defeats the vectorized eval prefix
    # on the shard that holds it; the whole query must re-run exact
    recs = list(RECORDS[:40])
    recs.append(MetricRecord(9000.0, "n0", "alpha.1", "perf",
                             {"status": "ok"}))
    recs.append(MetricRecord(9001.0, "n1", "alpha.1", "perf",
                             {"status": 5}))
    sh = random_store(records=recs, shards=2, seal_threshold=7)
    si = random_store(records=recs)
    q = "eval x=status+1 | stats count(x) avg(x)"
    assert_sharded_rows(query(sh, q), query(si, q), q, records=recs)


# ------------------------------------------------------------- store-like --

def test_sharded_store_surface_matches_single(single, sharded):
    assert len(sharded) == len(single)
    assert sharded.jobs() == single.jobs()
    assert sharded.kinds() == single.kinds()
    assert sharded.hosts() == single.hosts()
    assert sharded.hosts("alpha.1") == single.hosts("alpha.1")
    got = [encode_line(r) for r in sharded.select(job="beta.2",
                                                  kind="perf")]
    want = [encode_line(r) for r in single.select(job="beta.2",
                                                  kind="perf")]
    assert got == want
    assert [encode_line(r) for r in sharded.records] == \
        [encode_line(r) for r in single.records]


def test_sharded_dedup_matches_single():
    sh = random_store(records=RECORDS, shards=3)
    si = random_store(records=RECORDS)
    for rec in RECORDS[::5]:  # at-least-once retransmits
        assert not sh.insert(rec)
        assert not si.insert(rec)
    assert sh.duplicates_dropped == si.duplicates_dropped == len(
        RECORDS[::5])
    assert len(sh) == len(si)


def test_sharded_scan_merges_shard_scans(single, sharded):
    a = single.scan(kind="perf", fields=("gflops", "step"))
    b = sharded.scan(kind="perf", fields=("gflops", "step"))
    assert a.n == b.n
    # same multiset of (ts, host, gflops-or-nan) samples
    def key_set(sc):
        v, p = sc.field("gflops")
        return sorted(
            (float(t), str(sc.host_vocab[h]),
             float(v[i]) if p[i] and not np.isnan(v[i]) else None)
            for i, (t, h) in enumerate(zip(sc.ts, sc.host_codes)))
    assert key_set(a) == key_set(b)


def test_dashboards_and_detectors_identical_over_sharded_store():
    from repro.core.daemon import JobManifest
    from repro.core.dashboards import (job_metric_series,
                                       job_statistical_view,
                                       view_idle_accelerators)
    from repro.core.detectors import DetectorBank
    def fill(store):
        for h in range(3):
            for s in range(20):
                stalled = h == 2 and s > 10
                store.insert(MetricRecord(
                    1000.0 + s * 10.0 + h * 0.1, f"n{h}", "jobA", "perf",
                    {"gflops": 0.0 if stalled else 500.0, "mfu": 0.4,
                     "steps_per_s": 0.0 if stalled else 1.0, "step": s}))
                store.insert(MetricRecord(
                    1000.0 + s * 10.0 + h * 0.1 + 0.01, f"n{h}", "jobA",
                    "device", {"hbm_frac_used": 0.5, "local_devices": 4}))
        return store
    single = fill(MetricStore(seal_threshold=16))
    sh = fill(ShardedAggregator(num_shards=3, seal_threshold=16))
    assert job_metric_series(single, "jobA", "gflops") == \
        job_metric_series(sh, "jobA", "gflops")
    assert job_statistical_view(single, "jobA", "gflops") == \
        job_statistical_view(sh, "jobA", "gflops")
    assert_rows_equal(view_idle_accelerators(sh),
                      view_idle_accelerators(single), "idle_view")
    manifests = {"jobA": JobManifest(job_id="jobA", num_hosts=3)}
    key = lambda e: (e.detector, e.job, sorted(e.fields.items()))  # noqa: E731
    assert sorted(map(key, DetectorBank().scan(single, manifests))) == \
        sorted(map(key, DetectorBank().scan(sh, manifests)))


# ------------------------------------------------------------- durability --

def test_durable_sharded_store_reopens(tmp_path):
    sh = random_store(records=RECORDS, shards=3,
                      directory=tmp_path / "fleet", seal_threshold=37)
    want = query(sh, FLEET_Q)
    want_n = len(sh)
    sh.close()
    re = ShardedAggregator(num_shards=3, directory=tmp_path / "fleet",
                          seal_threshold=37)
    assert len(re) == want_n
    assert_rows_equal(query(re, FLEET_Q), want, FLEET_Q)
    # retransmits after restart still dedup (keys persisted per shard)
    for rec in RECORDS[:25]:
        assert not re.insert(rec)
    shard1_n = len(re.shards[1])
    re.close()
    # the shard-set manifest pins shape and policy
    with pytest.raises(ValueError):
        ShardedAggregator(num_shards=5, directory=tmp_path / "fleet")
    with pytest.raises(ValueError):
        ShardedAggregator(num_shards=3, policy="time",
                          directory=tmp_path / "fleet")
    # every shard dir is a complete standalone store
    alone = MetricStore(seal_threshold=37,
                        directory=tmp_path / "fleet" / "shard-01")
    assert len(alone) == shard1_n
    alone.close()


def test_time_window_pinned_by_manifest(tmp_path):
    # reopening a time-routed shard set with a different window would
    # re-route records and break the per-shard-dedup == global-dedup
    # invariant, so the manifest must reject it
    sh = ShardedAggregator(num_shards=2, policy="time", time_window_s=3600.0,
                           directory=tmp_path / "t")
    rec = MetricRecord(3600.0, "n0", "j", "perf", {"v": 1.0})
    assert sh.insert(rec)
    sh.close()
    with pytest.raises(ValueError):
        ShardedAggregator(num_shards=2, policy="time", time_window_s=60.0,
                          directory=tmp_path / "t")
    re = ShardedAggregator(num_shards=2, policy="time", time_window_s=3600.0,
                           directory=tmp_path / "t")
    assert not re.insert(rec)  # retransmit routes identically -> deduped
    assert len(re) == 1
    re.close()


def test_adopt_store_dir_time_policy_ships_whole_segments(tmp_path):
    src = random_store(records=RECORDS, directory=tmp_path / "src",
                       seal_threshold=40)
    src.close()
    # 40-record segments span 117s; a 200s window makes some segments
    # land inside one window (whole-file adoption) and some straddle a
    # boundary (row re-ingest) — both routes must coexist
    sh = ShardedAggregator(num_shards=3, policy="time", time_window_s=200.0,
                           directory=tmp_path / "dst")
    n = sh.adopt_store_dir(tmp_path / "src")
    assert n == len(RECORDS)
    assert sh.segments_adopted > 0
    assert sh.records_reingested > 0
    single = random_store(records=RECORDS)
    for q in SKEW_QUERIES[:5]:
        assert_sharded_rows(query(sh, q), query(single, q), q)
    # adopted dedup keys still reject retransmits
    assert not sh.insert(RECORDS[0])
    sh.close()


def test_adopt_store_dir_hash_policy_reroutes_rows(tmp_path):
    src = random_store(records=RECORDS, directory=tmp_path / "src",
                       seal_threshold=64)
    src.close()
    sh = ShardedAggregator(num_shards=4, policy="hash")
    n = sh.adopt_store_dir(tmp_path / "src")
    assert n == len(RECORDS)
    assert sh.records_reingested > 0  # multi-host segments must split
    single = random_store(records=RECORDS)
    q = "stats avg(gflops) count by host"
    assert_sharded_rows(query(sh, q), query(single, q), q)


def test_aggregator_with_shards_pumps_and_restarts(tmp_path):
    def rec(ts, host, v):
        return MetricRecord(ts, host, "j1", "perf", {"v": v})
    agg = Aggregator(tmp_path / "inbox", shards=2,
                     store_dir=tmp_path / "fleet")
    inbox = tmp_path / "inbox" / "a.log"
    lines = [encode_line(rec(1000.0 + i, f"n{i % 3}", float(i)))
             for i in range(9)]
    inbox.write_text("".join(ln + "\n" for ln in lines))
    assert agg.pump() == 9
    want = query(agg.store, "stats sum(v) count by host")
    agg.close()
    agg2 = Aggregator(tmp_path / "inbox", shards=2,
                      store_dir=tmp_path / "fleet")
    assert len(agg2.store) == 9
    assert agg2.pump() == 0  # re-tail deduplicated per shard
    assert agg2.store.duplicates_dropped == 9
    assert_rows_equal(query(agg2.store, "stats sum(v) count by host"),
                      want, "restart")
    agg2.close()
