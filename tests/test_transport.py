"""Transport-layer tests: spool rotation, at-least-once shipping, torn
lines, island relays, aggregator dedup."""

from pathlib import Path

from repro.core.aggregator import Aggregator, MetricStore
from repro.core.schema import MetricRecord, encode_line
from repro.core.transport import (IslandRelay, Shipper, Spool,
                                  StreamFileSink, TailReader)


def lines_for(n, host="n0"):
    return [encode_line(MetricRecord(1000.0 + i, host, "j", "perf",
                                     {"i": i})) for i in range(n)]


def test_spool_rotation(tmp_path):
    sp = Spool(tmp_path / "spool", max_segment_bytes=200)
    for ln in lines_for(20):
        sp.write_line(ln)
    sp.close()
    segs = sp.segments()
    assert len(segs) > 1
    total = sum(len(s.read_text().splitlines()) for s in segs)
    assert total == 20


def test_shipper_at_least_once_across_restarts(tmp_path):
    sp = Spool(tmp_path / "spool", max_segment_bytes=150)
    out = []
    for ln in lines_for(5):
        sp.write_line(ln)
    s1 = Shipper(tmp_path / "spool", out.append,
                 state_dir=tmp_path / "state")
    assert s1.ship_once() == 5
    for ln in lines_for(5, host="n1"):
        sp.write_line(ln)
    # new shipper instance (simulated restart) resumes from offsets
    s2 = Shipper(tmp_path / "spool", out.append,
                 state_dir=tmp_path / "state")
    assert s2.ship_once() == 5
    assert len(out) == 10
    assert s2.ship_once() == 0  # no duplicates when idle
    sp.close()


def test_shipper_ignores_torn_line(tmp_path):
    sp = Spool(tmp_path / "spool")
    sp.write_line("hpcmd ts=1 host=h job=j kind=perf a=1")
    # simulate a torn write: partial line without newline
    with open(sp._active_path(), "a") as f:
        f.write("hpcmd ts=2 host=h job=j kind=perf b=")
    out = []
    sh = Shipper(tmp_path / "spool", out.append)
    assert sh.ship_once() == 1
    # complete the line -> shipped on next pump
    with open(sp._active_path(), "a") as f:
        f.write("2\n")
    assert sh.ship_once() == 1
    assert out[1].endswith("b=2")
    sp.close()


def test_shipper_gc_rotated_segments(tmp_path):
    sp = Spool(tmp_path / "spool", max_segment_bytes=100)
    for ln in lines_for(30):
        sp.write_line(ln)
    sh = Shipper(tmp_path / "spool", lambda _line: None)
    sh.ship_once()
    remaining = sorted((tmp_path / "spool").glob("segment-*.log"))
    assert len(remaining) == 1  # only the active segment survives
    sp.close()


def test_island_relay_fan_in(tmp_path):
    spools = []
    for i in range(3):
        sp = Spool(tmp_path / f"node{i}")
        for ln in lines_for(4, host=f"node{i}"):
            sp.write_line(ln)
        spools.append(sp)
    relay = IslandRelay([tmp_path / f"node{i}" for i in range(3)],
                        tmp_path / "island")
    assert relay.pump() == 12
    collected = []
    uplink = relay.uplink(collected.append)
    assert uplink.ship_once() == 12
    hosts = {ln.split("host=")[1].split()[0] for ln in collected}
    assert hosts == {"node0", "node1", "node2"}
    for sp in spools:
        sp.close()


def test_aggregator_dedup_and_callbacks(tmp_path):
    agg = Aggregator(tmp_path / "inbox")
    seen = []
    agg.on_record(seen.append)
    sink = StreamFileSink(tmp_path / "inbox" / "a.log")
    for ln in lines_for(5):
        sink(ln)
    assert agg.pump() == 5
    # at-least-once duplicates are dropped
    for ln in lines_for(5):
        sink(ln)
    assert agg.pump() == 0
    assert agg.store.duplicates_dropped == 5
    assert len(seen) == 5


def test_aggregator_persist_and_replay(tmp_path):
    agg = Aggregator(tmp_path / "inbox", persist_path=tmp_path / "arch.log")
    sink = StreamFileSink(tmp_path / "inbox" / "a.log")
    for ln in lines_for(7):
        sink(ln)
    agg.pump()
    agg2 = Aggregator(tmp_path / "inbox2")
    assert agg2.load_archive(tmp_path / "arch.log") == 7
    assert len(agg2.store) == 7


def test_shipper_byte_offsets_with_multibyte_utf8(tmp_path):
    # offsets are bytes compared against stat().st_size; decoded-character
    # counting drifted on multi-byte UTF-8 and duplicated/truncated lines
    sp = Spool(tmp_path / "spool")
    l1 = 'hpcmd ts=1 host=h job=j kind=perf app="gemmä-β"'
    l2 = 'hpcmd ts=2 host=h job=j kind=perf app="中文模型"'
    l3 = "hpcmd ts=3 host=h job=j kind=perf v=3"
    out = []
    sp.write_line(l1)
    assert Shipper(tmp_path / "spool", out.append,
                   state_dir=tmp_path / "st").ship_once() == 1
    sp.write_line(l2)
    # restart between batches: byte offsets must resume exactly
    s2 = Shipper(tmp_path / "spool", out.append, state_dir=tmp_path / "st")
    assert s2.ship_once() == 1
    sp.write_line(l3)
    assert s2.ship_once() == 1
    assert out == [l1, l2, l3]
    sp.close()


def test_tail_reader_multibyte_utf8_offsets(tmp_path):
    p = tmp_path / "stream.log"
    tr = TailReader(p)
    with open(p, "w", encoding="utf-8") as f:
        f.write('hpcmd a="αβγ中文"\n')
    assert tr.read_new_lines() == ['hpcmd a="αβγ中文"']
    with open(p, "a", encoding="utf-8") as f:
        f.write("hpcmd b=1\n")
    # char-counted offsets would re-read into the middle of line 1
    assert tr.read_new_lines() == ["hpcmd b=1"]
    assert tr.read_new_lines() == []


def test_tail_reader_resets_on_truncation(tmp_path):
    # size < offset used to return [] forever, stalling the aggregator
    p = tmp_path / "stream.log"
    tr = TailReader(p)
    p.write_text("hpcmd a=1\nhpcmd b=2\n")
    assert len(tr.read_new_lines()) == 2
    p.write_text("hpcmd c=3\n")  # rotated/truncated underneath the reader
    assert tr.read_new_lines() == ["hpcmd c=3"]
    assert tr.truncations_seen == 1


def test_tail_reader_detects_rotation_by_inode(tmp_path):
    # a replacement file that already grew past the old offset would
    # pass the size check and silently skip its first lines
    p = tmp_path / "stream.log"
    tr = TailReader(p)
    p.write_text("hpcmd a=1\n")
    assert tr.read_new_lines() == ["hpcmd a=1"]
    fresh = tmp_path / "fresh.log"
    fresh.write_text("hpcmd b=2\nhpcmd c=3\nhpcmd d=4\n")  # > old size
    fresh.replace(p)  # rotation: new inode, larger than the offset
    assert tr.read_new_lines() == ["hpcmd b=2", "hpcmd c=3", "hpcmd d=4"]
    assert tr.truncations_seen == 1


def test_spool_reopen_rotates_at_configured_size(tmp_path):
    # fh.tell() reports 0 right after an append-mode reopen, so a
    # restarted daemon kept growing an already-oversized active segment
    sp = Spool(tmp_path / "spool", max_segment_bytes=1 << 20)
    for ln in lines_for(5):
        sp.write_line(ln)
    sp.close()
    sp2 = Spool(tmp_path / "spool", max_segment_bytes=50)
    sp2.write_line("hpcmd ts=9 host=h job=j kind=perf v=9")
    assert len(sp2.segments()) == 2  # rotated instead of overgrowing
    sp2.close()


def test_spool_reopen_terminates_torn_line(tmp_path):
    sp = Spool(tmp_path / "spool")
    sp.write_line("hpcmd ts=1 host=h job=j kind=perf v=1")
    sp.close()
    # crash mid-write: torn fragment, cut inside a multi-byte char
    torn = 'hpcmd ts=2 host=h job=j kind=perf tag="äb"'.encode("utf-8")[:-4]
    with open(tmp_path / "spool" / "segment-00000000.log", "ab") as f:
        f.write(torn)
    sp2 = Spool(tmp_path / "spool")
    sp2.write_line("hpcmd ts=3 host=h job=j kind=perf v=3")
    sp2.close()
    out = []
    Shipper(tmp_path / "spool", out.append).ship_once()
    assert len(out) == 3  # fragment isolated on its own line
    assert out[0].endswith("v=1")
    assert out[2].endswith("v=3") and "ts=2" not in out[2]  # no merge


def test_tail_reader_incremental(tmp_path):
    p = tmp_path / "stream.log"
    tr = TailReader(p)
    assert tr.read_new_lines() == []
    p.write_text("a\nb\n")
    assert tr.read_new_lines() == ["a", "b"]
    with open(p, "a") as f:
        f.write("c\npartial")
    assert tr.read_new_lines() == ["c"]
    with open(p, "a") as f:
        f.write("-done\n")
    assert tr.read_new_lines() == ["partial-done"]
