"""Segment compaction, tiered compressed storage, and retention.

Acceptance contract (ISSUE 6 / docs/storage.md): compacting a store —
merging small sealed segments into large compressed cold-tier ones —
changes *nothing* observable through the query surface: the shared
parity sweep returns byte-identical rows (numeric tolerance only where
float accumulation order legitimately differs) on compacted +
compressed stores vs the uncompacted rows-engine oracle, across
in-process single stores, sharded fleets, and remote worker fleets,
including after a crash anywhere inside the compaction swap window.
Retention rollups are consulted by the planner only when the plan is
exactly answerable from bucketed partials (or the caller opted into
``tolerance=``), and aggregate results survive raw-segment drops.
"""

import shutil

import pytest

from conftest import assert_rows_equal, random_records, random_store
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES
from test_sharded_parity import assert_sharded_rows

from repro.core.columnar import ColumnarMetricStore
from repro.core.compaction import Compactor, build_rollup, rollup_uid
from repro.core.schema import encode_line
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import (_select_rollups, _split_pipeline,
                                   compile_scatter_plan, query)

ALL_QUERIES = SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES
SEAL = 29  # small segments -> many compaction candidates
RECORDS = random_records(seed=11, n=420)

FLEET_Q = "search kind=perf | stats avg(gflops) count by job"


def oracle_rows(q):
    """Uncompacted rows-engine oracle over the shared workload."""
    return query(_ORACLE, q, engine="rows")


_ORACLE = random_store(records=RECORDS, seal_threshold=SEAL)


def compacted_single(directory=None, compress=True):
    st = random_store(records=RECORDS, seal_threshold=SEAL,
                      directory=directory)
    stats = st.compact(compress=compress)
    assert stats["segments_merged"] > 0
    return st


# ===========================================================================
# Parity: compacted + compressed stores vs the uncompacted oracle
# ===========================================================================

@pytest.fixture(scope="module")
def single_compacted():
    return compacted_single()


@pytest.mark.parametrize("q", ALL_QUERIES)
def test_compaction_parity_single(q, single_compacted):
    assert_rows_equal(query(single_compacted, q), oracle_rows(q), q)


@pytest.mark.parametrize("shards", [2, 3])
def test_compaction_parity_sharded(shards):
    agg = random_store(records=RECORDS, shards=shards, seal_threshold=SEAL)
    stats = agg.compact_all()
    assert stats["segments_merged"] > 0
    assert len(stats["shards"]) == shards
    assert stats["retired_uids"]
    for q in ALL_QUERIES:
        # quantile sketches merge approximately and are layout-
        # dependent; everything else must match the oracle exactly
        assert_sharded_rows(agg.query(q), oracle_rows(q), q,
                            records=RECORDS)


def test_compaction_parity_durable_and_after_reload(tmp_path):
    st = compacted_single(directory=tmp_path / "s")
    for q in ALL_QUERIES:
        assert_rows_equal(query(st, q), oracle_rows(q), q)
    n_segments = len(st._sealed)
    uids = {seg.uid for seg in st._sealed}
    st.close()
    back = ColumnarMetricStore(directory=tmp_path / "s",
                               seal_threshold=SEAL)
    assert len(back._sealed) == n_segments
    assert {seg.uid for seg in back._sealed} == uids
    for q in ALL_QUERIES:
        assert_rows_equal(query(back, q), oracle_rows(q), q)
    back.close()


def test_compact_reduces_segments_and_bytes(tmp_path):
    st = random_store(records=RECORDS, seal_threshold=SEAL,
                      directory=tmp_path / "s")
    before = st.storage_stats()
    assert "hot" in before["tiers"]
    stats = st.compact()
    after = st.storage_stats()
    assert after["segments"] < before["segments"]
    assert stats["segment_count"] == len(st._sealed)
    # the merged tier is compressed: stored bytes beat the raw layout
    cold = after["tiers"]["cold"]
    assert cold["segments"] >= 1
    assert cold["bytes"] < cold["raw_bytes"]
    assert stats["bytes_after"] < stats["bytes_before"]
    assert st.last_compaction is stats
    st.close()


def test_compact_is_idempotent_when_nothing_qualifies():
    st = compacted_single()
    again = st.compact()
    assert again["runs"] == 0
    assert again["segments_merged"] == 0


# ===========================================================================
# Crash windows inside the compaction swap (satellite)
# ===========================================================================

def test_orphan_merged_bin_is_invisible(tmp_path):
    """Crash after writing the merged ``.bin`` but before the manifest
    commit: the orphan payload has no ``.json``, so reload never sees
    it and the original small segments still answer everything."""
    st = random_store(records=RECORDS, seal_threshold=SEAL,
                      directory=tmp_path / "s")
    n_segments = len(st._sealed)
    st.close()
    seg_dir = tmp_path / "s" / "segments"
    (seg_dir / "seg-00000000-m99999999.bin").write_bytes(b"\x00" * 128)
    back = ColumnarMetricStore(directory=tmp_path / "s",
                               seal_threshold=SEAL)
    assert len(back._sealed) == n_segments
    for q in ALL_QUERIES[:6] + [FLEET_Q]:
        assert_rows_equal(query(back, q), oracle_rows(q), q)
    back.close()


def test_committed_manifest_with_undeleted_inputs_heals(tmp_path):
    """Crash after the merged manifest committed but before the retired
    input files were unlinked: reload must adopt the merged segment
    exactly once (the ``replaces`` skip), never double-count the
    retired inputs, and clean them from disk."""
    st = random_store(records=RECORDS, seal_threshold=SEAL,
                      directory=tmp_path / "s")
    seg_dir = tmp_path / "s" / "segments"
    saved = tmp_path / "saved"
    saved.mkdir()
    for f in seg_dir.iterdir():
        shutil.copy2(f, saved / f.name)
    st.compact()
    n_segments = len(st._sealed)
    total = len(st)
    st.close()
    # resurrect the retired inputs next to the committed merged files
    for f in saved.iterdir():
        target = seg_dir / f.name
        if not target.exists():
            shutil.copy2(f, target)
    back = ColumnarMetricStore(directory=tmp_path / "s",
                               seal_threshold=SEAL)
    assert len(back) == total
    assert len(back._sealed) == n_segments
    for q in ALL_QUERIES[:6] + [FLEET_Q]:
        assert_rows_equal(query(back, q), oracle_rows(q), q)
    # the loader garbage-collected the superseded files
    leftover = {p.stem for p in seg_dir.glob("*.json")}
    assert leftover == {s for s in back._sealed_stems if s}
    back.close()


def test_wal_buffer_rows_survive_compaction_crash(tmp_path):
    """Unsealed rows ride the WAL across a compaction + crash: the
    merged cold segments and the replayed buffer interleave back into
    the exact pre-crash row set."""
    head, tail = RECORDS[:400], RECORDS[400:]  # tail stays unsealed
    st = random_store(records=head, seal_threshold=SEAL,
                      directory=tmp_path / "s")
    for rec in tail:
        st.insert(rec)
    assert st._buffer
    st.compact()
    assert st._buffer  # compaction never touches the buffer
    st.close()
    back = ColumnarMetricStore(directory=tmp_path / "s",
                               seal_threshold=SEAL)
    assert len(back) == len(RECORDS)
    for q in ALL_QUERIES[:6] + [FLEET_Q]:
        assert_rows_equal(query(back, q), oracle_rows(q), q)
    back.close()


def test_read_only_store_refuses_compaction(tmp_path):
    st = random_store(records=RECORDS[:100], seal_threshold=SEAL,
                      directory=tmp_path / "s")
    st.close()
    ro = ColumnarMetricStore(directory=tmp_path / "s", read_only=True)
    with pytest.raises(RuntimeError, match="read-only"):
        ro.compact()
    with pytest.raises(RuntimeError, match="read-only"):
        ro.apply_retention()
    with pytest.raises(RuntimeError, match="read-only"):
        Compactor(ro)
    ro.close()


# ===========================================================================
# Cache invalidation: retired uids dropped, merged uid warms on touch
# ===========================================================================

def test_partial_cache_retired_and_rewarmed():
    st = random_store(records=RECORDS, seal_threshold=SEAL)
    query(st, FLEET_Q, engine="incremental")  # warm per-segment entries
    plan = compile_scatter_plan(_split_pipeline(FLEET_Q))
    old_uids = [seg.uid for seg in st._sealed]
    assert all(st.partial_cache.peek((u, plan.fingerprint))
               for u in old_uids)
    stats = st.compact()
    for uid in stats["retired_uids"]:
        assert not st.partial_cache.peek((uid, plan.fingerprint))
    e0 = st.explain(FLEET_Q)
    assert e0["segments"]["cached"] == 0  # merged uids are cold
    assert_rows_equal(query(st, FLEET_Q, engine="incremental"),
                      oracle_rows(FLEET_Q), FLEET_Q)
    e1 = st.explain(FLEET_Q)  # ... and warm after first touch
    assert e1["segments"]["cached"] == e1["segments"]["sealed"] > 0


# ===========================================================================
# Retention rollups: planner eligibility, tolerance gating, drops
# ===========================================================================

def rolled_store():
    st = random_store(records=RECORDS, seal_threshold=SEAL)
    st.seal()  # bufferless: every row lives in a covered segment
    stats = st.apply_retention(rollups=[(60.0, 0.0), (600.0, 0.0)])
    assert stats["rollups_created"] == 2
    return st


def rollup_count(store, q, tolerance=None):
    plan = compile_scatter_plan(_split_pipeline(q), tolerance=tolerance)
    assert plan is not None, q
    chosen, _skip, _shape = _select_rollups(store, plan)
    return len(chosen)


def test_rollup_chosen_only_when_exactly_aligned():
    st = rolled_store()
    aligned = "kind=perf ts>=1020 ts<2040 | stats avg(gflops) count by host"
    assert rollup_count(st, aligned) > 0
    assert_rows_equal(query(st, aligned), oracle_rows(aligned), aligned)
    # unaligned bound, p90 agg, non-dim group key: all planner-refused
    for q in ("kind=perf ts>=1010 ts<2040 | stats avg(gflops) by host",
              "ts>=1020 ts<2040 | stats p90(gflops) by host",
              "ts>=1020 ts<2040 | stats avg(gflops) by app"):
        assert rollup_count(st, q) == 0
        assert_rows_equal(query(st, q), oracle_rows(q), q)


def test_rollup_tolerance_snaps_bounds():
    st = rolled_store()
    q = "kind=perf ts>=1010 ts<2050 | stats avg(gflops) count by host"
    assert rollup_count(st, q) == 0          # exact mode: refused
    assert rollup_count(st, q, tolerance=60.0) > 0
    snapped = "kind=perf ts>=1020 ts<2040 | stats avg(gflops) count by host"
    assert_rows_equal(query(st, q, tolerance=60.0), oracle_rows(snapped), q)
    # a tolerance too small to reach the nearest bucket edge: refused
    assert rollup_count(st, q, tolerance=5.0) == 0
    assert_rows_equal(query(st, q, tolerance=5.0), oracle_rows(q), q)


def test_rollup_full_range_aggregate_matches_exactly():
    st = rolled_store()
    for q in ("ts>=0 | stats count by host",
              "ts>=0 | stats sum(gflops) min(gflops) max(gflops) by kind",
              "ts>=0 | stats stdev(gflops) by job",
              "kind=perf ts>=0 | timechart span=600 avg(gflops) by host"):
        assert rollup_count(st, q) > 0, q
        assert_rows_equal(query(st, q), oracle_rows(q), q)


def test_rollup_survives_raw_segment_drop():
    st = random_store(records=RECORDS, seal_threshold=SEAL)
    st.seal()
    q = "ts>=0 | stats count sum(gflops) by host"
    before = query(st, q)
    stats = st.apply_retention(rollups=[(60.0, 0.0)], raw_max_age_s=0.0)
    assert stats["dropped_segments"] > 0
    assert len(st._sealed) == 0
    assert_rows_equal(query(st, q), before, q)  # aggregates intact
    # row-level reads honestly reflect the drop (data is gone)
    assert len(st) < len(RECORDS) or len(st) == 0


def test_rollup_durable_reload(tmp_path):
    st = random_store(records=RECORDS, seal_threshold=SEAL,
                      directory=tmp_path / "s")
    st.seal()
    st.apply_retention(rollups=[(60.0, 0.0)])
    n_rollups = len(st._rollups)
    ruids = {seg.uid for seg in st._rollups}
    q = "ts>=0 | stats count avg(gflops) by host"
    want = query(st, q)
    st.close()
    back = ColumnarMetricStore(directory=tmp_path / "s",
                               seal_threshold=SEAL)
    assert len(back._rollups) == n_rollups
    assert {seg.uid for seg in back._rollups} == ruids
    assert rollup_count(back, q) > 0
    assert_rows_equal(query(back, q), want, q)
    back.close()


def test_compaction_pins_rollup_covered_segments():
    """A raw segment referenced by a rollup's ``covers`` keeps its uid:
    merging it would orphan the cover and break the planner's
    disjointness proof."""
    st = random_store(records=RECORDS, seal_threshold=SEAL)
    st.seal()
    st.apply_retention(rollups=[(60.0, 0.0)])
    covered = set()
    for rseg in st._rollups:
        covered.update(rseg.rollup["covers"])
    stats = st.compact()
    assert stats["segments_merged"] == 0  # everything is pinned
    assert {seg.uid for seg in st._sealed} >= covered


def test_rollup_uid_is_content_derived():
    segs = [s for s, _u in
            random_store(records=RECORDS,
                         seal_threshold=SEAL).segment_units(
                             include_buffer=False)][:3]
    a = build_rollup(segs, 60.0)
    b = build_rollup(segs, 60.0)
    assert a.uid == b.uid == rollup_uid(60.0, [s.uid for s in segs])
    assert build_rollup(segs, 600.0).uid != a.uid
    assert build_rollup(segs[:2], 60.0).uid != a.uid


# ===========================================================================
# explain(): storage block (satellite)
# ===========================================================================

def test_explain_storage_block_single(tmp_path):
    st = compacted_single(directory=tmp_path / "s")
    e = st.explain(FLEET_Q)
    storage = e["storage"]
    assert storage["segments"] == len(st._sealed)
    assert storage["tiers"]["cold"]["bytes"] < \
        storage["tiers"]["cold"]["raw_bytes"]
    assert storage["last_compaction"]["segments_merged"] > 0
    assert e["segments"]["rollup_segments"] == 0
    st.seal()
    st.apply_retention(rollups=[(60.0, 0.0)])
    e2 = st.explain("ts>=0 | stats count by host")
    assert e2["segments"]["rollup_segments"] > 0
    assert any(t.startswith("rollup-") for t in e2["storage"]["tiers"])
    st.close()


def test_explain_storage_block_sharded(tmp_path):
    agg = random_store(records=RECORDS, shards=2, seal_threshold=SEAL,
                       directory=tmp_path / "f")
    agg.compact_all()
    e = agg.explain(FLEET_Q)
    assert e["storage"]["segments"] == sum(len(s._sealed)
                                           for s in agg.shards)
    assert "cold" in e["storage"]["tiers"]
    assert len(e["storage"]["last_compaction"]) == 2
    e_full = agg.explain("search kind=perf | sort -gflops | head 3")
    assert "storage" in e_full  # exact-gather shape carries it too


# ===========================================================================
# Remote fleet: compaction RPCs, memo eviction, storage block, parity
# ===========================================================================

def test_remote_compaction_full_surface(tmp_path):
    """One worker fleet exercises the whole remote maintenance surface:
    ``compact``/``retention``/``storage`` ops, coordinator scatter-memo
    eviction on retirement (the drop_segment satellite), the explain
    storage block, tolerance over the wire, and the parity sweep over
    the compacted + compressed + rolled-up fleet."""
    from repro.core.remote import RemoteShardedAggregator
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=300.0)
    try:
        for rec in RECORDS:
            agg.insert(rec)
        agg.query(FLEET_Q)  # warm coordinator-side decoded maps
        assert any(sh._scatter_memo for sh in agg.shards)
        before = agg.storage_stats()
        stats = agg.compact_all()
        assert stats["segments_merged"] > 0 and stats["retired_uids"]
        # satellite: retired uids evict the coordinator's decoded maps
        assert all(not sh._scatter_memo for sh in agg.shards)
        after = agg.storage_stats()
        assert after["segments"] < before["segments"]
        assert "cold" in after["tiers"]
        for q in ALL_QUERIES:
            assert_sharded_rows(agg.query(q), oracle_rows(q), q,
                                records=RECORDS)
        # retention + tolerance ride the same wire protocol (buffers
        # sealed first: only covered segments may answer with snapped
        # bounds, so the comparison against the snapped oracle is exact)
        for sh in agg.shards:
            sh.seal()
        rstats = agg.apply_retention(rollups=[(60.0, 0.0)])
        assert rstats["rollups_created"] > 0
        tq = "kind=perf ts>=1010 ts<2050 | stats avg(gflops) count by host"
        rows_t = agg.query(tq, tolerance=60.0)
        tstats = dict(agg.last_query_stats)
        assert tstats["rollup_segments"] > 0
        snapped = ("kind=perf ts>=1020 ts<2040 | "
                   "stats avg(gflops) count by host")
        assert_rows_equal(rows_t, oracle_rows(snapped), tq)
        e = agg.explain(FLEET_Q)
        assert e["storage"]["segments"] == agg.storage_stats()["segments"]
        assert any(t.startswith("rollup-") for t in e["storage"]["tiers"])
        assert all(lc is not None for lc in e["storage"]["last_compaction"])
    finally:
        agg.close()


# ===========================================================================
# Aggregator: background maintenance trigger (policy config)
# ===========================================================================

def test_aggregator_background_compaction(tmp_path):
    from repro.core.aggregator import Aggregator
    inbox = tmp_path / "inbox"
    inbox.mkdir()
    agg = Aggregator(inbox, store_dir=tmp_path / "store",
                     compaction_policy={"every_seals": 4, "min_run": 2})
    agg.store.seal_threshold = SEAL
    with open(inbox / "s.log", "w", encoding="utf-8") as f:
        for rec in RECORDS:
            f.write(encode_line(rec) + "\n")
    assert agg.pump() == len(RECORDS)
    assert agg.last_maintenance is not None
    assert agg.last_maintenance["compact"]["segments_merged"] > 0
    for q in ALL_QUERIES[:6] + [FLEET_Q]:
        assert_rows_equal(query(agg.store, q), oracle_rows(q), q)
    # below-threshold growth does not re-trigger
    before = agg.last_maintenance
    agg.maybe_compact()
    assert agg.last_maintenance is before
    assert agg.maybe_compact(force=True) is not None
    agg.close()
