"""Wire-format tests: the log line is the system's contract."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.schema import MetricRecord, encode_line, parse_line

KEY = st.from_regex(r"[a-z_][a-z0-9_]{0,15}", fullmatch=True).filter(
    lambda k: k not in ("ts", "host", "job", "kind"))
SCALAR = st.one_of(
    st.integers(min_value=-2**53, max_value=2**53),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(min_size=0, max_size=40),
)


def test_basic_roundtrip():
    rec = MetricRecord(1000.25, "node01", "job.1", "perf",
                       {"gflops": 12.5, "app": "gemma2 27b", "step": 3})
    out = parse_line(encode_line(rec))
    assert out is not None
    assert out.host == "node01" and out.job == "job.1"
    assert out.fields == rec.fields


def test_quoting_edge_cases():
    rec = MetricRecord(1.0, "h", "j", "meta", {
        "cmd": 'python -m x --flag="v"',
        "path": "/a/b/c.py",
        "empty": "",
        "backslash": "a\\b",
    })
    out = parse_line(encode_line(rec))
    assert out.fields == rec.fields


def test_non_hpcmd_lines_ignored():
    assert parse_line("") is None
    assert parse_line("random syslog garbage") is None
    assert parse_line("hpcmd ") is None
    assert parse_line("hpcmd ts=x host=h job=j kind=k") is None  # bad ts


def test_torn_line_does_not_crash():
    rec = MetricRecord(5.0, "h", "j", "perf", {"gflops": 1.0})
    line = encode_line(rec)
    for cut in (5, 15, len(line) - 3):
        parse_line(line[:cut])  # must not raise


@given(ts=st.floats(min_value=0, max_value=4e9),
       host=st.from_regex(r"[a-z0-9.\-]{1,20}", fullmatch=True),
       job=st.from_regex(r"[a-zA-Z0-9._\-]{1,20}", fullmatch=True),
       fields=st.dictionaries(KEY, SCALAR, max_size=8))
@settings(max_examples=200, deadline=None)
def test_roundtrip_property(ts, host, job, fields):
    rec = MetricRecord(ts, host, job, "perf", fields)
    out = parse_line(encode_line(rec))
    assert out is not None
    assert out.host == host and out.job == job and out.kind == "perf"
    assert abs(out.ts - round(ts, 6)) < 1e-6
    assert set(out.fields) == set(fields)
    for k, v in fields.items():
        got = out.fields[k]
        if isinstance(v, float):
            assert got == pytest.approx(v, rel=1e-12, abs=1e-12)
        elif isinstance(v, int):
            # ints stay ints unless they collide with float repr
            assert float(got) == float(v)
        else:
            # numeric-looking strings legitimately come back as numbers
            # (kv wire formats are type-ambiguous for bare tokens)
            try:
                as_num = float(v)
                if math.isnan(as_num):
                    assert isinstance(got, float) and math.isnan(got)
                else:
                    assert float(got) == pytest.approx(as_num)
            except (ValueError, OverflowError):
                assert str(got) == v


def test_record_get_reserved():
    rec = MetricRecord(1.0, "h", "j", "perf", {"x": 1})
    assert rec.get("host") == "h"
    assert rec.get("x") == 1
    assert rec.get("missing", 42) == 42
