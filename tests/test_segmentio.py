"""Durable columnar segments: on-disk round-trips, WAL recovery, crash
semantics, dedup persistence, and crash/restart property tests over the
whole Spool -> Shipper -> Aggregator pipeline."""

import random
import shutil
import tempfile
from pathlib import Path

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import segmentio
from repro.core.aggregator import Aggregator, MetricStore
from repro.core.schema import MetricRecord, encode_line, parse_line
from repro.core.splunklite import query
from repro.core.transport import Shipper, Spool, StreamFileSink

from conftest import assert_rows_equal, random_store
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES


def rec(ts, host="n0", job="j1", kind="perf", **fields):
    return MetricRecord(ts, host, job, kind, fields)


def wire(store):
    """Canonical per-record lines — NaN-safe, order-sensitive equality."""
    return [encode_line(r) for r in store.records]


def mixed_store(directory, seal_threshold=6, n=20):
    """Every column kind: floats, ints, NaN, dict strings with multi-byte
    UTF-8, mixed-type obj columns, a field shadowing a reserved attr."""
    store = MetricStore(seal_threshold=seal_threshold, directory=directory)
    apps = ["gemmä-β", "中文模型", "plain", "a b\"c\\d"]
    for i in range(n):
        fields = {"v": float(i) / 3.0, "step": i}
        if i == 4:
            fields["v"] = float("nan")
        if i % 3 == 0:
            fields["app"] = apps[i % len(apps)]
        if i % 5 == 0:
            fields["mix"] = "str" if i % 2 else i * 1.5  # obj column
        if i % 7 == 0:
            fields["host"] = f"shadow{i}"  # field shadows the attr
        store.insert(MetricRecord(1000.0 + i, f"nöde{i % 3}", "j1", "perf",
                                  fields))
    return store


# ----------------------------------------------------------- round trips ---

def test_reload_round_trips_records_exactly(tmp_path):
    store = mixed_store(tmp_path / "store")
    want = wire(store)
    store.close()
    re = MetricStore(seal_threshold=6, directory=tmp_path / "store")
    assert wire(re) == want
    assert len(re) == len(want)
    # sealed segments came back memory-mapped, not re-parsed
    assert all(isinstance(s, segmentio.MappedSegment) for s in re._sealed)
    assert re._sealed and re.segment_load_errors == 0
    re.close()


def test_only_wal_is_replayed_on_restart(tmp_path):
    store = MetricStore(seal_threshold=10, directory=tmp_path / "store")
    for i in range(25):
        store.insert(rec(1000.0 + i, v=float(i)))
    wal = (tmp_path / "store" / "wal.log").read_text(encoding="utf-8")
    assert len(wal.splitlines()) == 5  # buffer only, not the 20 sealed
    store.close()
    re = MetricStore(seal_threshold=10, directory=tmp_path / "store")
    assert len(re) == 25
    assert [s.n for s in re._sealed] == [10, 10]
    assert len(re._buffer) == 5
    re.close()


def test_reloaded_store_keeps_sealing_and_persisting(tmp_path):
    store = MetricStore(seal_threshold=4, directory=tmp_path / "store")
    for i in range(6):
        store.insert(rec(1000.0 + i, v=float(i)))
    store.close()
    re = MetricStore(seal_threshold=4, directory=tmp_path / "store")
    for i in range(6, 12):
        re.insert(rec(1000.0 + i, v=float(i)))
    want = wire(re)
    re.close()
    re2 = MetricStore(seal_threshold=4, directory=tmp_path / "store")
    assert wire(re2) == want
    # sequence numbers continued instead of clobbering old segments
    manifests = sorted((tmp_path / "store" / "segments").glob("seg-*.json"))
    assert [m.stem for m in manifests] == [
        "seg-00000000", "seg-00000001", "seg-00000002"]
    re2.close()


def test_dedup_keys_persist_across_restart(tmp_path):
    store = MetricStore(seal_threshold=6, directory=tmp_path / "store")
    for i in range(20):
        store.insert(rec(1000.0 + i, v=float(i) / 3.0, step=i,
                         app="中文" if i % 2 else "gemmä"))
    lines = wire(store)
    store.close()
    re = MetricStore(seal_threshold=6, directory=tmp_path / "store")
    for ln in lines:  # full at-least-once re-delivery
        re.insert(parse_line(ln))
    assert len(re) == 20
    assert re.duplicates_dropped == 20
    re.close()


def test_shadowed_reserved_field_survives_only_via_sealed_segment(tmp_path):
    # a field named like a reserved attr is not representable on the
    # wire (parse_line keeps the last host= token as the attr), so the
    # legacy line archive corrupted such records on replay.  Columnar
    # segment files are schema-full: once sealed, both values survive.
    store = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    store.insert(MetricRecord(1.0, "aggregator", "j1", "event",
                              {"host": "n7", "detector": "hang"}))
    store.seal()
    store.close()
    re = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    r = re.records[0]
    assert r.host == "aggregator" and r.fields["host"] == "n7"
    re.close()


def test_scan_and_zone_identical_over_mmap(tmp_path):
    store = mixed_store(tmp_path / "store", n=30)
    store.close()
    re = MetricStore(seal_threshold=6, directory=tmp_path / "store")
    a = store.scan(kind="perf", fields=("v", "step"))
    b = re.scan(kind="perf", fields=("v", "step"))
    assert a.n == b.n
    np.testing.assert_array_equal(a.ts, b.ts)
    for f in ("v", "step"):
        va, pa = a.field(f)
        vb, pb = b.field(f)
        np.testing.assert_array_equal(pa, pb)
        np.testing.assert_array_equal(va[pa], vb[pb])
    assert [s.zone("v") for s in store._sealed] == \
        [s.zone("v") for s in re._sealed]
    assert store.jobs() == re.jobs()
    assert store.hosts() == re.hosts()
    re.close()


# ------------------------------------------------------- crash semantics ---

def test_wal_torn_tail_is_dropped_and_truncated(tmp_path):
    store = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    for i in range(5):
        store.insert(rec(1000.0 + i, v=float(i), app="中文"))
    want = wire(store)
    store.close()
    # crash mid-write: torn final line, cut inside a multi-byte char
    torn = encode_line(rec(2000.0, v=9.0, app="中文")).encode("utf-8")[:-4]
    with open(tmp_path / "store" / "wal.log", "ab") as f:
        f.write(torn)
    re = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    assert wire(re) == want  # torn record never half-ingested
    # ...and the torn bytes are gone from disk: new inserts cannot merge
    re.insert(rec(3000.0, v=10.0))
    re.close()
    re2 = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    assert wire(re2) == want + [encode_line(rec(3000.0, v=10.0))]
    re2.close()


def test_crash_before_manifest_commit_recovers_from_wal(tmp_path):
    store = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    for i in range(8):
        store.insert(rec(1000.0 + i, v=float(i)))
    want = wire(store)
    store.close()
    # interrupted seal: orphan .bin (any content), no .json manifest
    seg_dir = tmp_path / "store" / "segments"
    (seg_dir / "seg-00000000.bin").write_bytes(b"\0" * 128)
    re = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    assert wire(re) == want
    assert len(re._sealed) == 0  # orphan ignored, rows from WAL
    re.close()


def test_crash_before_wal_reset_does_not_duplicate(tmp_path):
    store = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    for i in range(8):
        store.insert(rec(1000.0 + i, v=float(i)))
    pre_seal_wal = (tmp_path / "store" / "wal.log").read_bytes()
    store.seal()  # segment committed, WAL reset...
    want = wire(store)
    store.close()
    # ...but pretend the crash hit between commit and reset
    (tmp_path / "store" / "wal.log").write_bytes(pre_seal_wal)
    re = MetricStore(seal_threshold=100, directory=tmp_path / "store")
    assert wire(re) == want
    assert len(re) == 8 and re.duplicates_dropped == 8
    re.close()


def test_crash_before_wal_reset_with_horizon_late_data(tmp_path):
    # the newest seal can hold data already past the dedup horizon
    # (late arrivals); its keys are normally evicted on load, but must
    # stay visible *during* WAL replay or the crash window between
    # segment commit and WAL reset duplicates every row
    kw = dict(seal_threshold=100, dedup_horizon_s=50.0,
              directory=tmp_path / "store")
    store = MetricStore(**kw)
    store.insert(rec(10000.0, v=99.0))  # watermark far ahead
    store.seal()
    for i in range(5):  # late-arriving rows
        store.insert(rec(1000.0 + i, v=float(i)))
    pre_seal_wal = (tmp_path / "store" / "wal.log").read_bytes()
    store.seal()
    want = wire(store)
    store.close()
    (tmp_path / "store" / "wal.log").write_bytes(pre_seal_wal)
    re = MetricStore(**kw)
    assert wire(re) == want and len(re) == 6
    # ...and after startup the late keys are evicted again, matching
    # the never-crashed store's horizon semantics
    assert re.insert(rec(1000.0, v=0.0))
    re.close()


def test_post_eviction_reaccepted_row_survives_restart(tmp_path):
    kw = dict(seal_threshold=2, dedup_horizon_s=10.0,
              directory=tmp_path / "store")
    store = MetricStore(**kw)
    store.insert(rec(1000.0, v=0.0))
    store.insert(rec(1001.0, v=1.0))  # seals seg0
    store.insert(rec(5000.0, v=9.0))
    store.insert(rec(5001.0, v=9.5))  # seals seg1, evicts seg0's keys
    store.insert(rec(1000.0, v=0.0))  # legitimately re-accepted copy
    assert len(store) == 5
    want = wire(store)
    store.close()
    # seg0 is past the horizon but is NOT the newest seal: its keys
    # must stay evicted through replay or the re-accepted row vanishes
    re = MetricStore(**kw)
    assert wire(re) == want and len(re) == 5
    re.close()


def test_corrupt_manifest_is_skipped_and_counted(tmp_path):
    store = mixed_store(tmp_path / "store")
    store.close()
    manifests = sorted((tmp_path / "store" / "segments").glob("seg-*.json"))
    manifests[0].write_text("{not json", encoding="utf-8")
    re = MetricStore(seal_threshold=6, directory=tmp_path / "store")
    assert re.segment_load_errors == 1
    assert len(re._sealed) == len(manifests) - 1
    re.close()


# ------------------------------------------------------------ engine use ---

def test_reloaded_store_answers_all_parity_queries(tmp_path):
    store = random_store(directory=tmp_path / "store")
    store.close()
    re = MetricStore(seal_threshold=97, directory=tmp_path / "store")
    for q in SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES:
        want = query(store, q)
        assert_rows_equal(query(re, q), want, q)  # columnar over mmap
        assert_rows_equal(query(re, q, engine="rows"), want, q)
    re.close()


def test_dashboards_and_detectors_identical_over_mmap(tmp_path):
    from repro.core.daemon import JobManifest
    from repro.core.dashboards import (job_metric_series,
                                       job_statistical_view)
    from repro.core.detectors import DetectorBank
    store = MetricStore(seal_threshold=16, directory=tmp_path / "store")
    for h in range(3):
        for s in range(20):
            stalled = h == 2 and s > 10
            store.insert(MetricRecord(
                1000.0 + s * 10.0, f"n{h}", "jobA", "perf",
                {"gflops": 0.0 if stalled else 500.0, "mfu": 0.4,
                 "steps_per_s": 0.0 if stalled else 1.0, "step": s}))
            store.insert(MetricRecord(
                1000.0 + s * 10.0, f"n{h}", "jobA", "device",
                {"hbm_frac_used": 0.5, "local_devices": 4}))
    store.close()
    re = MetricStore(seal_threshold=16, directory=tmp_path / "store")
    assert job_metric_series(store, "jobA", "gflops") == \
        job_metric_series(re, "jobA", "gflops")
    assert job_statistical_view(store, "jobA", "gflops") == \
        job_statistical_view(re, "jobA", "gflops")
    manifests = {"jobA": JobManifest(job_id="jobA", num_hosts=3)}
    key = lambda e: (e.detector, e.job, sorted(e.fields.items()))  # noqa: E731
    assert sorted(map(key, DetectorBank().scan(store, manifests))) == \
        sorted(map(key, DetectorBank().scan(re, manifests)))
    re.close()


def test_aggregator_restart_over_store_dir(tmp_path):
    agg = Aggregator(tmp_path / "inbox", store_dir=tmp_path / "store")
    sink = StreamFileSink(tmp_path / "inbox" / "a.log")
    lines = [encode_line(rec(1000.0 + i, v=float(i))) for i in range(7)]
    for ln in lines:
        sink(ln)
    assert agg.pump() == 7
    want = wire(agg.store)
    agg.close()
    # restart: store restored from disk; inbox re-tail is deduplicated
    agg2 = Aggregator(tmp_path / "inbox", store_dir=tmp_path / "store")
    assert len(agg2.store) == 7
    assert agg2.pump() == 0
    assert agg2.store.duplicates_dropped == 7
    assert wire(agg2.store) == want
    agg2.close()


# ------------------------------------------------- crash/restart property --

def _pipeline_records(rng, n):
    apps = ["gemmä-β", "中文模型", "plain", "ωλ space y"]
    out = []
    for i in range(n):
        fields = {"v": round(rng.uniform(0, 100), 3), "step": i}
        if rng.random() < 0.5:
            fields["app"] = rng.choice(apps)
        out.append(rec(1000.0 + i, host=f"nö{i % 2}", **fields))
    return out


def _run_pipeline(records, seed, crashy):
    """Drive spool -> shipper -> aggregator; when ``crashy``, kill and
    recreate every component at pseudo-random points."""
    rng = random.Random(seed)
    base = Path(tempfile.mkdtemp())
    try:
        spool_dir = base / "spool"
        mk_spool = lambda: Spool(spool_dir, max_segment_bytes=  # noqa: E731
                                 rng.choice([200, 400, 1 << 20]))
        mk_shipper = lambda: Shipper(  # noqa: E731
            spool_dir, StreamFileSink(base / "inbox" / "n0.log"),
            state_dir=base / "shipstate")
        mk_agg = lambda: Aggregator(  # noqa: E731
            base / "inbox",
            store=MetricStore(seal_threshold=7, directory=base / "store"))
        spool, shipper, agg = mk_spool(), mk_shipper(), mk_agg()
        for r in records:
            spool.write_line(encode_line(r))
            if crashy and rng.random() < 0.25:
                spool.close()
                spool = mk_spool()
            if rng.random() < 0.4:
                shipper.ship_once()
            if crashy and rng.random() < 0.2:
                shipper = mk_shipper()  # offsets reloaded from disk
            if rng.random() < 0.4:
                agg.pump()
            if crashy and rng.random() < 0.2:
                agg.close()
                agg = mk_agg()  # store reloaded: mmap + WAL replay
        shipper.ship_once()
        agg.pump()
        out = wire(agg.store)
        agg.close()
        # final cold restart must read back the identical store
        agg2 = mk_agg()
        assert wire(agg2.store) == out
        agg2.close()
        spool.close()
        return out
    finally:
        shutil.rmtree(base, ignore_errors=True)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_crash_restart_pipeline_matches_clean_run(seed):
    rng = random.Random(seed ^ 0x5EED)
    records = _pipeline_records(rng, rng.randint(20, 60))
    clean = _run_pipeline(records, seed, crashy=False)
    crashed = _run_pipeline(records, seed, crashy=True)
    assert clean == [encode_line(r) for r in records]
    assert crashed == clean
