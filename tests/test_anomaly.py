"""Streaming anomaly detection (§4.6 outlook): EWMA z-score + CUSUM."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.anomaly import AnomalyBank, CusumDetector, EwmaDetector
from repro.core.schema import MetricRecord


def test_ewma_flags_step_change():
    det = EwmaDetector(z_thresh=4.0, warmup=5)
    rng = np.random.default_rng(0)
    for x in 100 + rng.standard_normal(50):
        assert det.update(float(x)) is None
    z = det.update(30.0)  # sudden collapse
    assert z is not None and z < -4


def test_ewma_adapts_to_new_level():
    """After a (flagged) level shift, the baseline re-converges and stops
    alarming — no alarm storms."""
    det = EwmaDetector(z_thresh=4.0, warmup=5)
    rng = np.random.default_rng(1)
    for x in 100 + rng.standard_normal(40):
        det.update(float(x))
    alarms = sum(det.update(float(x)) is not None
                 for x in 50 + rng.standard_normal(60))
    assert 1 <= alarms <= 12  # flags the shift, then re-baselines


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ewma_quiet_on_stationary_noise(seed):
    rng = np.random.default_rng(seed)
    det = EwmaDetector(z_thresh=6.0, warmup=10)
    alarms = sum(det.update(float(x)) is not None
                 for x in rng.standard_normal(300))
    assert alarms <= 3  # ~0 false positives at 6 sigma


def test_cusum_catches_slow_drift():
    """A drift of 0.15 sigma/step never trips a 4-sigma point alarm but
    must trip CUSUM."""
    rng = np.random.default_rng(2)
    ew = EwmaDetector(z_thresh=4.0, warmup=5)
    cs = CusumDetector(k=0.25, h=6.0, alpha=0.02)
    point_alarms, drift_alarms = 0, 0
    for i in range(300):
        x = float(rng.standard_normal() + (i * 0.05 if i > 100 else 0.0))
        if ew.update(x) is not None:
            point_alarms += 1
        if cs.update(x) is not None:
            drift_alarms += 1
    assert drift_alarms >= 1


def test_anomaly_bank_end_to_end():
    bank = AnomalyBank(metrics=("gflops",))
    rng = np.random.default_rng(3)
    events = []
    for i in range(60):
        g = 800 + rng.standard_normal() * 5 if i < 50 else 100.0
        events += bank.feed(MetricRecord(
            1000.0 + i, "n0", "j1", "perf", {"gflops": float(g)}))
    assert any(e.detector == "ewma_anomaly" for e in events)
    ev = next(e for e in events if e.detector == "ewma_anomaly")
    assert ev.job == "j1" and ev.fields["metric"] == "gflops"
    # streams are independent per host
    bank.feed(MetricRecord(2000.0, "n1", "j1", "perf", {"gflops": 5.0}))
    assert ("j1", "n1", "gflops") in bank._ewma
