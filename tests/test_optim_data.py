"""Optimizer, schedule, compression, and data-pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import MemmapSource, Pipeline, SyntheticSource
from repro.configs import get_arch, reduced
from repro.optim import AdamW, OptimizerConfig, lr_at
from repro.optim import compression


# ------------------------------------------------------------- optimizer ---

def test_adamw_minimizes_quadratic():
    opt = AdamW(OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=100.0))
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss(params)) < 1e-2


def test_grad_clipping():
    opt = AdamW(OptimizerConfig(clip_norm=1.0, warmup_steps=0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(4, 100.0)}, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 5)) == pytest.approx(0.5)
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1)
    # monotone decay after warmup
    vals = [float(lr_at(cfg, s)) for s in range(10, 101, 10)]
    assert vals == sorted(vals, reverse=True)


# ------------------------------------------------------------ compression --

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_quantize_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 10)
    q, scale, err = compression.quantize(x, jnp.zeros_like(x))
    deq = compression.dequantize(q, scale)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With EF, the *accumulated* quantized signal tracks the true
    accumulated signal much better than independent rounding."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    err = jnp.zeros_like(g)
    acc_q, acc_true = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        q, s, err = compression.quantize(g, err)
        acc_q = acc_q + compression.dequantize(q, s)
        acc_true = acc_true + g
    drift = float(jnp.max(jnp.abs(acc_q - acc_true)))
    assert drift <= float(jnp.max(jnp.abs(g))) / 127 + 1e-4


def test_compressed_psum_single_participant_exact_vs_quant():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import _mk
    mesh = _mk((1,), ("pod",))
    x = jnp.linspace(-1, 1, 64)
    err = jnp.zeros_like(x)

    def f(x, e):
        return compression.compressed_psum(x, e, "pod")

    out, new_err = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()))(x, err)
    assert float(jnp.max(jnp.abs(out - x))) <= 1.01 / 127


# ------------------------------------------------------------------ data ---

def test_synthetic_determinism_and_host_sharding():
    cfg = reduced(get_arch("qwen3-8b"))
    a0 = SyntheticSource(cfg, 16, 8, host_id=0, num_hosts=2).get(5)
    a0b = SyntheticSource(cfg, 16, 8, host_id=0, num_hosts=2).get(5)
    a1 = SyntheticSource(cfg, 16, 8, host_id=1, num_hosts=2).get(5)
    np.testing.assert_array_equal(a0["tokens"], a0b["tokens"])
    assert not np.array_equal(a0["tokens"], a1["tokens"])
    assert a0["tokens"].shape == (4, 16)  # local batch = 8/2
    # labels are next-token shifted
    np.testing.assert_array_equal(a0["tokens"][:, 1:], a0["labels"][:, :-1])


def test_memmap_source(tmp_path):
    cfg = reduced(get_arch("qwen3-8b"))
    corpus = MemmapSource.write_synthetic_corpus(
        tmp_path / "corpus.bin", cfg.vocab_size, 40_000)
    src = MemmapSource(corpus, cfg, seq_len=16, batch=4)
    b0, b1 = src.get(0), src.get(1)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(src.get(0)["tokens"], b0["tokens"])
    # host sharding reads disjoint stripes
    h0 = MemmapSource(corpus, cfg, 16, 4, host_id=0, num_hosts=2).get(0)
    h1 = MemmapSource(corpus, cfg, 16, 4, host_id=1, num_hosts=2).get(0)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_pipeline_prefetch_and_stats(tmp_path):
    cfg = reduced(get_arch("qwen3-8b"))
    src = SyntheticSource(cfg, 16, 4)
    pipe = Pipeline(src, prefetch=2)
    batches = [pipe.next() for _ in range(5)]
    assert all(b["tokens"].shape == (4, 16) for b in batches)
    b, t, w = pipe.stats.snapshot()
    assert b == 5 and t == 5 * 4 * 16
    pipe.close()
