"""Loop-aware HLO analyzer: validated against XLA's own cost analysis on
loop-free programs, and against known trip counts for scans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo import collective_summary
from repro.core.hlo_cost import analyze_hlo, parse_computations


def compile_fn(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def xla_cost(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returned [dict]
        ca = ca[0]
    return ca


def test_loop_free_matches_xla():
    def g(a, b, c):
        return jax.nn.relu(a @ b) @ c
    cg = compile_fn(g,
                    jax.ShapeDtypeStruct((128, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 256), jnp.float32),
                    jax.ShapeDtypeStruct((256, 32), jnp.float32))
    cost = analyze_hlo(cg.as_text())
    xla = xla_cost(cg)
    assert cost.flops == pytest.approx(xla["flops"], rel=0.02)
    assert cost.traffic_bytes == pytest.approx(xla["bytes accessed"],
                                               rel=0.1)


def test_scan_trip_scaling():
    def f(x, w):
        def body(x, wi):
            return x @ wi, None
        x, _ = jax.lax.scan(body, x, w)
        return x
    c = compile_fn(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                   jax.ShapeDtypeStruct((12, 128, 128), jnp.float32))
    cost = analyze_hlo(c.as_text())
    per_mm = 2 * 128 ** 3
    assert cost.flops == pytest.approx(12 * per_mm, rel=0.02)
    assert 12 in cost.loop_trips.values()
    # xla's own analysis counts the body once — document the discrepancy
    assert xla_cost(c)["flops"] == pytest.approx(per_mm, rel=0.02)


def test_nested_scan_trip_scaling():
    def f(x, w):
        def outer(x, wo):
            def inner(x, wi):
                return x @ wi, None
            x, _ = jax.lax.scan(inner, x, wo)
            return x, None
        x, _ = jax.lax.scan(outer, x, w)
        return x
    c = compile_fn(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                   jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32))
    cost = analyze_hlo(c.as_text())
    per_mm = 2 * 64 ** 3
    assert cost.flops == pytest.approx(12 * per_mm, rel=0.05)


def test_dus_slice_traffic_not_inflated():
    """Checkpoint-style stacking must not count the whole stack per
    write."""
    def f(xs):
        def body(acc, i):
            acc = jax.lax.dynamic_update_slice(
                acc, xs[i][None], (i, 0))
            return acc, None
        acc0 = jnp.zeros((16, 1024), jnp.float32)
        acc, _ = jax.lax.scan(body, acc0, jnp.arange(16))
        return acc
    c = compile_fn(f, jax.ShapeDtypeStruct((16, 1024), jnp.float32))
    cost = analyze_hlo(c.as_text())
    stack_bytes = 16 * 1024 * 4
    # naive counting would charge ~16 whole-stack transfers (>1MB);
    # slice-aware traffic stays within a few stack sizes
    assert cost.traffic_bytes < 6 * stack_bytes


def test_parse_computations_smoke():
    def g(a):
        return jnp.sin(a) + 1
    c = compile_fn(g, jax.ShapeDtypeStruct((32,), jnp.float32))
    comps = parse_computations(c.as_text())
    assert any(comp.is_entry for comp in comps.values())


def test_collective_summary_shapes():
    summary = collective_summary("""
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64]{0} all-gather(bf16[8]{0} %y), dimensions={0}
""")
    assert summary.per_kind["all-reduce"].operand_bytes == 128 * 256 * 4
    assert summary.per_kind["all-gather"].operand_bytes == 8 * 2
    assert summary.total_count == 2
